"""L2 JAX models (build-time only)."""
from . import deepfm, mnist_mlp, transformer_tiny  # noqa: F401
