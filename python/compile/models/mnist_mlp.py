"""MNIST MLP — the workload of the paper's Listings 1/2/4 (``mnist.py``).

A 784-256-128-10 classifier trained with softmax cross-entropy.  Dense
layers are the Pallas ``dense`` kernel.  This is the model the distributed
(TonY-like) driver trains for the Ke.com speedup experiment (E3): the
``grad_step`` artifact runs on each simulated worker over its data shard,
Rust all-reduces the gradients, and ``apply_update`` applies SGD.
"""

import numpy as np
import jax
import jax.numpy as jnp

from ..kernels import dense
from .common import glorot, sgd, softmax_cross_entropy

BATCH = 128
IN_DIM = 784
HIDDEN = (256, 128)
CLASSES = 10

PARAM_ORDER = ("w1", "b1", "w2", "b2", "w3", "b3")


def init_params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w1": glorot(rng, (IN_DIM, HIDDEN[0])),
        "b1": np.zeros((HIDDEN[0],), np.float32),
        "w2": glorot(rng, (HIDDEN[0], HIDDEN[1])),
        "b2": np.zeros((HIDDEN[1],), np.float32),
        "w3": glorot(rng, (HIDDEN[1], CLASSES)),
        "b3": np.zeros((CLASSES,), np.float32),
    }


def forward(params, x):
    w1, b1, w2, b2, w3, b3 = params
    h = dense(x, w1, b1, "relu")
    h = dense(h, w2, b2, "relu")
    return dense(h, w3, b3, "none")


def loss_fn(params, x, y):
    return softmax_cross_entropy(forward(params, x), y)


def _split(args):
    n = len(PARAM_ORDER)
    return tuple(args[:n]), args[n:]


def train_step(*args):
    """(*params, x, y, lr) -> (*new_params, loss)."""
    params, (x, y, lr) = _split(args)
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    return sgd(params, grads, lr) + (loss,)


def grad_step(*args):
    """(*params, x, y) -> (*grads, loss)."""
    params, (x, y) = _split(args)
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    return tuple(grads) + (loss,)


def apply_update(*args):
    """(*params, *grads, lr) -> (*new_params,)."""
    n = len(PARAM_ORDER)
    params, grads, lr = args[:n], args[n:2 * n], args[2 * n]
    return sgd(params, grads, lr)


def predict(*args):
    """(*params, x) -> logits f32[B, 10]."""
    params, (x,) = _split(args)
    return (forward(params, x),)


def example_batch():
    return {
        "x": jax.ShapeDtypeStruct((BATCH, IN_DIM), jnp.float32),
        "y": jax.ShapeDtypeStruct((BATCH,), jnp.int32),
        "lr": jax.ShapeDtypeStruct((), jnp.float32),
    }
