"""Shared building blocks for the L2 JAX models.

Every model in this package exposes the same AOT surface so the Rust
runtime can treat them uniformly:

- ``PARAM_ORDER``: ordered parameter names (the flat calling convention).
- ``init_params(seed) -> dict[name, np.ndarray]``
- ``train_step(*params, *batch, lr) -> (*new_params, loss)``
- ``grad_step(*params, *batch) -> (*grads, loss)``  (for data-parallel
  workers: Rust all-reduces the gradients and calls ``apply_update``)
- ``apply_update(*params, *grads, lr) -> (*new_params,)``
- ``predict(*params, *inputs) -> outputs``

All artifacts are lowered with static example shapes by ``compile/aot.py``.
"""

import jax.numpy as jnp
import numpy as np


def sigmoid_bce_with_logits(logits, labels):
    """Numerically stable binary cross-entropy over logits, mean-reduced."""
    # max(x,0) - x*y + log(1 + exp(-|x|))
    zeros = jnp.zeros_like(logits)
    loss = jnp.maximum(logits, zeros) - logits * labels + jnp.log1p(
        jnp.exp(-jnp.abs(logits)))
    return jnp.mean(loss)


def softmax_cross_entropy(logits, labels):
    """Mean softmax cross-entropy; labels are int class ids.

    logits: f32[..., C], labels: i32[...].
    """
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    logz = jnp.log(jnp.sum(jnp.exp(logits), axis=-1))
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - ll)


def sgd(params, grads, lr):
    """Plain SGD update over a tuple of arrays."""
    return tuple(p - lr * g for p, g in zip(params, grads))


def glorot(rng, shape):
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    fan_out = shape[-1]
    limit = np.sqrt(6.0 / (fan_in + fan_out))
    return rng.uniform(-limit, limit, size=shape).astype(np.float32)


def param_count(params):
    return int(sum(np.prod(p.shape) for p in params.values()))
