"""DeepFM (Guo et al., IJCAI'17) — the paper's Listing-3 headline workload.

CTR prediction over hashed sparse features:

    logit = b0 + <linear term> + <FM 2nd-order term> + <deep tower>

The FM second-order term is the Pallas ``fm_interaction`` kernel; the deep
tower layers are the Pallas ``dense`` kernel.  Input convention follows the
Criteo setup the Submarine SDK's DeepFM targets: ``F`` feature fields, each
hashed into a shared vocabulary of size ``V``; a batch is ``(ids i32[B,F],
vals f32[B,F], labels f32[B])``.
"""

import numpy as np
import jax
import jax.numpy as jnp

from ..kernels import dense, fm_interaction
from .common import glorot, sgd, sigmoid_bce_with_logits

# Static AOT configuration (mirrors deepfm.json in the Submarine SDK docs).
BATCH = 256
FIELDS = 39
# Hashed-vocabulary size.  5k (not Criteo's millions) so plain-SGD sparse
# updates revisit each id often enough to converge in a few hundred demo
# steps — the platform behaviour under test, not CTR SOTA.
VOCAB = 5_000
EMB_DIM = 8
HIDDEN = (200, 200)

PARAM_ORDER = ("emb", "lin", "b0", "w1", "b1", "w2", "b2", "w3", "b3")


def init_params(seed=0):
    rng = np.random.default_rng(seed)
    d_in = FIELDS * EMB_DIM
    return {
        "emb": (rng.normal(size=(VOCAB, EMB_DIM)) * 0.01).astype(np.float32),
        "lin": np.zeros((VOCAB,), np.float32),
        "b0": np.zeros((1,), np.float32),
        "w1": glorot(rng, (d_in, HIDDEN[0])),
        "b1": np.zeros((HIDDEN[0],), np.float32),
        "w2": glorot(rng, (HIDDEN[0], HIDDEN[1])),
        "b2": np.zeros((HIDDEN[1],), np.float32),
        "w3": glorot(rng, (HIDDEN[1], 1)),
        "b3": np.zeros((1,), np.float32),
    }


def forward(params, ids, vals):
    """logits f32[B] from (ids i32[B,F], vals f32[B,F])."""
    emb, lin, b0, w1, b1, w2, b2, w3, b3 = params
    v = emb[ids] * vals[..., None]            # [B, F, K]
    linear = jnp.sum(lin[ids] * vals, axis=1)  # [B]
    fm = fm_interaction(v)                     # [B] — Pallas kernel
    h = v.reshape(v.shape[0], -1)              # [B, F*K]
    h = dense(h, w1, b1, "relu")               # Pallas kernel
    h = dense(h, w2, b2, "relu")
    deep = dense(h, w3, b3, "none")[:, 0]      # [B]
    return b0[0] + linear + fm + deep


def loss_fn(params, ids, vals, labels):
    return sigmoid_bce_with_logits(forward(params, ids, vals), labels)


def _split(args):
    n = len(PARAM_ORDER)
    return tuple(args[:n]), args[n:]


def train_step(*args):
    """(*params, ids, vals, labels, lr) -> (*new_params, loss)."""
    params, rest = _split(args)
    ids, vals, labels, lr = rest
    loss, grads = jax.value_and_grad(loss_fn)(params, ids, vals, labels)
    return sgd(params, grads, lr) + (loss,)


def grad_step(*args):
    """(*params, ids, vals, labels) -> (*grads, loss)."""
    params, rest = _split(args)
    ids, vals, labels = rest
    loss, grads = jax.value_and_grad(loss_fn)(params, ids, vals, labels)
    return tuple(grads) + (loss,)


def apply_update(*args):
    """(*params, *grads, lr) -> (*new_params,)."""
    n = len(PARAM_ORDER)
    params, grads, lr = args[:n], args[n:2 * n], args[2 * n]
    return sgd(params, grads, lr)


def predict(*args):
    """(*params, ids, vals) -> probabilities f32[B]."""
    params, rest = _split(args)
    ids, vals = rest
    return (jax.nn.sigmoid(forward(params, ids, vals)),)


def example_batch():
    return {
        "ids": jax.ShapeDtypeStruct((BATCH, FIELDS), jnp.int32),
        "vals": jax.ShapeDtypeStruct((BATCH, FIELDS), jnp.float32),
        "labels": jax.ShapeDtypeStruct((BATCH,), jnp.float32),
        "lr": jax.ShapeDtypeStruct((), jnp.float32),
    }
