"""Tiny transformer encoder — the BERT-Large proxy for the LinkedIn use
case (paper §6.2: 24-layer, 300M+ parameter BERT on a 50-node cluster).

One CPU core cannot train BERT-Large; per DESIGN.md §Substitutions this
module keeps the *structure* (token embedding, multi-head self-attention,
GELU FFN, layernorm, tied LM head) at a tiny scale, and the LinkedIn bench
scales measured step times with an analytic FLOP model to the paper's
cluster.  FFN layers use the Pallas ``dense`` kernel.
"""

import numpy as np
import jax
import jax.numpy as jnp

from ..kernels import dense
from .common import glorot, sgd, softmax_cross_entropy

BATCH = 8
SEQ = 32
VOCAB = 1_000
D_MODEL = 64
N_HEADS = 4
N_LAYERS = 2
D_FF = 256

# Parameter layout: embedding + positional, then per layer
# (wq, wk, wv, wo, ln1_g, ln1_b, wff1, bff1, wff2, bff2, ln2_g, ln2_b).
_LAYER_PARAMS = ("wq", "wk", "wv", "wo", "ln1_g", "ln1_b",
                 "wff1", "bff1", "wff2", "bff2", "ln2_g", "ln2_b")
PARAM_ORDER = ("emb", "pos") + tuple(
    f"l{i}_{p}" for i in range(N_LAYERS) for p in _LAYER_PARAMS)


def init_params(seed=0):
    rng = np.random.default_rng(seed)
    params = {
        "emb": (rng.normal(size=(VOCAB, D_MODEL)) * 0.02).astype(np.float32),
        "pos": (rng.normal(size=(SEQ, D_MODEL)) * 0.02).astype(np.float32),
    }
    for i in range(N_LAYERS):
        params[f"l{i}_wq"] = glorot(rng, (D_MODEL, D_MODEL))
        params[f"l{i}_wk"] = glorot(rng, (D_MODEL, D_MODEL))
        params[f"l{i}_wv"] = glorot(rng, (D_MODEL, D_MODEL))
        params[f"l{i}_wo"] = glorot(rng, (D_MODEL, D_MODEL))
        params[f"l{i}_ln1_g"] = np.ones((D_MODEL,), np.float32)
        params[f"l{i}_ln1_b"] = np.zeros((D_MODEL,), np.float32)
        params[f"l{i}_wff1"] = glorot(rng, (D_MODEL, D_FF))
        params[f"l{i}_bff1"] = np.zeros((D_FF,), np.float32)
        params[f"l{i}_wff2"] = glorot(rng, (D_FF, D_MODEL))
        params[f"l{i}_bff2"] = np.zeros((D_MODEL,), np.float32)
        params[f"l{i}_ln2_g"] = np.ones((D_MODEL,), np.float32)
        params[f"l{i}_ln2_b"] = np.zeros((D_MODEL,), np.float32)
    return params


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(x, wq, wk, wv, wo):
    b, s, d = x.shape
    hd = d // N_HEADS
    q = (x @ wq).reshape(b, s, N_HEADS, hd).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, s, N_HEADS, hd).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, s, N_HEADS, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(hd)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ wo


def forward(params, ids):
    p = dict(zip(PARAM_ORDER, params))
    b, s = ids.shape
    x = p["emb"][ids] + p["pos"][None, :s]
    for i in range(N_LAYERS):
        h = _layernorm(x, p[f"l{i}_ln1_g"], p[f"l{i}_ln1_b"])
        x = x + _attention(h, p[f"l{i}_wq"], p[f"l{i}_wk"],
                           p[f"l{i}_wv"], p[f"l{i}_wo"])
        h = _layernorm(x, p[f"l{i}_ln2_g"], p[f"l{i}_ln2_b"])
        h2 = h.reshape(b * s, D_MODEL)
        h2 = dense(h2, p[f"l{i}_wff1"], p[f"l{i}_bff1"], "relu")  # Pallas
        h2 = dense(h2, p[f"l{i}_wff2"], p[f"l{i}_bff2"], "none")  # Pallas
        x = x + h2.reshape(b, s, D_MODEL)
    return x @ p["emb"].T  # tied LM head: logits f32[B,S,V]


def loss_fn(params, ids, targets):
    return softmax_cross_entropy(forward(params, ids), targets)


def _split(args):
    n = len(PARAM_ORDER)
    return tuple(args[:n]), args[n:]


def train_step(*args):
    """(*params, ids, targets, lr) -> (*new_params, loss)."""
    params, (ids, targets, lr) = _split(args)
    loss, grads = jax.value_and_grad(loss_fn)(params, ids, targets)
    return sgd(params, grads, lr) + (loss,)


def grad_step(*args):
    """(*params, ids, targets) -> (*grads, loss)."""
    params, (ids, targets) = _split(args)
    loss, grads = jax.value_and_grad(loss_fn)(params, ids, targets)
    return tuple(grads) + (loss,)


def apply_update(*args):
    """(*params, *grads, lr) -> (*new_params,)."""
    n = len(PARAM_ORDER)
    params, grads, lr = args[:n], args[n:2 * n], args[2 * n]
    return sgd(params, grads, lr)


def predict(*args):
    """(*params, ids) -> logits f32[B,S,V]."""
    params, (ids,) = _split(args)
    return (forward(params, ids),)


def example_batch():
    return {
        "ids": jax.ShapeDtypeStruct((BATCH, SEQ), jnp.int32),
        "targets": jax.ShapeDtypeStruct((BATCH, SEQ), jnp.int32),
        "lr": jax.ShapeDtypeStruct((), jnp.float32),
    }
