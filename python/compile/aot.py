"""AOT compiler: lower every L2 model artifact to HLO text + emit the
manifest the Rust runtime loads.

Run once at build time (``make artifacts``); Python never runs on the
request path.  Outputs, per model:

- ``artifacts/<model>_<artifact>.hlo.txt``  — HLO text per entry point
  (train_step / grad_step / apply_update / predict)
- ``artifacts/<model>.params``              — initial parameters, raw
  little-endian f32, tensors concatenated in PARAM_ORDER
- ``artifacts/manifest.json``               — shapes/dtypes/offsets for all
  of the above (the Rust side's single source of truth)
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from .hlo import to_hlo_text
from .models import deepfm, mnist_mlp, transformer_tiny
from .models.common import param_count

MODELS = {
    "deepfm": deepfm,
    "mnist_mlp": mnist_mlp,
    "transformer_tiny": transformer_tiny,
}

# Entry points lowered for every model, with their example signatures.
ARTIFACTS = ("train_step", "grad_step", "apply_update", "predict")


def _param_specs(mod):
    params = mod.init_params()
    return [jax.ShapeDtypeStruct(params[n].shape, jnp.float32)
            for n in mod.PARAM_ORDER], params


def _spec_meta(name, spec):
    return {"name": name, "shape": list(spec.shape), "dtype": spec.dtype.name}


def _artifact_signature(mod, artifact, pspecs):
    """Example args + input metadata for one entry point."""
    batch = mod.example_batch()
    names = list(batch.keys())           # e.g. [ids, vals, labels, lr]
    data_specs = [batch[n] for n in names]
    pmeta = [_spec_meta(n, s) for n, s in zip(mod.PARAM_ORDER, pspecs)]
    if artifact == "train_step":
        args = pspecs + data_specs
        meta = pmeta + [_spec_meta(n, s) for n, s in zip(names, data_specs)]
    elif artifact == "grad_step":
        args = pspecs + data_specs[:-1]  # no lr
        meta = pmeta + [_spec_meta(n, s)
                        for n, s in zip(names[:-1], data_specs[:-1])]
    elif artifact == "apply_update":
        lr = data_specs[-1]
        args = pspecs + pspecs + [lr]
        meta = (pmeta
                + [_spec_meta("g_" + n, s)
                   for n, s in zip(mod.PARAM_ORDER, pspecs)]
                + [_spec_meta("lr", lr)])
    elif artifact == "predict":
        # inputs only (no labels/targets, no lr)
        n_in = len(names) - 2
        args = pspecs + data_specs[:n_in]
        meta = pmeta + [_spec_meta(n, s)
                        for n, s in zip(names[:n_in], data_specs[:n_in])]
    else:
        raise ValueError(artifact)
    return args, meta


def _output_meta(mod, artifact):
    n = len(mod.PARAM_ORDER)
    if artifact == "train_step":
        return [{"name": p} for p in mod.PARAM_ORDER] + [{"name": "loss"}]
    if artifact == "grad_step":
        return [{"name": "g_" + p} for p in mod.PARAM_ORDER] + [
            {"name": "loss"}]
    if artifact == "apply_update":
        return [{"name": p} for p in mod.PARAM_ORDER]
    if artifact == "predict":
        return [{"name": "out"}]
    raise ValueError(artifact)


def compile_model(name, mod, outdir):
    pspecs, params = _param_specs(mod)
    entry = {
        "param_order": list(mod.PARAM_ORDER),
        "param_shapes": {n: list(params[n].shape) for n in mod.PARAM_ORDER},
        "param_count": param_count(params),
        "params_file": f"{name}.params",
        "batch_inputs": list(mod.example_batch().keys()),
        "artifacts": {},
    }
    # Dump initial parameters (flat f32, PARAM_ORDER concatenation).
    with open(os.path.join(outdir, f"{name}.params"), "wb") as f:
        for pname in mod.PARAM_ORDER:
            f.write(np.ascontiguousarray(
                params[pname], dtype="<f4").tobytes())

    for artifact in ARTIFACTS:
        fn = getattr(mod, artifact)
        args, in_meta = _artifact_signature(mod, artifact, pspecs)
        text = to_hlo_text(fn, *args)
        fname = f"{name}_{artifact}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        entry["artifacts"][artifact] = {
            "file": fname,
            "inputs": in_meta,
            "outputs": _output_meta(mod, artifact),
        }
        print(f"  {fname}: {len(text)} chars, {len(in_meta)} inputs")
    return entry


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for artifacts")
    ap.add_argument("--models", default=",".join(MODELS),
                    help="comma-separated subset of models to compile")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    # Merge into an existing manifest so `--models <subset>` recompiles
    # incrementally instead of clobbering the other entries.
    manifest = {"format": 1, "models": {}}
    manifest_path = os.path.join(args.out, "manifest.json")
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            try:
                manifest = json.load(f)
            except json.JSONDecodeError:
                pass
    for name in args.models.split(","):
        print(f"compiling {name} ...")
        manifest["models"][name] = compile_model(name, MODELS[name], args.out)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
