"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must match its oracle under `numpy.testing.assert_allclose` across the
shape/dtype sweeps in ``python/tests/test_kernels.py``.
"""

import jax.numpy as jnp


def fm_interaction_ref(v):
    """Factorization-Machine second-order interaction term.

    Args:
      v: f32[batch, fields, k] — per-field embedding vectors (already scaled
         by the feature values).

    Returns:
      f32[batch] — 0.5 * sum_k ((sum_f v_fk)^2 - sum_f v_fk^2), i.e. the
      sum over all unordered field pairs of <v_i, v_j>.
    """
    s = jnp.sum(v, axis=1)            # [B, K]
    q = jnp.sum(v * v, axis=1)        # [B, K]
    return 0.5 * jnp.sum(s * s - q, axis=-1)


def dense_ref(x, w, b, activation="relu"):
    """Dense layer oracle: x @ w + b with optional activation.

    Args:
      x: f32[batch, in_dim]
      w: f32[in_dim, out_dim]
      b: f32[out_dim]
      activation: "relu" | "none"
    """
    y = jnp.dot(x, w) + b[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y
