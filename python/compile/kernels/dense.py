"""Blocked dense (matmul + bias + activation) Pallas kernel.

Used for the DeepFM deep tower and the MNIST MLP layers.  The grid tiles
the output matrix in (block_m, block_n) panels; the contraction dimension is
kept whole inside a tile (layer widths here are <= 1024, so an in_dim x
block_n panel of f32 weights is well under a VMEM budget).  Tile sizes
default to MXU-friendly multiples of 128 — see DESIGN.md
§Hardware-Adaptation for the GPU->TPU mapping rationale.

Like ``fm_interaction``, the forward is Pallas and the backward is the
analytic jnp gradient via ``jax.custom_vjp`` so train steps lower into a
single HLO module.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128


def _dense_relu_kernel(x_ref, w_ref, b_ref, o_ref):
    y = jnp.dot(x_ref[...], w_ref[...]) + b_ref[...][None, :]
    o_ref[...] = jnp.maximum(y, 0.0)


def _dense_none_kernel(x_ref, w_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...]) + b_ref[...][None, :]


def _dense_pallas(x, w, b, activation, block_m, block_n):
    m, kdim = x.shape
    _, n = w.shape
    pm = (-m) % block_m
    pn = (-n) % block_n
    xp = jnp.pad(x, ((0, pm), (0, 0))) if pm else x
    wp = jnp.pad(w, ((0, 0), (0, pn))) if pn else w
    bp = jnp.pad(b, (0, pn)) if pn else b
    mm, nn = m + pm, n + pn
    kernel = _dense_relu_kernel if activation == "relu" else _dense_none_kernel
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((mm, nn), x.dtype),
        grid=(mm // block_m, nn // block_n),
        in_specs=[
            pl.BlockSpec((block_m, kdim), lambda i, j: (i, 0)),
            pl.BlockSpec((kdim, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def dense(x, w, b, activation="relu",
          block_m=DEFAULT_BLOCK_M, block_n=DEFAULT_BLOCK_N):
    """Dense layer f32[M,K] @ f32[K,N] + f32[N], Pallas forward."""
    return _dense_pallas(x, w, b, activation, block_m, block_n)


def _dense_fwd(x, w, b, activation, block_m, block_n):
    y = _dense_pallas(x, w, b, activation, block_m, block_n)
    return y, (x, w, y)


def _dense_bwd(activation, block_m, block_n, res, g):
    x, w, y = res
    if activation == "relu":
        g = g * (y > 0).astype(g.dtype)
    dx = jnp.dot(g, w.T)
    dw = jnp.dot(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
