"""L1 Pallas kernels (build-time only) + pure-jnp oracles."""
from .fm_interaction import fm_interaction
from .dense import dense
from . import ref

__all__ = ["fm_interaction", "dense", "ref"]
