"""Pallas kernel for the Factorization-Machine second-order interaction.

This is the compute hot-spot of DeepFM (the paper's Listing-3 headline
workload).  The kernel is tiled over the batch dimension with a BlockSpec so
each block's working set (block_b * fields * k floats) stays far below a
TPU-core VMEM budget (~16 MiB); on the CPU PJRT plugin it runs through
``interpret=True`` (real-TPU lowering would emit a Mosaic custom-call that
the CPU client cannot execute — see DESIGN.md §Hardware-Adaptation).

The kernel is wrapped in ``jax.custom_vjp`` so the DeepFM training step can
differentiate through it: the forward pass is the Pallas kernel, the
backward pass is the analytic gradient (d/dv_f = s - v_f per latent dim),
expressed in jnp and fused by XLA into the same HLO module.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _fm_kernel(v_ref, o_ref):
    """One batch tile: o[b] = 0.5 * sum_k((sum_f v)^2 - sum_f v^2)."""
    v = v_ref[...]                      # [bb, F, K]
    s = jnp.sum(v, axis=1)              # [bb, K]
    q = jnp.sum(v * v, axis=1)          # [bb, K]
    o_ref[...] = 0.5 * jnp.sum(s * s - q, axis=-1)


def _fm_pallas(v, block_b):
    b, f, k = v.shape
    # Pad the batch up to a block multiple so the grid tiles exactly; the
    # pad rows are zeros and are sliced off below.
    pb = (-b) % block_b
    if pb:
        v = jnp.pad(v, ((0, pb), (0, 0), (0, 0)))
    grid = (v.shape[0] // block_b,)
    out = pl.pallas_call(
        _fm_kernel,
        out_shape=jax.ShapeDtypeStruct((v.shape[0],), v.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, f, k), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        interpret=True,
    )(v)
    return out[:b]


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def fm_interaction(v, block_b=DEFAULT_BLOCK_B):
    """FM second-order term, f32[B,F,K] -> f32[B] (Pallas forward)."""
    return _fm_pallas(v, block_b)


def _fm_fwd(v, block_b):
    return _fm_pallas(v, block_b), v


def _fm_bwd(block_b, v, g):
    # d out / d v[b,f,k] = sum_f' v[b,f',k] - v[b,f,k]
    s = jnp.sum(v, axis=1, keepdims=True)     # [B,1,K]
    return (g[:, None, None] * (s - v),)


fm_interaction.defvjp(_fm_fwd, _fm_bwd)
