"""HLO-text lowering helper.

HLO *text* (not serialized HloModuleProto) is the Python->Rust interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which the xla
crate's XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids, so text round-trips cleanly.  Lower with
``return_tuple=True`` and unwrap with ``Literal::to_tuple*`` on the Rust
side.
"""

import jax
from jax._src.lib import xla_client as xc


def to_hlo_text(fn, *example_args) -> str:
    """Lower ``jax.jit(fn)`` at the example shapes to XLA HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
