"""L2 model checks: shapes, loss decrease under training, artifact
signature consistency (train_step == grad_step + apply_update)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import deepfm, mnist_mlp, transformer_tiny

MODELS = {
    "deepfm": deepfm,
    "mnist_mlp": mnist_mlp,
    "transformer_tiny": transformer_tiny,
}


def _params_tuple(mod, seed=0):
    p = mod.init_params(seed)
    return tuple(jnp.asarray(p[n]) for n in mod.PARAM_ORDER)


def _batch(mod, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for name, spec in mod.example_batch().items():
        if name == "lr":
            out.append(jnp.asarray(0.05, jnp.float32))
        elif spec.dtype == jnp.int32:
            hi = {"deepfm": deepfm.VOCAB,
                  "transformer_tiny": transformer_tiny.VOCAB,
                  "mnist_mlp": mnist_mlp.CLASSES}
            mx = hi[mod.__name__.split(".")[-1]]
            out.append(jnp.asarray(
                rng.integers(0, mx, size=spec.shape).astype(np.int32)))
        else:
            out.append(jnp.asarray(
                rng.normal(size=spec.shape).astype(np.float32)))
    return tuple(out)


def _labels_fixup(mod, batch):
    # deepfm labels must be 0/1
    if mod is deepfm:
        ids, vals, labels, lr = batch
        labels = (labels > 0).astype(jnp.float32)
        return (ids, vals, labels, lr)
    return batch


@pytest.mark.parametrize("name", list(MODELS))
def test_train_step_shapes_and_finite(name):
    mod = MODELS[name]
    params = _params_tuple(mod)
    batch = _labels_fixup(mod, _batch(mod))
    out = mod.train_step(*params, *batch)
    assert len(out) == len(mod.PARAM_ORDER) + 1
    for p, q in zip(params, out[:-1]):
        assert p.shape == q.shape
        assert bool(jnp.all(jnp.isfinite(q)))
    assert out[-1].shape == ()
    assert bool(jnp.isfinite(out[-1]))


@pytest.mark.parametrize("name", list(MODELS))
def test_loss_decreases_over_steps(name):
    mod = MODELS[name]
    params = _params_tuple(mod)
    batch = _labels_fixup(mod, _batch(mod))
    losses = []
    for _ in range(8):
        out = mod.train_step(*params, *batch)
        params, loss = out[:-1], out[-1]
        losses.append(float(loss))
    # training on a fixed batch must reduce the loss
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("name", list(MODELS))
def test_grad_plus_apply_equals_train_step(name):
    mod = MODELS[name]
    params = _params_tuple(mod)
    batch = _labels_fixup(mod, _batch(mod))
    lr = batch[-1]
    t_out = mod.train_step(*params, *batch)
    g_out = mod.grad_step(*params, *batch[:-1])
    grads, g_loss = g_out[:-1], g_out[-1]
    a_out = mod.apply_update(*params, *grads, lr)
    np.testing.assert_allclose(float(g_loss), float(t_out[-1]), rtol=1e-5)
    for a, t in zip(a_out, t_out[:-1]):
        np.testing.assert_allclose(a, t, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name", list(MODELS))
def test_predict_shape(name):
    mod = MODELS[name]
    params = _params_tuple(mod)
    batch = _batch(mod)
    n_in = len(mod.example_batch()) - 2  # drop labels/targets + lr
    (out,) = mod.predict(*params, *batch[:n_in])
    if mod is deepfm:
        assert out.shape == (deepfm.BATCH,)
        assert bool(jnp.all((out >= 0) & (out <= 1)))
    elif mod is mnist_mlp:
        assert out.shape == (mnist_mlp.BATCH, mnist_mlp.CLASSES)
    else:
        assert out.shape == (transformer_tiny.BATCH, transformer_tiny.SEQ,
                             transformer_tiny.VOCAB)


@pytest.mark.parametrize("name", list(MODELS))
def test_param_order_matches_init(name):
    mod = MODELS[name]
    params = mod.init_params()
    assert set(params) == set(mod.PARAM_ORDER)
    assert len(mod.PARAM_ORDER) == len(set(mod.PARAM_ORDER))


def test_deepfm_fm_term_contributes():
    """DeepFM logit must depend on embedding interactions (FM path)."""
    params = list(_params_tuple(deepfm))
    ids, vals, labels, lr = _labels_fixup(deepfm, _batch(deepfm))
    base = deepfm.forward(tuple(params), ids, vals)
    params[0] = params[0] * 2.0  # scale embeddings
    bumped = deepfm.forward(tuple(params), ids, vals)
    assert not np.allclose(np.asarray(base), np.asarray(bumped))
