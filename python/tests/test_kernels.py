"""Kernel-vs-oracle correctness: the CORE signal for the L1 layer.

Hypothesis sweeps shapes (including non-divisible-by-block sizes, which
exercise the pad/slice path) and values; every case must match the
pure-jnp oracle to float32 tolerance, for both forward and gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense, fm_interaction, ref

TOL = dict(rtol=2e-4, atol=2e-4)


def _arr(rng, shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------- fm kernel

@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 300),
    f=st.integers(1, 48),
    k=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_fm_matches_ref(b, f, k, seed):
    rng = np.random.default_rng(seed)
    v = _arr(rng, (b, f, k))
    np.testing.assert_allclose(
        fm_interaction(v), ref.fm_interaction_ref(v), **TOL)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 64), f=st.integers(2, 16), k=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_fm_grad_matches_ref(b, f, k, seed):
    rng = np.random.default_rng(seed)
    v = _arr(rng, (b, f, k))
    g1 = jax.grad(lambda v: jnp.sum(fm_interaction(v) ** 2))(v)
    g2 = jax.grad(lambda v: jnp.sum(ref.fm_interaction_ref(v) ** 2))(v)
    np.testing.assert_allclose(g1, g2, **TOL)


@pytest.mark.parametrize("block_b", [1, 8, 128, 256])
def test_fm_block_size_invariance(block_b):
    rng = np.random.default_rng(0)
    v = _arr(rng, (100, 13, 8))
    np.testing.assert_allclose(
        fm_interaction(v, block_b), ref.fm_interaction_ref(v), **TOL)


def test_fm_zero_input():
    v = jnp.zeros((5, 4, 3), jnp.float32)
    np.testing.assert_allclose(fm_interaction(v), np.zeros(5), **TOL)


def test_fm_single_field_is_zero():
    # One field has no pairwise interactions.
    rng = np.random.default_rng(1)
    v = _arr(rng, (17, 1, 8))
    np.testing.assert_allclose(fm_interaction(v), np.zeros(17), **TOL)


def test_fm_two_fields_is_dot_product():
    rng = np.random.default_rng(2)
    v = _arr(rng, (9, 2, 6))
    expect = np.sum(np.asarray(v[:, 0]) * np.asarray(v[:, 1]), axis=-1)
    np.testing.assert_allclose(fm_interaction(v), expect, **TOL)


# ------------------------------------------------------------- dense kernel

@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 96),
    n=st.integers(1, 200),
    act=st.sampled_from(["relu", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _arr(rng, (m, k)), _arr(rng, (k, n)), _arr(rng, (n,))
    np.testing.assert_allclose(
        dense(x, w, b, act), ref.dense_ref(x, w, b, act), **TOL)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 64), k=st.integers(1, 32), n=st.integers(1, 48),
       seed=st.integers(0, 2**31 - 1))
def test_dense_grads_match_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _arr(rng, (m, k)), _arr(rng, (k, n)), _arr(rng, (n,))

    def f_ker(x, w, b):
        return jnp.sum(dense(x, w, b, "relu") ** 2)

    def f_ref(x, w, b):
        return jnp.sum(ref.dense_ref(x, w, b, "relu") ** 2)

    g1 = jax.grad(f_ker, argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(g1, g2):
        np.testing.assert_allclose(a, e, **TOL)


@pytest.mark.parametrize("bm,bn", [(1, 1), (8, 16), (128, 128), (256, 64)])
def test_dense_block_size_invariance(bm, bn):
    rng = np.random.default_rng(3)
    x, w, b = _arr(rng, (90, 33)), _arr(rng, (33, 70)), _arr(rng, (70,))
    np.testing.assert_allclose(
        dense(x, w, b, "relu", bm, bn), ref.dense_ref(x, w, b), **TOL)


def test_dense_identity():
    eye = jnp.eye(16, dtype=jnp.float32)
    b = jnp.zeros((16,), jnp.float32)
    rng = np.random.default_rng(4)
    x = _arr(rng, (5, 16))
    np.testing.assert_allclose(dense(x, eye, b, "none"), x, **TOL)


def test_dense_relu_clamps():
    x = jnp.asarray([[-1.0, 2.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros((2,), jnp.float32)
    np.testing.assert_allclose(
        dense(x, w, b, "relu"), [[0.0, 2.0]], **TOL)


def test_dense_rejects_bad_activation():
    with pytest.raises(ValueError):
        ref.dense_ref(jnp.zeros((1, 1)), jnp.zeros((1, 1)),
                      jnp.zeros((1,)), "tanh")
