"""AOT pipeline checks: artifacts exist, manifest is self-consistent, and
the params dump round-trips against ``init_params``."""

import json
import os
import struct

import numpy as np
import pytest

from compile.aot import ARTIFACTS, MODELS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


def _manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_covers_all_models_and_artifacts():
    man = _manifest()
    assert set(man["models"]) == set(MODELS)
    for name, entry in man["models"].items():
        assert set(entry["artifacts"]) == set(ARTIFACTS)
        for art in entry["artifacts"].values():
            assert os.path.exists(os.path.join(ART, art["file"]))


def test_hlo_files_are_text_modules():
    man = _manifest()
    for entry in man["models"].values():
        for art in entry["artifacts"].values():
            with open(os.path.join(ART, art["file"])) as f:
                head = f.read(200)
            assert "HloModule" in head, art["file"]


def test_params_files_match_shapes_and_values():
    man = _manifest()
    for name, entry in man["models"].items():
        expect = MODELS[name].init_params()
        path = os.path.join(ART, entry["params_file"])
        raw = np.fromfile(path, dtype="<f4")
        total = sum(int(np.prod(entry["param_shapes"][p]))
                    for p in entry["param_order"])
        assert raw.size == total
        off = 0
        for p in entry["param_order"]:
            shape = entry["param_shapes"][p]
            n = int(np.prod(shape))
            got = raw[off:off + n].reshape(shape)
            np.testing.assert_allclose(got, expect[p], rtol=1e-6)
            off += n


def test_manifest_input_shapes_match_models():
    man = _manifest()
    for name, entry in man["models"].items():
        mod = MODELS[name]
        ts = entry["artifacts"]["train_step"]["inputs"]
        # first inputs are params in PARAM_ORDER
        for meta, pname in zip(ts, entry["param_order"]):
            assert meta["name"] == pname
            assert meta["shape"] == entry["param_shapes"][pname]
        # remaining are the batch inputs
        batch = mod.example_batch()
        tail = ts[len(entry["param_order"]):]
        assert [m["name"] for m in tail] == list(batch.keys())


def test_train_step_outputs_are_params_plus_loss():
    man = _manifest()
    for entry in man["models"].values():
        outs = entry["artifacts"]["train_step"]["outputs"]
        assert [o["name"] for o in outs] == entry["param_order"] + ["loss"]
