//! Pure-Rust stub of the `xla` (PJRT) bindings used by `submarine`.
//!
//! The deployment image carries the real XLA/PJRT toolchain; this CI and
//! laptop build does not, and the offline registry cannot fetch native
//! bindings. The stub keeps the whole platform compiling and testable:
//!
//! - [`Literal`] is fully functional host-side tensor data (scalar/vec1/
//!   reshape/to_vec round-trips are bit-exact), so every code path that
//!   marshals batches and parameters works for real.
//! - Device entry points ([`PjRtClient::compile`],
//!   [`PjRtLoadedExecutable::execute`], [`HloModuleProto::from_text_file`])
//!   return [`Error`] `"xla backend unavailable"`. Callers already gate
//!   on compiled artifacts being present, so tests skip rather than fail.

use std::fmt;

/// Error type mirroring the real bindings' opaque error.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    fn unavailable(what: &str) -> Error {
        Error::new(format!(
            "xla backend unavailable ({what}): built against the in-tree \
             stub; install the PJRT plugin build to execute artifacts"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn scalar_literal(self) -> Literal;
    fn vec1_literal(data: &[Self]) -> Literal;
    fn read_literal(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn scalar_literal(self) -> Literal {
        Literal::F32 {
            data: vec![self],
            dims: Vec::new(),
        }
    }
    fn vec1_literal(data: &[f32]) -> Literal {
        Literal::F32 {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }
    fn read_literal(lit: &Literal) -> Result<Vec<f32>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            other => Err(Error::new(format!(
                "literal is not f32: {}",
                other.type_name()
            ))),
        }
    }
}

impl NativeType for i32 {
    fn scalar_literal(self) -> Literal {
        Literal::I32 {
            data: vec![self],
            dims: Vec::new(),
        }
    }
    fn vec1_literal(data: &[i32]) -> Literal {
        Literal::I32 {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }
    fn read_literal(lit: &Literal) -> Result<Vec<i32>> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            other => Err(Error::new(format!(
                "literal is not i32: {}",
                other.type_name()
            ))),
        }
    }
}

/// Host-side tensor value (the real crate's device-backed literal).
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    fn type_name(&self) -> &'static str {
        match self {
            Literal::F32 { .. } => "f32",
            Literal::I32 { .. } => "i32",
            Literal::Tuple(_) => "tuple",
        }
    }

    fn len(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
            Literal::Tuple(t) => t.len(),
        }
    }

    pub fn scalar<T: NativeType>(v: T) -> Literal {
        v.scalar_literal()
    }

    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::vec1_literal(data)
    }

    /// Reinterpret the flat data with new dimensions (element count must
    /// match, as in the real bindings).
    pub fn reshape(&self, new_dims: &[i64]) -> Result<Literal> {
        let n: i64 = new_dims.iter().product();
        if n < 0 || n as usize != self.len() {
            return Err(Error::new(format!(
                "reshape: {} elements into dims {new_dims:?}",
                self.len()
            )));
        }
        Ok(match self {
            Literal::F32 { data, .. } => Literal::F32 {
                data: data.clone(),
                dims: new_dims.to_vec(),
            },
            Literal::I32 { data, .. } => Literal::I32 {
                data: data.clone(),
                dims: new_dims.to_vec(),
            },
            Literal::Tuple(_) => {
                return Err(Error::new("cannot reshape a tuple literal"))
            }
        })
    }

    /// Read the flat element data back to the host.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read_literal(self)
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::read_literal(self)?
            .first()
            .copied()
            .ok_or_else(|| Error::new("empty literal"))
    }

    /// Decompose a tuple literal into its leaves.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            other => Ok(vec![other]),
        }
    }
}

/// Marker for types accepted by [`PjRtLoadedExecutable::execute`]
/// (owned or borrowed literals, like the real generic bound).
pub trait ExecuteInput {}
impl ExecuteInput for Literal {}
impl<'a> ExecuteInput for &'a Literal {}

/// Parsed HLO module handle. Parsing requires the native toolchain.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HLO text parser"))
    }
}

/// Computation handle wrapping an [`HloModuleProto`].
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by execution.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// PJRT client handle; construction succeeds so the service stack wires
/// up, and only artifact compilation/execution reports unavailability.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compiler"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: ExecuteInput>(
        &self,
        _inputs: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("executor"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let l = l.reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_first_element() {
        let l = Literal::scalar(7i32);
        assert_eq!(l.get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::Tuple(vec![
            Literal::scalar(1.0f32),
            Literal::scalar(2.0f32),
        ]);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
        // non-tuples decompose to a single leaf
        assert_eq!(Literal::scalar(1i32).to_tuple().unwrap().len(), 1);
    }

    #[test]
    fn device_paths_report_unavailable() {
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub-cpu");
        assert!(client.compile(&XlaComputation).is_err());
        let exe = PjRtLoadedExecutable;
        assert!(exe.execute::<Literal>(&[]).is_err());
    }
}
