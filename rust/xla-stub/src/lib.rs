//! Pure-Rust stub of the `xla` (PJRT) bindings used by `submarine`.
//!
//! The deployment image carries the real XLA/PJRT toolchain; this CI and
//! laptop build does not, and the offline registry cannot fetch native
//! bindings. The stub keeps the whole platform compiling and testable:
//!
//! - [`Literal`] is fully functional host-side tensor data (scalar/vec1/
//!   reshape/to_vec round-trips are bit-exact), so every code path that
//!   marshals batches and parameters works for real.
//! - Device entry points ([`PjRtClient::compile`],
//!   [`PjRtLoadedExecutable::execute`], [`HloModuleProto::from_text_file`])
//!   return [`Error`] `"xla backend unavailable"`. Callers already gate
//!   on compiled artifacts being present, so tests skip rather than fail.

use std::fmt;

/// Error type mirroring the real bindings' opaque error.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    fn unavailable(what: &str) -> Error {
        Error::new(format!(
            "xla backend unavailable ({what}): built against the in-tree \
             stub; install the PJRT plugin build to execute artifacts"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn scalar_literal(self) -> Literal;
    fn vec1_literal(data: &[Self]) -> Literal;
    fn read_literal(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn scalar_literal(self) -> Literal {
        Literal::F32 {
            data: vec![self],
            dims: Vec::new(),
        }
    }
    fn vec1_literal(data: &[f32]) -> Literal {
        Literal::F32 {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }
    fn read_literal(lit: &Literal) -> Result<Vec<f32>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            other => Err(Error::new(format!(
                "literal is not f32: {}",
                other.type_name()
            ))),
        }
    }
}

impl NativeType for i32 {
    fn scalar_literal(self) -> Literal {
        Literal::I32 {
            data: vec![self],
            dims: Vec::new(),
        }
    }
    fn vec1_literal(data: &[i32]) -> Literal {
        Literal::I32 {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }
    fn read_literal(lit: &Literal) -> Result<Vec<i32>> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            other => Err(Error::new(format!(
                "literal is not i32: {}",
                other.type_name()
            ))),
        }
    }
}

/// Host-side tensor value (the real crate's device-backed literal).
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    fn type_name(&self) -> &'static str {
        match self {
            Literal::F32 { .. } => "f32",
            Literal::I32 { .. } => "i32",
            Literal::Tuple(_) => "tuple",
        }
    }

    fn len(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
            Literal::Tuple(t) => t.len(),
        }
    }

    pub fn scalar<T: NativeType>(v: T) -> Literal {
        v.scalar_literal()
    }

    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::vec1_literal(data)
    }

    /// Reinterpret the flat data with new dimensions (element count must
    /// match, as in the real bindings).
    pub fn reshape(&self, new_dims: &[i64]) -> Result<Literal> {
        let n: i64 = new_dims.iter().product();
        if n < 0 || n as usize != self.len() {
            return Err(Error::new(format!(
                "reshape: {} elements into dims {new_dims:?}",
                self.len()
            )));
        }
        Ok(match self {
            Literal::F32 { data, .. } => Literal::F32 {
                data: data.clone(),
                dims: new_dims.to_vec(),
            },
            Literal::I32 { data, .. } => Literal::I32 {
                data: data.clone(),
                dims: new_dims.to_vec(),
            },
            Literal::Tuple(_) => {
                return Err(Error::new("cannot reshape a tuple literal"))
            }
        })
    }

    /// Read the flat element data back to the host.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read_literal(self)
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::read_literal(self)?
            .first()
            .copied()
            .ok_or_else(|| Error::new("empty literal"))
    }

    /// Decompose a tuple literal into its leaves.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            other => Ok(vec![other]),
        }
    }
}

/// Host-side batched affine transform: `out = W · X + b` with `W` a
/// `[n_out, n_in]` row-major literal, `b` a `[n_out]` literal and `X`
/// a *batch-minor* `[n_in, batch]` literal (`x[k*batch + r]` is
/// feature `k` of row `r`). Returns `[n_out, batch]`, also
/// batch-minor.
///
/// This is the one dense op the serving tier batches through: the
/// batch-minor layout keeps the inner accumulation loop contiguous so
/// a `batch`-wide call amortizes the weight traversal that dominates
/// `batch` separate matvecs. `batch == 1` degenerates to the plain
/// matvec. On the deployment image the same contraction lowers to a
/// real XLA dot; the stub computes it on the host.
pub fn affine_batched(
    w: &Literal,
    b: &Literal,
    x: &Literal,
    batch: usize,
) -> Result<Literal> {
    let (Literal::F32 { data: w, .. }, Literal::F32 { data: b, .. }) =
        (w, b)
    else {
        return Err(Error::new("affine_batched: w/b must be f32"));
    };
    let Literal::F32 { data: x, .. } = x else {
        return Err(Error::new("affine_batched: x must be f32"));
    };
    let n_out = b.len();
    if batch == 0 || n_out == 0 || w.len() % n_out != 0 {
        return Err(Error::new(format!(
            "affine_batched: |w|={} not divisible by |b|={n_out} \
             (or empty batch)",
            w.len()
        )));
    }
    let n_in = w.len() / n_out;
    if x.len() != n_in * batch {
        return Err(Error::new(format!(
            "affine_batched: |x|={} != n_in({n_in}) * batch({batch})",
            x.len()
        )));
    }
    let mut out = vec![0.0f32; n_out * batch];
    for i in 0..n_out {
        let row = &w[i * n_in..(i + 1) * n_in];
        let o = &mut out[i * batch..(i + 1) * batch];
        o.fill(b[i]);
        for (k, &wv) in row.iter().enumerate() {
            let xs = &x[k * batch..(k + 1) * batch];
            for (ov, &xv) in o.iter_mut().zip(xs) {
                *ov += wv * xv;
            }
        }
    }
    Ok(Literal::F32 {
        data: out,
        dims: vec![n_out as i64, batch as i64],
    })
}

/// Marker for types accepted by [`PjRtLoadedExecutable::execute`]
/// (owned or borrowed literals, like the real generic bound).
pub trait ExecuteInput {}
impl ExecuteInput for Literal {}
impl<'a> ExecuteInput for &'a Literal {}

/// Parsed HLO module handle. Parsing requires the native toolchain.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HLO text parser"))
    }
}

/// Computation handle wrapping an [`HloModuleProto`].
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by execution.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// PJRT client handle; construction succeeds so the service stack wires
/// up, and only artifact compilation/execution reports unavailability.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compiler"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: ExecuteInput>(
        &self,
        _inputs: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("executor"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let l = l.reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_first_element() {
        let l = Literal::scalar(7i32);
        assert_eq!(l.get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::Tuple(vec![
            Literal::scalar(1.0f32),
            Literal::scalar(2.0f32),
        ]);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
        // non-tuples decompose to a single leaf
        assert_eq!(Literal::scalar(1i32).to_tuple().unwrap().len(), 1);
    }

    #[test]
    fn affine_batched_matches_naive() {
        // 2x3 weights, batch of 4, hand-checkable values
        let w = Literal::vec1(&[1.0f32, 2.0, 3.0, -1.0, 0.5, 0.0])
            .reshape(&[2, 3])
            .unwrap();
        let b = Literal::vec1(&[0.1f32, -0.2]);
        let x_rows: [[f32; 3]; 4] = [
            [1.0, 0.0, 0.0],
            [0.0, 1.0, 0.0],
            [1.0, 1.0, 1.0],
            [2.0, -1.0, 0.5],
        ];
        // batch-minor: x[k*batch + r]
        let mut xt = vec![0.0f32; 3 * 4];
        for (r, row) in x_rows.iter().enumerate() {
            for (k, &v) in row.iter().enumerate() {
                xt[k * 4 + r] = v;
            }
        }
        let x = Literal::F32 {
            data: xt,
            dims: vec![3, 4],
        };
        let out = affine_batched(&w, &b, &x, 4).unwrap();
        let got = out.to_vec::<f32>().unwrap();
        for (r, row) in x_rows.iter().enumerate() {
            for i in 0..2 {
                let wrow = [[1.0f32, 2.0, 3.0], [-1.0, 0.5, 0.0]][i];
                let bias = [0.1f32, -0.2][i];
                let want: f32 = bias
                    + wrow.iter().zip(row).map(|(a, c)| a * c).sum::<f32>();
                assert!(
                    (got[i * 4 + r] - want).abs() < 1e-6,
                    "out[{i}][{r}] = {} want {want}",
                    got[i * 4 + r]
                );
            }
        }
        // batch == 1 degenerates to the plain matvec
        let x1 = Literal::vec1(&[1.0f32, 1.0, 1.0]);
        let o1 = affine_batched(&w, &b, &x1, 1).unwrap();
        let v1 = o1.to_vec::<f32>().unwrap();
        assert!((v1[0] - 6.1).abs() < 1e-6 && (v1[1] + 0.7).abs() < 1e-6);
    }

    #[test]
    fn affine_batched_shape_errors() {
        let w = Literal::vec1(&[1.0f32, 2.0]);
        let b = Literal::vec1(&[0.0f32]);
        let x = Literal::vec1(&[1.0f32, 2.0]);
        assert!(affine_batched(&w, &b, &x, 1).is_ok());
        // wrong x length for the batch
        assert!(affine_batched(&w, &b, &x, 3).is_err());
        // zero batch
        assert!(affine_batched(&w, &b, &x, 0).is_err());
        // non-f32 input
        let xi = Literal::vec1(&[1i32, 2]);
        assert!(affine_batched(&w, &b, &xi, 1).is_err());
    }

    #[test]
    fn device_paths_report_unavailable() {
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub-cpu");
        assert!(client.compile(&XlaComputation).is_err());
        let exe = PjRtLoadedExecutable;
        assert!(exe.execute::<Literal>(&[]).is_err());
    }
}
