//! Integration tests over the full service stack: REST server on a real
//! TCP port, SDK client, local PJRT runtime, template/environment/model
//! services — the paper's Fig. 1 composed end to end.

use std::collections::BTreeMap;
use std::sync::Arc;
use submarine::experiment::monitor::ExperimentMonitor;
use submarine::experiment::spec::{ExperimentSpec, ExperimentStatus};
use submarine::httpd::server::{Server, Services};
use submarine::orchestrator::local::LocalSubmitter;
use submarine::orchestrator::sim_submitter::SimSubmitter;
use submarine::orchestrator::Submitter;
use submarine::sdk::ExperimentClient;
use submarine::storage::{MetaStore, MetricStore};
use submarine::util::clock::SimTime;

fn artifacts() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts().join("manifest.json").exists()
}

/// Full local-runtime stack behind a TCP server.
fn local_stack() -> (Arc<Services>, Arc<LocalSubmitter>) {
    let store = Arc::new(MetaStore::in_memory());
    let monitor = Arc::new(ExperimentMonitor::new());
    let metrics = Arc::new(MetricStore::new());
    let submitter = Arc::new(LocalSubmitter::new(
        Arc::clone(&monitor),
        Arc::clone(&metrics),
        &artifacts(),
    ));
    let services = Arc::new(Services::with_parts(
        store,
        monitor,
        metrics,
        Arc::clone(&submitter) as Arc<dyn Submitter>,
    ));
    (services, submitter)
}

#[test]
fn rest_roundtrip_trains_real_model() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (services, submitter) = local_stack();
    let server = Arc::new(Server::bind(services, 0, None).unwrap());
    let port = server.port();
    let stop = server.stopper();
    let handle = Arc::clone(&server).serve_background();

    let client = ExperimentClient::new("127.0.0.1", port);
    let spec = ExperimentSpec::parse(
        r#"{
          "meta": {"name": "it-mnist"},
          "spec": {"Worker": {"replicas": 1, "resources": "cpu=1"}},
          "workload": {"model": "mnist_mlp", "steps": 20, "lr": 0.1}
        }"#,
    )
    .unwrap();
    let id = client.create_experiment(&spec).unwrap();
    let st = client
        .wait(&id, std::time::Duration::from_secs(600))
        .unwrap();
    assert_eq!(st, ExperimentStatus::Succeeded);

    let curve = client.metrics(&id, "loss").unwrap();
    assert_eq!(curve.len(), 20);
    assert!(curve.last().unwrap().1 < curve[0].1, "loss must drop");

    submitter.join_all();
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = std::net::TcpStream::connect(("127.0.0.1", port));
    handle.join().unwrap();
}

#[test]
fn zero_code_template_flow_over_rest() {
    if !have_artifacts() {
        return;
    }
    let (services, submitter) = local_stack();
    let server = Arc::new(Server::bind(services, 0, None).unwrap());
    let port = server.port();
    let stop = server.stopper();
    let handle = Arc::clone(&server).serve_background();

    let client = ExperimentClient::new("127.0.0.1", port);
    client
        .register_template(&submarine::template::tf_mnist_template())
        .unwrap();
    let mut params = BTreeMap::new();
    params.insert("learning_rate".into(), "0.1".into());
    params.insert("batch_size".into(), "128".into());
    let id = client
        .submit_template("tf-mnist-template", &params)
        .unwrap();
    let st = client
        .wait(&id, std::time::Duration::from_secs(600))
        .unwrap();
    assert_eq!(st, ExperimentStatus::Succeeded);

    submitter.join_all();
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = std::net::TcpStream::connect(("127.0.0.1", port));
    handle.join().unwrap();
}

#[test]
fn kill_interrupts_local_training() {
    if !have_artifacts() {
        return;
    }
    let (services, submitter) = local_stack();
    let spec = ExperimentSpec::parse(
        r#"{
          "meta": {"name": "long"},
          "spec": {"Worker": {"replicas": 1, "resources": "cpu=1"}},
          "workload": {"model": "deepfm", "steps": 100000, "lr": 0.1}
        }"#,
    )
    .unwrap();
    let id = services.experiments.submit(&spec).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(300));
    services.experiments.kill(&id).unwrap();
    submitter.join_all(); // must terminate promptly (kill-checked chunks)
    assert_eq!(
        services.experiments.status(&id),
        ExperimentStatus::Killed
    );
}

#[test]
fn sim_submitter_stack_runs_linkedin_shape() {
    // Fig. 4 with the YARN submitter against the cluster sim (no PJRT
    // needed): 20 gang experiments on a 10-node cluster.
    let store = Arc::new(MetaStore::in_memory());
    let monitor = Arc::new(ExperimentMonitor::new());
    let metrics = Arc::new(MetricStore::new());
    let sim = submarine::cluster::ClusterSim::homogeneous(
        10,
        submarine::cluster::Resources::new(32, 131_072, 4),
        2,
    );
    let submitter = Arc::new(
        SimSubmitter::new(
            Box::new(submarine::scheduler::yarn::YarnScheduler::new(
                submarine::scheduler::queue::QueueTree::flat(),
            )),
            sim,
            Arc::clone(&monitor),
        )
        .with_container_duration(SimTime::from_millis(500)),
    );
    let services = Arc::new(Services::with_parts(
        store,
        monitor,
        metrics,
        Arc::clone(&submitter) as Arc<dyn Submitter>,
    ));
    let spec = ExperimentSpec::parse(
        r#"{
          "meta": {"name": "bert"},
          "spec": {
            "Ps":     {"replicas": 1, "resources": "cpu=2,memory=2G"},
            "Worker": {"replicas": 4, "resources": "cpu=4,gpu=1,memory=4G"}
          }
        }"#,
    )
    .unwrap();
    let ids: Vec<String> = (0..20)
        .map(|_| services.experiments.submit(&spec).unwrap())
        .collect();
    submitter.drain(
        SimTime::from_millis(100),
        SimTime::from_secs_f64(600.0),
    );
    for id in &ids {
        assert_eq!(
            services.experiments.status(id),
            ExperimentStatus::Succeeded,
            "{id}"
        );
    }
    assert!(submitter.gpu_utilization() > 0.1);
}

#[test]
fn auth_token_guards_the_api() {
    let (services, _submitter) = local_stack();
    let server =
        Arc::new(Server::bind(services, 0, Some("sekrit")).unwrap());
    let port = server.port();
    let stop = server.stopper();
    let handle = Arc::clone(&server).serve_background();

    let anon = ExperimentClient::new("127.0.0.1", port);
    assert!(anon.list_experiments().is_err());
    let authed =
        ExperimentClient::new("127.0.0.1", port).with_token("sekrit");
    assert!(authed.list_experiments().is_ok());

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = std::net::TcpStream::connect(("127.0.0.1", port));
    handle.join().unwrap();
}

#[test]
fn experiment_metadata_survives_restart() {
    // WAL-backed store: metadata written by one stack instance is
    // visible after "restart" (a new Services over the same WAL).
    let dir = std::env::temp_dir()
        .join(format!("submarine-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let wal = dir.join("meta.jsonl");
    let _ = std::fs::remove_file(&wal);

    struct NullSubmitter;
    impl Submitter for NullSubmitter {
        fn name(&self) -> &'static str {
            "null"
        }
        fn submit(&self, _: &str, _: &ExperimentSpec)
            -> submarine::Result<()>
        {
            Ok(())
        }
        fn kill(&self, _: &str) -> submarine::Result<()> {
            Ok(())
        }
    }

    let id = {
        let services = Arc::new(Services::new(
            Arc::new(MetaStore::open(&wal).unwrap()),
            Arc::new(NullSubmitter),
        ));
        let spec = ExperimentSpec::parse(
            r#"{"meta":{"name":"durable"},
                "spec":{"W":{"replicas":1,"resources":"cpu=1"}}}"#,
        )
        .unwrap();
        services.experiments.submit(&spec).unwrap()
    };
    // restart
    let services = Arc::new(Services::new(
        Arc::new(MetaStore::open(&wal).unwrap()),
        Arc::new(NullSubmitter),
    ));
    let doc = services.experiments.get(&id).unwrap();
    assert_eq!(
        doc.at(&["spec", "meta", "name"]).unwrap().as_str(),
        Some("durable")
    );
    std::fs::remove_file(&wal).ok();
}
