//! End-to-end execution pipeline tests (paper Fig. 4, §5.1.5): an
//! experiment POSTed over real HTTP is gang-scheduled by the background
//! engine onto the cluster sim and reaches a terminal status with **no
//! test-side event injection** — the serving path the tentpole wires up.
//!
//! Covers: Accepted→Running→Succeeded transitions observed through the
//! `?status=` index filters, kill mid-run freeing cluster + queue share
//! with `Killed` surviving a storage restart (PR-2 recovery harness),
//! the events endpoint, unknown-queue fallback accounting, and the tune
//! endpoint running trials as real child experiments.

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use submarine::cluster::{ClusterSim, Resources};
use submarine::experiment::monitor::ExperimentMonitor;
use submarine::experiment::spec::{ExperimentSpec, ExperimentStatus};
use submarine::httpd::server::{Server, Services};
use submarine::httpd::ApiConfig;
use submarine::orchestrator::engine::EngineConfig;
use submarine::orchestrator::sim_submitter::SimSubmitter;
use submarine::orchestrator::Submitter;
use submarine::scheduler::queue::QueueTree;
use submarine::scheduler::yarn::YarnScheduler;
use submarine::sdk::ExperimentClient;
use submarine::storage::{MetaStore, MetricStore};
use submarine::util::clock::SimTime;
use submarine::util::json::Json;

struct TestServer {
    services: Arc<Services>,
    port: u16,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl TestServer {
    /// Full stack over the sim pipeline: 2 nodes x 4 GPUs, yarn
    /// scheduler with eng/sci queues, background engine at 1ms tick /
    /// 50ms sim step, containers running `container_ms` of sim time.
    fn start(store: Arc<MetaStore>, container_ms: u64) -> TestServer {
        let sim =
            ClusterSim::homogeneous(2, Resources::new(16, 65536, 4), 2);
        let mut queues = QueueTree::flat();
        queues.add("root", "eng", 0.6, 1.0).unwrap();
        queues.add("root", "sci", 0.4, 0.9).unwrap();
        let submitter = Arc::new(
            SimSubmitter::new(
                Box::new(YarnScheduler::new(queues)),
                sim,
                Arc::new(ExperimentMonitor::new()),
            )
            .with_container_duration(SimTime::from_millis(container_ms)),
        );
        let services = Arc::new(Services::with_sim_executor(
            store,
            submitter,
            Arc::new(MetricStore::new()),
            EngineConfig {
                tick: std::time::Duration::from_millis(1),
                sim_step: SimTime::from_millis(50),
            },
        ));
        let server = Arc::new(
            Server::bind_with_config(
                Arc::clone(&services),
                0,
                &ApiConfig::default(),
            )
            .unwrap(),
        );
        let port = server.port();
        let stop = server.stopper();
        let handle = Arc::clone(&server).serve_background();
        TestServer {
            services,
            port,
            stop,
            handle: Some(handle),
        }
    }

    fn client(&self) -> ExperimentClient {
        ExperimentClient::v2("127.0.0.1", self.port)
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(("127.0.0.1", self.port));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn spec(name: &str, queue: &str, replicas: u32) -> ExperimentSpec {
    ExperimentSpec::parse(&format!(
        r#"{{"meta":{{"name":"{name}"}},
            "queue":"{queue}",
            "spec":{{"Worker":{{"replicas":{replicas},
                                "resources":"cpu=1,gpu=1"}}}}}}"#
    ))
    .unwrap()
}

/// Poll the REST status until `want` (or panic after `secs`).
fn wait_for_status(
    client: &ExperimentClient,
    id: &str,
    want: ExperimentStatus,
    secs: u64,
) {
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(secs);
    loop {
        let st = client.status(id).unwrap();
        if st == want {
            return;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "experiment {id} stuck in {:?} waiting for {:?}",
            st,
            want
        );
        assert!(
            !st.is_terminal(),
            "experiment {id} terminal in {st:?}, wanted {want:?}"
        );
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
}

#[test]
fn posted_experiment_runs_to_succeeded_through_real_scheduler() {
    let srv =
        TestServer::start(Arc::new(MetaStore::in_memory()), 20_000);
    let client = srv.client();
    let id = client.create_experiment(&spec("e2e", "eng", 2)).unwrap();

    // the background loop places the gang: Accepted -> Running with no
    // manual pumping or event injection
    wait_for_status(&client, &id, ExperimentStatus::Running, 10);

    // the ?status= secondary-index filter observes the live transition
    let (rows, total) = client
        .list_experiments_paged(None, 0, Some("running"))
        .unwrap();
    assert_eq!(total, 1, "{rows:?}");
    assert_eq!(rows[0].0, id);

    // cluster status shows the containers on nodes and the queue charged
    let cs = client.cluster_status().unwrap();
    assert_eq!(cs.str_field("scheduler"), Some("yarn-capacity"));
    assert_eq!(cs.num_field("running_containers"), Some(2.0));
    let queues = cs.get("queues").unwrap().as_arr().unwrap();
    let eng = queues
        .iter()
        .find(|q| q.str_field("name") == Some("root.eng"))
        .expect("eng queue in status");
    assert!(eng.num_field("used_share").unwrap() > 0.0);

    // simulated time advances the containers to completion
    wait_for_status(&client, &id, ExperimentStatus::Succeeded, 30);
    let (rows, total) = client
        .list_experiments_paged(None, 0, Some("succeeded"))
        .unwrap();
    assert_eq!(total, 1);
    assert_eq!(rows[0].0, id);

    // full event log flowed through the monitor
    let events = client.events(&id).unwrap();
    let types: Vec<&str> = events
        .iter()
        .filter_map(|e| e.at(&["event", "type"]).and_then(Json::as_str))
        .collect();
    assert!(types.contains(&"Accepted"), "{types:?}");
    assert_eq!(
        types.iter().filter(|t| **t == "ContainerStarted").count(),
        2
    );
    assert_eq!(
        types.iter().filter(|t| **t == "ContainerFinished").count(),
        2
    );

    // all shares released once the job finished
    let cs = client.cluster_status().unwrap();
    assert_eq!(cs.num_field("running_containers"), Some(0.0));
}

/// No-op submitter for the restart half (nothing should be running).
struct NullSubmitter;
impl Submitter for NullSubmitter {
    fn name(&self) -> &'static str {
        "null"
    }
    fn submit(&self, _: &str, _: &ExperimentSpec) -> submarine::Result<()> {
        Ok(())
    }
    fn kill(&self, _: &str) -> submarine::Result<()> {
        Ok(())
    }
}

#[test]
fn kill_mid_run_frees_cluster_and_survives_storage_restart() {
    let dir = std::env::temp_dir().join(format!(
        "submarine-execution-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let id;
    {
        // containers "run" 10 simulated minutes: the job cannot finish
        // before the kill
        let store = Arc::new(MetaStore::open(&dir).unwrap());
        let srv = TestServer::start(store, 600_000);
        let client = srv.client();
        id = client.create_experiment(&spec("doomed", "eng", 2)).unwrap();
        wait_for_status(&client, &id, ExperimentStatus::Running, 10);
        client.kill(&id).unwrap();
        assert_eq!(
            client.status(&id).unwrap(),
            ExperimentStatus::Killed
        );
        // kill freed the sim containers and the queue share
        let cs = client.cluster_status().unwrap();
        assert_eq!(cs.num_field("running_containers"), Some(0.0));
        let queues = cs.get("queues").unwrap().as_arr().unwrap();
        let root = queues
            .iter()
            .find(|q| q.str_field("name") == Some("root"))
            .unwrap();
        assert!(
            root.num_field("used_share").unwrap() < 1e-6,
            "share not released: {root:?}"
        );
    } // server + engine stop; store closes

    // restart: recover the same data dir with a cold monitor — the
    // persisted status (and its index) must still say Killed
    let store = Arc::new(MetaStore::open(&dir).unwrap());
    let services = Arc::new(Services::with_parts(
        store,
        Arc::new(ExperimentMonitor::new()),
        Arc::new(MetricStore::new()),
        Arc::new(NullSubmitter),
    ));
    assert_eq!(
        services.experiments.status(&id),
        ExperimentStatus::Killed
    );
    let (rows, total) =
        services.experiments.list_page(Some("killed"), 0, None);
    assert_eq!(total, 1);
    assert_eq!(rows[0].0, id);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unknown_queue_falls_back_and_is_counted() {
    let srv =
        TestServer::start(Arc::new(MetaStore::in_memory()), 500);
    let client = srv.client();
    let id = client
        .create_experiment(&spec("stray", "no-such-queue", 1))
        .unwrap();
    // lands in the default queue and still completes
    wait_for_status(&client, &id, ExperimentStatus::Succeeded, 30);
    let cs = client.cluster_status().unwrap();
    assert_eq!(cs.num_field("unknown_queue_count"), Some(1.0));
}

#[test]
fn tune_runs_trials_as_child_experiments_through_pipeline() {
    let srv =
        TestServer::start(Arc::new(MetaStore::in_memory()), 500);
    let client = srv.client();
    client
        .register_template(&submarine::template::tf_mnist_template())
        .unwrap();
    let req = Json::parse(
        r#"{"template":"tf-mnist-template",
            "strategy":"random_search",
            "trials":3, "budget":8, "seed":7,
            "trial_timeout_ms":20000,
            "space":{"learning_rate":{"log_uniform":[0.0001,1.0]}}}"#,
    )
    .unwrap();
    let out = client.tune(&req).unwrap();
    let trials = out.get("trials").unwrap().as_arr().unwrap();
    assert_eq!(trials.len(), 3);
    for t in trials {
        assert_eq!(t.str_field("status"), Some("Succeeded"), "{t:?}");
        assert!(!t
            .str_field("experimentId")
            .unwrap_or("")
            .is_empty());
    }
    let best_id = out
        .at(&["best", "experimentId"])
        .and_then(Json::as_str)
        .unwrap()
        .to_string();
    assert!(!best_id.is_empty());
    // every trial is a real, listed, terminal experiment
    let (_, total) = client
        .list_experiments_paged(None, 0, Some("succeeded"))
        .unwrap();
    assert_eq!(total, 3);
    // and the tuned objective was logged as a metric on the best child
    let obj = client.metrics(&best_id, "objective").unwrap();
    assert_eq!(obj.len(), 1);
    // deterministic for the seed: a rerun returns the same best params
    let out2 = client.tune(&req).unwrap();
    assert_eq!(
        out.at(&["best", "params"]).map(|p| p.dump()),
        out2.at(&["best", "params"]).map(|p| p.dump()),
    );
}
