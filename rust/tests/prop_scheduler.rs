//! Property tests over scheduler/cluster invariants (DESIGN.md S3).
//!
//! Random job mixes against random cluster shapes; invariants:
//!  - no node is ever oversubscribed in any resource dimension
//!  - no GPU is double-bound
//!  - gang jobs are placed all-or-nothing (YARN)
//!  - queue burst ceilings are never exceeded (YARN)
//!  - placements stamp monotonically non-decreasing decision times
//!  - releasing everything restores full capacity

use submarine::cluster::{ClusterSim, Resources};
use submarine::scheduler::k8s::K8sScheduler;
use submarine::scheduler::queue::QueueTree;
use submarine::scheduler::yarn::YarnScheduler;
use submarine::scheduler::{JobRequest, Scheduler, TaskGroup};
use submarine::util::clock::SimTime;
use submarine::util::prop::{check, Gen, PropResult};
use submarine::{prop_assert, prop_assert_eq};

fn gen_cluster(g: &mut Gen) -> ClusterSim {
    let nodes = g.usize(1, 8);
    let gpus = g.usize(0, 9) as u32;
    let sockets = g.usize(1, 3) as u32;
    ClusterSim::homogeneous(
        nodes,
        Resources::new(
            g.usize(4, 64) as u32,
            g.usize(4096, 262_144) as u64,
            gpus,
        ),
        sockets,
    )
}

fn gen_jobs(g: &mut Gen, max_gpu: u32) -> Vec<JobRequest> {
    let jobs = g.vec(1..20, |g| {
        let tasks = g.vec(1..4, |g| TaskGroup {
            name: format!("t{}", g.usize(0, 1000)),
            replicas: g.usize(1, 5) as u32,
            resources: Resources::new(
                g.usize(1, 8) as u32,
                g.usize(128, 8192) as u64,
                g.usize(0, (max_gpu + 1) as usize) as u32,
            ),
            duration: SimTime::from_millis(g.u64(1, 500)),
        });
        (g.bool(), tasks)
    });
    jobs.into_iter()
        .enumerate()
        .map(|(i, (gang, tasks))| JobRequest {
            id: format!("job-{i}"),
            queue: "root".into(),
            gang,
            tasks,
        })
        .collect()
}

fn no_oversubscription(sim: &ClusterSim) -> PropResult {
    for node in &sim.nodes {
        prop_assert!(
            node.capacity.fits(&node.allocated),
            "node {} oversubscribed: cap={} alloc={}",
            node.id,
            node.capacity,
            node.allocated
        );
        // GPU bindings consistent with the resource ledger
        let bound = node
            .gpus
            .iter()
            .filter(|s| s.bound_to.is_some())
            .count() as u32;
        prop_assert_eq!(bound, node.allocated.gpus);
    }
    Ok(())
}

#[test]
fn yarn_never_oversubscribes_and_gangs_are_atomic() {
    check(60, |g| {
        let mut sim = gen_cluster(g);
        let max_gpu = sim.nodes[0].capacity.gpus;
        let mut sched = YarnScheduler::new(QueueTree::flat());
        let jobs = gen_jobs(g, max_gpu);
        let totals: std::collections::BTreeMap<String, u32> = jobs
            .iter()
            .map(|j| (j.id.clone(), j.total_containers()))
            .collect();
        for j in jobs {
            sched.submit(j);
        }
        let mut placed_per_job: std::collections::BTreeMap<String, u32> =
            Default::default();
        let mut last = SimTime::ZERO;
        for _round in 0..10 {
            let ps = sched.schedule(&mut sim);
            for p in &ps {
                *placed_per_job.entry(p.job.clone()).or_default() += 1;
                prop_assert!(
                    p.decided_at >= last,
                    "decision time went backwards"
                );
                last = p.decided_at;
            }
            no_oversubscription(&sim)?;
            // gang atomicity: every job is fully placed or not at all
            for (job, placed) in &placed_per_job {
                prop_assert_eq!(*placed, totals[job]);
            }
            if let Some(t) = sim.next_event() {
                sim.advance_to(t);
            }
        }
        Ok(())
    });
}

#[test]
fn k8s_never_oversubscribes() {
    check(60, |g| {
        let mut sim = gen_cluster(g);
        let max_gpu = sim.nodes[0].capacity.gpus;
        let mut sched = K8sScheduler::new();
        for j in gen_jobs(g, max_gpu) {
            sched.submit(j);
        }
        for _ in 0..10 {
            sched.schedule(&mut sim);
            no_oversubscription(&sim)?;
            if let Some(t) = sim.next_event() {
                sim.advance_to(t);
            }
        }
        Ok(())
    });
}

#[test]
fn completion_restores_full_capacity() {
    check(40, |g| {
        let mut sim = gen_cluster(g);
        let max_gpu = sim.nodes[0].capacity.gpus;
        let mut sched: Box<dyn Scheduler> = if g.bool() {
            Box::new(YarnScheduler::new(QueueTree::flat()))
        } else {
            Box::new(K8sScheduler::new())
        };
        for j in gen_jobs(g, max_gpu) {
            sched.submit(j);
        }
        for _ in 0..50 {
            sched.schedule(&mut sim);
            match sim.next_event() {
                Some(t) => {
                    sim.advance_to(t);
                }
                None => break,
            }
        }
        // drain whatever is still running
        while let Some(t) = sim.next_event() {
            sim.advance_to(t);
        }
        prop_assert_eq!(sim.total_allocated(), Resources::ZERO);
        for node in &sim.nodes {
            prop_assert_eq!(
                node.free_gpu_indices().len(),
                node.capacity.gpus as usize
            );
        }
        Ok(())
    });
}

#[test]
fn queue_ceilings_never_exceeded() {
    check(40, |g| {
        let mut queues = QueueTree::flat();
        let ceiling = 0.2 + g.f64() * 0.5;
        queues.add("root", "capped", ceiling, ceiling).unwrap();
        let mut sched = YarnScheduler::new(queues);
        let mut sim = ClusterSim::homogeneous(
            4,
            Resources::new(32, 65_536, 8),
            2,
        );
        let mut jobs = gen_jobs(g, 4);
        for j in &mut jobs {
            j.queue = "root.capped".into();
        }
        for j in jobs {
            sched.submit(j);
        }
        sched.schedule(&mut sim);
        let q = sched.queues.get("root.capped").unwrap();
        prop_assert!(
            q.used_share <= q.max_capacity + 1e-6,
            "queue share {} exceeds ceiling {}",
            q.used_share,
            q.max_capacity
        );
        Ok(())
    });
}

#[test]
fn failure_injection_releases_resources() {
    check(30, |g| {
        let mut sim = gen_cluster(g);
        let max_gpu = sim.nodes[0].capacity.gpus;
        let mut sched = YarnScheduler::new(QueueTree::flat());
        for j in gen_jobs(g, max_gpu) {
            sched.submit(j);
        }
        let ps = sched.schedule(&mut sim);
        // kill a random subset of running containers
        for p in &ps {
            if g.chance(0.5) {
                sim.fail(&p.container).map_err(|e| {
                    submarine::util::prop::PropFail(e.to_string())
                })?;
            }
        }
        no_oversubscription(&sim)?;
        // completing the rest must still work
        while let Some(t) = sim.next_event() {
            sim.advance_to(t);
        }
        prop_assert_eq!(sim.total_allocated(), Resources::ZERO);
        Ok(())
    });
}
