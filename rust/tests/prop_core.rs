//! Property tests over the platform's serialization and service
//! substrates: JSON round-trips, template substitution, resource algebra,
//! metadata-store semantics, model-registry blobs.

use std::collections::BTreeMap;
use submarine::cluster::Resources;
use submarine::model::ModelRegistry;
use submarine::storage::MetaStore;
use submarine::util::json::Json;
use submarine::util::prop::{check, Gen, PropResult};
use submarine::{prop_assert, prop_assert_eq};

fn gen_json(g: &mut Gen, depth: usize) -> Json {
    if depth == 0 {
        return match g.usize(0, 4) {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.u64(0, 1_000_000) as f64) / 8.0),
            _ => Json::Str(g.string(24)),
        };
    }
    match g.usize(0, 6) {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => Json::Num(g.u64(0, 1_000_000) as f64),
        3 => Json::Str(g.string(24)),
        4 => Json::Arr(g.vec(0..5, |g| gen_json(g, depth - 1))),
        _ => {
            let n = g.usize(0, 5);
            let mut fields = Vec::new();
            for i in 0..n {
                fields.push((
                    format!("k{i}_{}", g.string(6)),
                    gen_json(g, depth - 1),
                ));
            }
            Json::Obj(fields)
        }
    }
}

#[test]
fn json_dump_parse_roundtrip() {
    check(300, |g| {
        let j = gen_json(g, 3);
        let parsed = Json::parse(&j.dump()).map_err(|e| {
            submarine::util::prop::PropFail(format!("{e} on {}", j.dump()))
        })?;
        prop_assert_eq!(parsed, j);
        // pretty form parses back to the same value too
        let pretty = Json::parse(&j.pretty()).map_err(|e| {
            submarine::util::prop::PropFail(e.to_string())
        })?;
        prop_assert_eq!(pretty, j);
        Ok(())
    });
}

#[test]
fn resource_algebra_invariants() {
    check(300, |g| {
        let a = Resources::new(
            g.usize(0, 128) as u32,
            g.usize(0, 1 << 20) as u64,
            g.usize(0, 16) as u32,
        );
        let b = Resources::new(
            g.usize(0, 128) as u32,
            g.usize(0, 1 << 20) as u64,
            g.usize(0, 16) as u32,
        );
        // add then sub restores
        let sum = a.add(&b);
        prop_assert_eq!(sum.checked_sub(&b), Some(a));
        // fits is consistent with checked_sub
        prop_assert_eq!(sum.fits(&a), sum.checked_sub(&a).is_some());
        // display round-trips through parse
        let rt = Resources::parse(&a.to_string()).map_err(|e| {
            submarine::util::prop::PropFail(e.to_string())
        })?;
        prop_assert_eq!(rt, a);
        // dominant share within [0,1] for sub-capacity requests
        if !sum.is_zero() {
            let ds = a.dominant_share(&sum);
            prop_assert!((0.0..=1.0).contains(&ds), "ds={ds}");
        }
        Ok(())
    });
}

#[test]
fn template_substitution_is_total_and_idempotent() {
    check(150, |g| {
        let n_params = g.usize(1, 5);
        let params: Vec<(String, String)> = (0..n_params)
            .map(|i| {
                (format!("p{i}"), format!("v{}", g.u64(0, 1000)))
            })
            .collect();
        // build a template whose cmd references every param
        let mut cmd = String::from("run");
        for (k, _) in &params {
            cmd.push_str(&format!(" --{k}={{{{{k}}}}}"));
        }
        let param_json: Vec<Json> = params
            .iter()
            .map(|(k, _)| {
                Json::obj()
                    .set("name", Json::Str(k.clone()))
                    .set("required", Json::Bool(true))
            })
            .collect();
        let tpl_json = Json::obj()
            .set("name", Json::Str("t".into()))
            .set("parameters", Json::Arr(param_json))
            .set(
                "experimentSpec",
                Json::obj()
                    .set(
                        "meta",
                        Json::obj()
                            .set("name", Json::Str("exp".into()))
                            .set("cmd", Json::Str(cmd)),
                    )
                    .set(
                        "spec",
                        Json::obj().set(
                            "Worker",
                            Json::obj()
                                .set("replicas", Json::Num(1.0))
                                .set(
                                    "resources",
                                    Json::Str("cpu=1".into()),
                                ),
                        ),
                    ),
            );
        let tpl = submarine::template::Template::from_json(&tpl_json)
            .map_err(|e| {
                submarine::util::prop::PropFail(e.to_string())
            })?;
        let values: BTreeMap<String, String> =
            params.iter().cloned().collect();
        let spec = tpl.instantiate(&values).map_err(|e| {
            submarine::util::prop::PropFail(e.to_string())
        })?;
        // total: no placeholder survives
        prop_assert!(
            !spec.meta.cmd.contains("{{"),
            "unsubstituted: {}",
            spec.meta.cmd
        );
        // every value appears
        for (_, v) in &params {
            prop_assert!(spec.meta.cmd.contains(v), "missing {v}");
        }
        // idempotent
        let again = tpl.instantiate(&values).map_err(|e| {
            submarine::util::prop::PropFail(e.to_string())
        })?;
        prop_assert_eq!(spec, again);
        Ok(())
    });
}

#[test]
fn metastore_behaves_like_a_map() {
    check(100, |g| {
        let store = MetaStore::in_memory();
        let mut model: BTreeMap<String, Json> = BTreeMap::new();
        for _ in 0..g.usize(1, 40) {
            let key = format!("k{}", g.usize(0, 10));
            if g.chance(0.3) {
                store.delete("ns", &key).map_err(|e| {
                    submarine::util::prop::PropFail(e.to_string())
                })?;
                model.remove(&key);
            } else {
                let doc = gen_json(g, 2);
                store.put("ns", &key, doc.clone()).map_err(|e| {
                    submarine::util::prop::PropFail(e.to_string())
                })?;
                model.insert(key, doc);
            }
        }
        prop_assert_eq!(store.count("ns"), model.len());
        for (k, v) in &model {
            let got = store.get("ns", k).map(|d| d.json().clone());
            prop_assert_eq!(got.as_ref(), Some(v));
        }
        Ok(())
    });
}

#[test]
fn model_registry_blobs_roundtrip() {
    check(60, |g| {
        let reg = ModelRegistry::new(std::sync::Arc::new(
            MetaStore::in_memory(),
        ));
        let params: Vec<Vec<f32>> = g.vec(1..4, |g| {
            g.vec(1..64, |g| {
                // exercise odd float values, incl. negatives/zeros
                (g.u64(0, 1 << 20) as f32 - 500_000.0) / 1024.0
            })
        });
        let v = reg
            .register("m", "exp", &params, &[])
            .map_err(|e| {
                submarine::util::prop::PropFail(e.to_string())
            })?;
        let loaded = reg.load_params("m", v).map_err(|e| {
            submarine::util::prop::PropFail(e.to_string())
        })?;
        prop_assert_eq!(loaded, params);
        Ok(())
    });
}

#[test]
fn dependency_resolution_is_sound() {
    use submarine::environment::resolver::{
        Constraint, DependencySolver, PackageIndex,
    };
    check(80, |g| {
        let idx = PackageIndex::builtin();
        let pool = ["python", "numpy", "tensorflow", "pytorch", "mxnet",
                    "scipy"];
        let specs: Vec<String> = g.vec(1..4, |g| {
            let pkg = *g.choose(&pool);
            match g.usize(0, 3) {
                0 => pkg.to_string(),
                1 => format!("{pkg}>=1.0"),
                _ => format!("{pkg}<99"),
            }
        });
        let solver = DependencySolver::new(&idx);
        if let Ok(assignment) = solver.resolve(&specs) {
            // soundness: every user constraint admits its assignment
            for s in &specs {
                let c = Constraint::parse(s).unwrap();
                let v = assignment.get(&c.package).ok_or_else(|| {
                    submarine::util::prop::PropFail(format!(
                        "{} unassigned",
                        c.package
                    ))
                })?;
                prop_assert!(c.admits(*v), "{s} violated by {v}");
            }
            // transitive deps present and admitted
            for (pkg, v) in &assignment {
                for d in idx.deps(pkg, *v) {
                    let c = Constraint::parse(d).unwrap();
                    let dv =
                        assignment.get(&c.package).ok_or_else(|| {
                            submarine::util::prop::PropFail(format!(
                                "dep {} of {pkg} unassigned",
                                c.package
                            ))
                        })?;
                    prop_assert!(c.admits(*dv), "{pkg}: {d} violated");
                }
            }
        }
        Ok(())
    });
}
