//! Integration tests for `submarine-lint` (ISSUE 6 satellites c + d).
//!
//! Fixture snippets with a known lock inversion, a hot-path clone, and
//! a fresh unwrap must flag; clean fixtures must pass. The runtime
//! tracker's deterministic-interleaving regression runs in a subprocess
//! (the inversion panics, and a panic must not take the test harness
//! down with it).

use std::collections::BTreeMap;
use submarine::analysis::scanner::scan;
use submarine::analysis::{baseline, rules, run_all};

// ------------------------------------------------ static-rule fixtures

/// Canonical inversion: feed mutex held while a shard lock is taken.
#[test]
fn fixture_lock_inversion_flags() {
    let bad = "impl Store {\n\
               \x20   fn publish(&self) {\n\
               \x20       let feed = self.feed.lock().unwrap();\n\
               \x20       let shard = self.shards[3].write().unwrap();\n\
               \x20       shard.touch(feed.rev);\n\
               \x20   }\n\
               }\n";
    let findings = rules::lock_order("storage/kv.rs", &scan(bad));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "lock-order");
    assert_eq!(findings[0].line, 4);
    assert!(findings[0].message.contains("Shard"));
    assert!(findings[0].message.contains("Feed"));
}

/// Same locks, canonical order: clean.
#[test]
fn fixture_lock_order_clean_passes() {
    let good = "impl Store {\n\
                \x20   fn publish(&self) {\n\
                \x20       let shard = self.shards[3].write().unwrap();\n\
                \x20       let feed = self.feed.lock().unwrap();\n\
                \x20       shard.touch(feed.rev);\n\
                \x20   }\n\
                }\n";
    let findings = rules::lock_order("storage/kv.rs", &scan(good));
    assert!(findings.is_empty(), "{findings:?}");
}

/// Helper-call acquisitions (`self.feed_lock()`, `self.shard_read()`)
/// are tracked just like direct `.lock()` calls.
#[test]
fn fixture_helper_call_inversion_flags() {
    let bad = "impl Store {\n\
               \x20   fn scan(&self, ns: &str) {\n\
               \x20       let feed = self.feed_lock();\n\
               \x20       let (shard, _held) = self.shard_read(ns);\n\
               \x20   }\n\
               }\n";
    let findings = rules::lock_order("storage/kv.rs", &scan(bad));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].message.contains("Shard"));
}

/// WAL/socket writes under the feed mutex are prohibited.
#[test]
fn fixture_io_under_feed_flags() {
    let bad = "impl Store {\n\
               \x20   fn rotate(&self) {\n\
               \x20       let feed = self.feed.lock().unwrap();\n\
               \x20       self.file.write_all(feed.bytes()).unwrap();\n\
               \x20   }\n\
               }\n";
    let findings = rules::lock_order("storage/kv.rs", &scan(bad));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].message.contains("file/socket write"));
}

/// A registered hot function introducing `.clone()` flags; the same
/// token under `lint: allow(hot)` or in an unregistered function does
/// not.
#[test]
fn fixture_hot_path_clone_flags() {
    let bad = "impl Kv {\n\
               \x20   pub fn get(&self) -> Doc {\n\
               \x20       self.doc.clone()\n\
               \x20   }\n\
               \x20   pub fn cold(&self) -> Doc {\n\
               \x20       self.doc.clone()\n\
               \x20   }\n\
               }\n";
    let findings = rules::hot_path("storage/kv.rs", &scan(bad));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "hot-path");
    assert_eq!(findings[0].line, 3);

    let allowed = "impl Kv {\n\
                   \x20   pub fn get(&self) -> Doc {\n\
                   \x20       self.doc.clone() // lint: allow(hot)\n\
                   \x20   }\n\
                   }\n";
    assert!(rules::hot_path("storage/kv.rs", &scan(allowed)).is_empty());
}

/// Zero-copy hot function: clean.
#[test]
fn fixture_hot_path_clean_passes() {
    let good = "impl Kv {\n\
                \x20   pub fn get(&self) -> Arc<Doc> {\n\
                \x20       Arc::clone(&self.doc)\n\
                \x20   }\n\
                }\n";
    assert!(rules::hot_path("storage/kv.rs", &scan(good)).is_empty());
}

/// A fresh `.unwrap()` in a request path is counted, and the ratchet
/// rejects any count above the grandfathered baseline.
#[test]
fn fixture_fresh_unwrap_fails_ratchet() {
    let src = "fn handle(&self) {\n\
               \x20   let doc = body.parse().unwrap();\n\
               }\n";
    let sites = rules::unwrap_sites("httpd/handler.rs", &scan(src));
    assert_eq!(sites, vec![2]);

    let mut current = BTreeMap::new();
    current.insert("httpd/handler.rs".to_string(), sites.len() as u64);
    let rep = baseline::ratchet(&current, &BTreeMap::new());
    assert_eq!(rep.errors.len(), 1, "fresh unwrap must block");
    assert_eq!(rep.errors[0].rule, "unwrap-ratchet");
}

/// Test code and reviewed `lint: allow(unwrap)` sites are exempt.
#[test]
fn fixture_unwrap_exemptions_pass() {
    let src = "fn handle(&self) {\n\
               \x20   let doc = body.parse().unwrap(); \
               // lint: allow(unwrap)\n\
               }\n\
               #[cfg(test)]\n\
               mod tests {\n\
               \x20   fn t() {\n\
               \x20       x.unwrap();\n\
               \x20   }\n\
               }\n";
    assert!(rules::unwrap_sites("httpd/handler.rs", &scan(src)).is_empty());
}

/// The ratchet only turns one way: equal counts pass, decreases warn
/// (stale baseline), increases fail.
#[test]
fn ratchet_is_one_way() {
    let mut base = BTreeMap::new();
    base.insert("httpd/server.rs".to_string(), 2u64);

    let rep = baseline::ratchet(&base, &base);
    assert!(rep.errors.is_empty() && rep.warnings.is_empty());

    let mut fewer = base.clone();
    fewer.insert("httpd/server.rs".to_string(), 1);
    let rep = baseline::ratchet(&fewer, &base);
    assert!(rep.errors.is_empty());
    assert_eq!(rep.warnings.len(), 1);

    let mut more = base.clone();
    more.insert("httpd/server.rs".to_string(), 3);
    assert_eq!(baseline::ratchet(&more, &base).errors.len(), 1);
}

/// The same invariant CI enforces: the lint is clean over its own tree.
#[test]
fn lint_passes_over_own_tree() {
    let crate_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = run_all(crate_dir).expect("lint run");
    assert!(
        report.ok(),
        "blocking findings:\n{}",
        report
            .findings
            .iter()
            .map(|f| f.render())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

// -------------------------------- runtime tracker (subprocess, debug)

/// Child half of the deterministic-interleaving regression. A no-op
/// pass unless the parent sets `SUBMARINE_TRACKER_CHILD=1`; then it
/// stages the classic two-thread deadlock — thread A takes a shard
/// lock then the feed mutex (canonical), thread B takes the feed mutex
/// then a shard lock (inverted) — with a barrier guaranteeing both
/// first acquisitions happen before either second one. Without the
/// tracker this interleaving deadlocks; with it, thread B panics
/// before blocking, and the child exits 42 to prove it.
#[test]
fn tracker_child_inverted_interleaving() {
    if std::env::var("SUBMARINE_TRACKER_CHILD").is_err() {
        return;
    }
    use std::sync::{Arc, Barrier, Mutex, RwLock};
    use submarine::analysis::lock_order::LockRank;
    use submarine::analysis::tracker;

    let shard = Arc::new(RwLock::new(0u64));
    let feed = Arc::new(Mutex::new(0u64));
    let gate = Arc::new(Barrier::new(2));

    let a = {
        let (shard, feed, gate) =
            (Arc::clone(&shard), Arc::clone(&feed), Arc::clone(&gate));
        std::thread::spawn(move || {
            let _hs = tracker::acquired(LockRank::Shard, 0);
            let _s = shard.read().unwrap();
            gate.wait();
            // Blocks until thread B's panic releases the feed mutex —
            // the deadlock half that the tracker must break.
            let _hf = tracker::acquired(LockRank::Feed, 0);
            let _f = feed.lock().unwrap_or_else(|e| e.into_inner());
        })
    };
    let b = {
        let (shard, feed, gate) =
            (Arc::clone(&shard), Arc::clone(&feed), Arc::clone(&gate));
        std::thread::spawn(move || -> Option<String> {
            let _hf = tracker::acquired(LockRank::Feed, 0);
            let _f = feed.lock().unwrap();
            gate.wait();
            let caught = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| {
                    let _hs = tracker::acquired(LockRank::Shard, 0);
                    let _s = shard.read().unwrap();
                }),
            );
            match caught {
                Ok(()) => None,
                Err(p) => p
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| {
                        p.downcast_ref::<&str>()
                            .map(|s| s.to_string())
                    }),
            }
        })
    };
    let msg = b.join().expect("thread B must not die outside the trap");
    a.join().expect("thread A must complete once B releases feed");
    match msg {
        Some(m) if m.contains("lock-order violation") => {
            std::process::exit(42)
        }
        other => {
            eprintln!("expected tracker panic, got {other:?}");
            std::process::exit(1)
        }
    }
}

/// Parent half: re-run this binary filtered to the child test with the
/// env guard set, and require the tracker-panic exit code. Debug
/// builds only — in release the tracker compiles to a no-op and the
/// staged interleaving would genuinely deadlock.
#[cfg(debug_assertions)]
#[test]
fn tracker_panics_on_inverted_interleaving() {
    let exe = std::env::current_exe().expect("current_exe");
    let out = std::process::Command::new(exe)
        .args([
            "tracker_child_inverted_interleaving",
            "--exact",
            "--test-threads=1",
            "--nocapture",
        ])
        .env("SUBMARINE_TRACKER_CHILD", "1")
        .output()
        .expect("spawn child test process");
    assert_eq!(
        out.status.code(),
        Some(42),
        "child must exit via the tracker panic path\nstdout:\n{}\n\
         stderr:\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
}
