//! Integration tests for `submarine-lint` (ISSUE 6 satellites c + d,
//! ISSUE 8 satellite c).
//!
//! Fixture snippets with a known lock inversion, a hot-path clone, a
//! fresh unwrap, an unchecked FFI return, a missing EINTR loop, an fd
//! leak, an unregistered atomic, a Relaxed publish-flag, an undeclared
//! conn-state transition, and a wildcard state match must all flag;
//! clean and allow-marked fixtures must pass. The runtime tracker's
//! deterministic-interleaving regression runs in a subprocess (the
//! inversion panics, and a panic must not take the test harness down
//! with it).

use std::collections::BTreeMap;
use submarine::analysis::scanner::{scan, Scan};
use submarine::analysis::{
    atomics, baseline, conn_contract, ffi_contracts, rules, run_all,
};

// ------------------------------------------------ static-rule fixtures

/// Canonical inversion: feed mutex held while a shard lock is taken.
#[test]
fn fixture_lock_inversion_flags() {
    let bad = "impl Store {\n\
               \x20   fn publish(&self) {\n\
               \x20       let feed = self.feed.lock().unwrap();\n\
               \x20       let shard = self.shards[3].write().unwrap();\n\
               \x20       shard.touch(feed.rev);\n\
               \x20   }\n\
               }\n";
    let findings = rules::lock_order("storage/kv.rs", &scan(bad));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "lock-order");
    assert_eq!(findings[0].line, 4);
    assert!(findings[0].message.contains("Shard"));
    assert!(findings[0].message.contains("Feed"));
}

/// Same locks, canonical order: clean.
#[test]
fn fixture_lock_order_clean_passes() {
    let good = "impl Store {\n\
                \x20   fn publish(&self) {\n\
                \x20       let shard = self.shards[3].write().unwrap();\n\
                \x20       let feed = self.feed.lock().unwrap();\n\
                \x20       shard.touch(feed.rev);\n\
                \x20   }\n\
                }\n";
    let findings = rules::lock_order("storage/kv.rs", &scan(good));
    assert!(findings.is_empty(), "{findings:?}");
}

/// Helper-call acquisitions (`self.feed_lock()`, `self.shard_read()`)
/// are tracked just like direct `.lock()` calls.
#[test]
fn fixture_helper_call_inversion_flags() {
    let bad = "impl Store {\n\
               \x20   fn scan(&self, ns: &str) {\n\
               \x20       let feed = self.feed_lock();\n\
               \x20       let (shard, _held) = self.shard_read(ns);\n\
               \x20   }\n\
               }\n";
    let findings = rules::lock_order("storage/kv.rs", &scan(bad));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].message.contains("Shard"));
}

/// WAL/socket writes under the feed mutex are prohibited.
#[test]
fn fixture_io_under_feed_flags() {
    let bad = "impl Store {\n\
               \x20   fn rotate(&self) {\n\
               \x20       let feed = self.feed.lock().unwrap();\n\
               \x20       self.file.write_all(feed.bytes()).unwrap();\n\
               \x20   }\n\
               }\n";
    let findings = rules::lock_order("storage/kv.rs", &scan(bad));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].message.contains("file/socket write"));
}

/// A registered hot function introducing `.clone()` flags; the same
/// token under `lint: allow(hot)` or in an unregistered function does
/// not.
#[test]
fn fixture_hot_path_clone_flags() {
    let bad = "impl Kv {\n\
               \x20   pub fn get(&self) -> Doc {\n\
               \x20       self.doc.clone()\n\
               \x20   }\n\
               \x20   pub fn cold(&self) -> Doc {\n\
               \x20       self.doc.clone()\n\
               \x20   }\n\
               }\n";
    let findings = rules::hot_path("storage/kv.rs", &scan(bad));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "hot-path");
    assert_eq!(findings[0].line, 3);

    let allowed = "impl Kv {\n\
                   \x20   pub fn get(&self) -> Doc {\n\
                   \x20       self.doc.clone() // lint: allow(hot)\n\
                   \x20   }\n\
                   }\n";
    assert!(rules::hot_path("storage/kv.rs", &scan(allowed)).is_empty());
}

/// Zero-copy hot function: clean.
#[test]
fn fixture_hot_path_clean_passes() {
    let good = "impl Kv {\n\
                \x20   pub fn get(&self) -> Arc<Doc> {\n\
                \x20       Arc::clone(&self.doc)\n\
                \x20   }\n\
                }\n";
    assert!(rules::hot_path("storage/kv.rs", &scan(good)).is_empty());
}

/// A fresh `.unwrap()` in a request path is counted, and the ratchet
/// rejects any count above the grandfathered baseline.
#[test]
fn fixture_fresh_unwrap_fails_ratchet() {
    let src = "fn handle(&self) {\n\
               \x20   let doc = body.parse().unwrap();\n\
               }\n";
    let sites = rules::unwrap_sites("httpd/handler.rs", &scan(src));
    assert_eq!(sites, vec![2]);

    let mut current = BTreeMap::new();
    current.insert("httpd/handler.rs".to_string(), sites.len() as u64);
    let rep = baseline::ratchet(
        &current,
        &BTreeMap::new(),
        "unwrap-ratchet",
        "unwrap/expect sites",
        "handle the error instead",
    );
    assert_eq!(rep.errors.len(), 1, "fresh unwrap must block");
    assert_eq!(rep.errors[0].rule, "unwrap-ratchet");
}

/// Test code and reviewed `lint: allow(unwrap)` sites are exempt.
#[test]
fn fixture_unwrap_exemptions_pass() {
    let src = "fn handle(&self) {\n\
               \x20   let doc = body.parse().unwrap(); \
               // lint: allow(unwrap)\n\
               }\n\
               #[cfg(test)]\n\
               mod tests {\n\
               \x20   fn t() {\n\
               \x20       x.unwrap();\n\
               \x20   }\n\
               }\n";
    assert!(rules::unwrap_sites("httpd/handler.rs", &scan(src)).is_empty());
}

/// The ratchet only turns one way: equal counts pass, decreases warn
/// (stale baseline), increases fail.
#[test]
fn ratchet_is_one_way() {
    let r = |cur: &BTreeMap<String, u64>, base: &BTreeMap<String, u64>| {
        baseline::ratchet(
            cur,
            base,
            "unwrap-ratchet",
            "unwrap/expect sites",
            "handle the error instead",
        )
    };
    let mut base = BTreeMap::new();
    base.insert("httpd/server.rs".to_string(), 2u64);

    let rep = r(&base, &base);
    assert!(rep.errors.is_empty() && rep.warnings.is_empty());

    let mut fewer = base.clone();
    fewer.insert("httpd/server.rs".to_string(), 1);
    let rep = r(&fewer, &base);
    assert!(rep.errors.is_empty());
    assert_eq!(rep.warnings.len(), 1);

    let mut more = base.clone();
    more.insert("httpd/server.rs".to_string(), 3);
    assert_eq!(r(&more, &base).errors.len(), 1);
}

/// The unsafe-block count rides the same one-way ratchet under its own
/// rule name: growth blocks, shrinkage only warns about a stale
/// baseline.
#[test]
fn unsafe_ratchet_is_one_way() {
    let r = |cur: &BTreeMap<String, u64>, base: &BTreeMap<String, u64>| {
        baseline::ratchet(
            cur,
            base,
            "unsafe-ratchet",
            "unsafe blocks",
            "use a safe wrapper",
        )
    };
    let mut base = BTreeMap::new();
    base.insert("httpd/reactor.rs".to_string(), 11u64);

    assert!(r(&base, &base).errors.is_empty());

    let mut more = base.clone();
    more.insert("httpd/reactor.rs".to_string(), 12);
    let rep = r(&more, &base);
    assert_eq!(rep.errors.len(), 1, "new unsafe must block");
    assert_eq!(rep.errors[0].rule, "unsafe-ratchet");
    assert!(rep.errors[0].message.contains("unsafe blocks"));

    let mut fewer = base.clone();
    fewer.insert("httpd/reactor.rs".to_string(), 10);
    let rep = r(&fewer, &base);
    assert!(rep.errors.is_empty());
    assert_eq!(rep.warnings.len(), 1, "shrink only warns");
}

/// The same invariant CI enforces: the lint is clean over its own tree.
#[test]
fn lint_passes_over_own_tree() {
    let crate_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = run_all(crate_dir).expect("lint run");
    assert!(
        report.ok(),
        "blocking findings:\n{}",
        report
            .findings
            .iter()
            .map(|f| f.render())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

// ------------------------------------------- unsafe/FFI audit fixtures

/// A must-check syscall whose return value is discarded in statement
/// position flags; binding and using the value passes.
#[test]
fn fixture_unchecked_ffi_return_flags() {
    let bad = "impl Epoll {\n\
               \x20   fn arm(&self, fd: i32) {\n\
               \x20       // SAFETY: epfd and fd are open descriptors.\n\
               \x20       unsafe { sys::epoll_ctl(self.ep, 1, fd, p) };\n\
               \x20   }\n\
               }\n";
    let (findings, count) =
        ffi_contracts::audit("httpd/reactor.rs", &scan(bad));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "unsafe-ffi");
    assert_eq!(findings[0].line, 4);
    assert!(findings[0].message.contains("discarded"));
    assert_eq!(count, 1);

    let good = "impl Epoll {\n\
                \x20   fn arm(&self, fd: i32) -> i32 {\n\
                \x20       // SAFETY: epfd and fd are open descriptors.\n\
                \x20       let rc = unsafe {\n\
                \x20           sys::epoll_ctl(self.ep, 1, fd, p)\n\
                \x20       };\n\
                \x20       rc\n\
                \x20   }\n\
                }\n";
    let (findings, count) =
        ffi_contracts::audit("httpd/reactor.rs", &scan(good));
    assert!(findings.is_empty(), "{findings:?}");
    assert_eq!(count, 1);
}

/// A `write(2)` call whose enclosing fn has no EINTR retry loop flags.
#[test]
fn fixture_missing_eintr_retry_flags() {
    let bad = "impl EventFd {\n\
               \x20   fn wake(&self) -> isize {\n\
               \x20       // SAFETY: valid eventfd and 8-byte buffer.\n\
               \x20       let rc = unsafe { sys::write(self.fd, p, 8) };\n\
               \x20       rc\n\
               \x20   }\n\
               }\n";
    let (findings, _) =
        ffi_contracts::audit("httpd/reactor.rs", &scan(bad));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].message.contains("EINTR"));

    let good = "impl EventFd {\n\
                \x20   fn wake(&self) {\n\
                \x20       loop {\n\
                \x20           // SAFETY: valid eventfd, 8-byte buffer.\n\
                \x20           let rc =\n\
                \x20               unsafe { sys::write(self.fd, p, 8) };\n\
                \x20           if rc == 8 {\n\
                \x20               return;\n\
                \x20           }\n\
                \x20           let k =\n\
                \x20               std::io::Error::last_os_error().kind();\n\
                \x20           if k != std::io::ErrorKind::Interrupted {\n\
                \x20               return;\n\
                \x20           }\n\
                \x20       }\n\
                \x20   }\n\
                }\n";
    let (findings, _) =
        ffi_contracts::audit("httpd/reactor.rs", &scan(good));
    assert!(findings.is_empty(), "{findings:?}");
}

/// An fd-creating syscall in a fn that neither closes it nor belongs
/// to a type with a closing Drop flags as a leak; adding the Drop impl
/// passes.
#[test]
fn fixture_fd_leak_on_error_path_flags() {
    let bad = "impl Epoll {\n\
               \x20   fn open() -> i32 {\n\
               \x20       // SAFETY: CLOEXEC only; result checked.\n\
               \x20       let fd = unsafe { sys::epoll_create1(flags) };\n\
               \x20       fd\n\
               \x20   }\n\
               }\n";
    let (findings, _) =
        ffi_contracts::audit("httpd/reactor.rs", &scan(bad));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].message.contains("fd leak"));

    let good = "impl Epoll {\n\
                \x20   fn open() -> i32 {\n\
                \x20       // SAFETY: CLOEXEC only; result checked.\n\
                \x20       let fd = unsafe { sys::epoll_create1(flags) };\n\
                \x20       fd\n\
                \x20   }\n\
                }\n\
                impl Drop for Epoll {\n\
                \x20   fn drop(&mut self) {\n\
                \x20       // SAFETY: fd is ours; close is fire-and-forget.\n\
                \x20       unsafe { sys::close(self.fd) };\n\
                \x20   }\n\
                }\n";
    let (findings, _) =
        ffi_contracts::audit("httpd/reactor.rs", &scan(good));
    assert!(findings.is_empty(), "{findings:?}");
}

/// `unsafe` without a SAFETY comment flags in any file; a reviewed
/// `lint: allow(ffi)` marker silences a contract finding.
#[test]
fn fixture_safety_comment_and_allow_marker() {
    let bare = "fn peek() -> i32 {\n\
                \x20   let v = unsafe { raw() };\n\
                \x20   v\n\
                }\n";
    let (findings, count) =
        ffi_contracts::audit("storage/kv.rs", &scan(bare));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].message.contains("SAFETY"));
    assert_eq!(count, 1);

    let allowed = "impl Epoll {\n\
                   \x20   fn nudge(&self, fd: i32) {\n\
                   \x20       // SAFETY: best-effort re-arm.\n\
                   \x20       unsafe { sys::epoll_ctl(self.ep, 1, fd, p) }; \
                   // lint: allow(ffi)\n\
                   \x20   }\n\
                   }\n";
    let (findings, _) =
        ffi_contracts::audit("httpd/reactor.rs", &scan(allowed));
    assert!(findings.is_empty(), "{findings:?}");
}

// --------------------------------------- atomics-ordering fixtures

fn one_file(rel: &str, src: &str) -> BTreeMap<String, Scan> {
    let mut m = BTreeMap::new();
    m.insert(rel.to_string(), scan(src));
    m
}

/// An atomic receiver absent from ATOMIC_REGISTRY flags.
#[test]
fn fixture_unregistered_atomic_flags() {
    let bad = "impl Pool {\n\
               \x20   fn tick(&self) {\n\
               \x20       self.mystery.fetch_add(1, Ordering::Relaxed);\n\
               \x20   }\n\
               }\n";
    let out = atomics::check(&one_file("httpd/handler.rs", bad));
    assert_eq!(out.findings.len(), 1, "{:?}", out.findings);
    assert_eq!(out.findings[0].rule, "atomics");
    assert!(out.findings[0].message.contains("unregistered"));
    assert!(out.findings[0].message.contains("mystery"));
}

/// A registered publish-flag written with Relaxed flags; Release
/// passes, and the allow marker silences a reviewed site.
#[test]
fn fixture_relaxed_publish_flag_flags() {
    let bad = "impl R {\n\
               \x20   fn shutdown(&self) {\n\
               \x20       self.stop.store(true, Ordering::Relaxed);\n\
               \x20   }\n\
               }\n";
    let out = atomics::check(&one_file("httpd/reactor.rs", bad));
    assert_eq!(out.findings.len(), 1, "{:?}", out.findings);
    assert!(out.findings[0].message.contains("publish-flag"));

    let good = "impl R {\n\
                \x20   fn shutdown(&self) {\n\
                \x20       self.stop.store(true, Ordering::Release);\n\
                \x20   }\n\
                }\n";
    let out = atomics::check(&one_file("httpd/reactor.rs", good));
    assert!(out.findings.is_empty(), "{:?}", out.findings);

    let allowed = "impl R {\n\
                   \x20   fn shutdown(&self) {\n\
                   \x20       self.stop.store(true, Ordering::Relaxed); \
                   // lint: allow(atomics)\n\
                   \x20   }\n\
                   }\n";
    let out = atomics::check(&one_file("httpd/reactor.rs", allowed));
    assert!(out.findings.is_empty(), "{:?}", out.findings);
}

/// The universal compare_exchange rule: a failure ordering stronger
/// than the success ordering flags even on a lenient role.
#[test]
fn fixture_cas_failure_stronger_than_success_flags() {
    let bad = "impl Gate {\n\
               \x20   fn try_take(&self) {\n\
               \x20       let _ = self.state.compare_exchange(\n\
               \x20           cur,\n\
               \x20           next,\n\
               \x20           Ordering::Relaxed,\n\
               \x20           Ordering::Acquire,\n\
               \x20       );\n\
               \x20   }\n\
               }\n";
    let out = atomics::check(&one_file("httpd/middleware.rs", bad));
    assert_eq!(out.findings.len(), 1, "{:?}", out.findings);
    assert!(out.findings[0].message.contains("stronger"));
}

/// Registry rows whose file is scanned but never matched surface as
/// non-blocking staleness warnings, not findings.
#[test]
fn fixture_stale_registry_row_warns() {
    let src = "fn quiet() {}\n";
    let out = atomics::check(&one_file("util/id.rs", src));
    assert!(out.findings.is_empty(), "{:?}", out.findings);
    assert_eq!(out.warnings.len(), 1, "{:?}", out.warnings);
    assert!(out.warnings[0].message.contains("SEQ"));
}

// ------------------------------------- conn state-machine fixtures

/// Direct `.state =` assignment outside `Conn::set_state` flags.
#[test]
fn fixture_direct_state_assignment_flags() {
    let bad = "impl Conn {\n\
               \x20   fn hack(&mut self) {\n\
               \x20       self.state = ConnState::Handle;\n\
               \x20   }\n\
               }\n";
    let findings = conn_contract::check_file("httpd/conn.rs", &scan(bad));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "conn-state");
    assert!(findings[0].message.contains("set_state"));
}

/// A set_state call naming a state missing from the contract tables
/// flags — the static half of the undeclared-transition guard (the
/// dynamic half is the debug assert inside `Conn::set_state`).
#[test]
fn fixture_undeclared_conn_state_flags() {
    let bad = "impl Conn {\n\
               \x20   fn jump(&mut self) {\n\
               \x20       self.set_state(ConnState::Zombie);\n\
               \x20   }\n\
               }\n";
    let findings = conn_contract::check_file("httpd/conn.rs", &scan(bad));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].message.contains("Zombie"));

    assert!(!conn_contract::transition_allowed(
        submarine::httpd::conn::ConnState::WriteResponse,
        submarine::httpd::conn::ConnState::ReadBody,
    ));
}

/// A match over the conn state with a wildcard arm flags; spelling
/// every state out passes.
#[test]
fn fixture_wildcard_state_match_flags() {
    let bad = "impl Conn {\n\
               \x20   fn ready(&self) -> bool {\n\
               \x20       match self.state {\n\
               \x20           ConnState::ReadHeaders => true,\n\
               \x20           _ => false,\n\
               \x20       }\n\
               \x20   }\n\
               }\n";
    let findings = conn_contract::check_file("httpd/conn.rs", &scan(bad));
    assert!(
        findings.iter().any(|f| f.message.contains("wildcard arm")),
        "{findings:?}"
    );

    let good = "impl Conn {\n\
                \x20   fn reads(&self) -> bool {\n\
                \x20       match self.state {\n\
                \x20           ConnState::ReadHeaders => true,\n\
                \x20           ConnState::ReadBody => true,\n\
                \x20           ConnState::Handle => false,\n\
                \x20           ConnState::WriteResponse => false,\n\
                \x20           ConnState::KeepAliveIdle => true,\n\
                \x20           ConnState::Tail => true,\n\
                \x20       }\n\
                \x20   }\n\
                }\n";
    let findings = conn_contract::check_file("httpd/conn.rs", &scan(good));
    assert!(findings.is_empty(), "{findings:?}");
}

/// A rearm arm whose epoll interest disagrees with the declared
/// interest table flags.
#[test]
fn fixture_rearm_interest_mismatch_flags() {
    let bad = "impl Reactor {\n\
               \x20   fn rearm(&self, idx: usize) {\n\
               \x20       let mut want = sys::EPOLLRDHUP;\n\
               \x20       match self.slots[idx].conn.state {\n\
               \x20           ConnState::ReadHeaders\n\
               \x20           | ConnState::ReadBody\n\
               \x20           | ConnState::KeepAliveIdle => {\n\
               \x20               want |= sys::EPOLLIN;\n\
               \x20           }\n\
               \x20           ConnState::Handle => {}\n\
               \x20           ConnState::WriteResponse => {\n\
               \x20               want |= sys::EPOLLIN;\n\
               \x20           }\n\
               \x20           ConnState::Tail => {\n\
               \x20               want |= sys::EPOLLIN;\n\
               \x20               want |= sys::EPOLLOUT;\n\
               \x20           }\n\
               \x20       }\n\
               \x20   }\n\
               }\n";
    let findings =
        conn_contract::check_rearm("httpd/reactor.rs", &scan(bad));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].message.contains("WriteResponse"));
}

// -------------------------------- runtime tracker (subprocess, debug)

/// Child half of the deterministic-interleaving regression. A no-op
/// pass unless the parent sets `SUBMARINE_TRACKER_CHILD=1`; then it
/// stages the classic two-thread deadlock — thread A takes a shard
/// lock then the feed mutex (canonical), thread B takes the feed mutex
/// then a shard lock (inverted) — with a barrier guaranteeing both
/// first acquisitions happen before either second one. Without the
/// tracker this interleaving deadlocks; with it, thread B panics
/// before blocking, and the child exits 42 to prove it.
#[test]
fn tracker_child_inverted_interleaving() {
    if std::env::var("SUBMARINE_TRACKER_CHILD").is_err() {
        return;
    }
    use std::sync::{Arc, Barrier, Mutex, RwLock};
    use submarine::analysis::lock_order::LockRank;
    use submarine::analysis::tracker;

    let shard = Arc::new(RwLock::new(0u64));
    let feed = Arc::new(Mutex::new(0u64));
    let gate = Arc::new(Barrier::new(2));

    let a = {
        let (shard, feed, gate) =
            (Arc::clone(&shard), Arc::clone(&feed), Arc::clone(&gate));
        std::thread::spawn(move || {
            let _hs = tracker::acquired(LockRank::Shard, 0);
            let _s = shard.read().unwrap();
            gate.wait();
            // Blocks until thread B's panic releases the feed mutex —
            // the deadlock half that the tracker must break.
            let _hf = tracker::acquired(LockRank::Feed, 0);
            let _f = feed.lock().unwrap_or_else(|e| e.into_inner());
        })
    };
    let b = {
        let (shard, feed, gate) =
            (Arc::clone(&shard), Arc::clone(&feed), Arc::clone(&gate));
        std::thread::spawn(move || -> Option<String> {
            let _hf = tracker::acquired(LockRank::Feed, 0);
            let _f = feed.lock().unwrap();
            gate.wait();
            let caught = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| {
                    let _hs = tracker::acquired(LockRank::Shard, 0);
                    let _s = shard.read().unwrap();
                }),
            );
            match caught {
                Ok(()) => None,
                Err(p) => p
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| {
                        p.downcast_ref::<&str>()
                            .map(|s| s.to_string())
                    }),
            }
        })
    };
    let msg = b.join().expect("thread B must not die outside the trap");
    a.join().expect("thread A must complete once B releases feed");
    match msg {
        Some(m) if m.contains("lock-order violation") => {
            std::process::exit(42)
        }
        other => {
            eprintln!("expected tracker panic, got {other:?}");
            std::process::exit(1)
        }
    }
}

/// Parent half: re-run this binary filtered to the child test with the
/// env guard set, and require the tracker-panic exit code. Debug
/// builds only — in release the tracker compiles to a no-op and the
/// staged interleaving would genuinely deadlock.
#[cfg(debug_assertions)]
#[test]
fn tracker_panics_on_inverted_interleaving() {
    let exe = std::env::current_exe().expect("current_exe");
    let out = std::process::Command::new(exe)
        .args([
            "tracker_child_inverted_interleaving",
            "--exact",
            "--test-threads=1",
            "--nocapture",
        ])
        .env("SUBMARINE_TRACKER_CHILD", "1")
        .output()
        .expect("spawn child test process");
    assert_eq!(
        out.status.code(),
        Some(42),
        "child must exit via the tracker panic path\nstdout:\n{}\n\
         stderr:\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
}
