//! Crash-recovery and concurrency tests for the storage engine v2
//! (ISSUE 2): torn-tail tolerance, snapshot+tail vs pure-WAL
//! equivalence, compaction bounding the log, legacy migration, and a
//! concurrent put/list hammer across shards.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use submarine::storage::{MetaStore, StoreOptions};
use submarine::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "submarine-recovery-{tag}-{}",
        std::process::id()
    ));
    let _ = fs::remove_dir_all(&d);
    let _ = fs::remove_file(&d);
    d
}

/// The WAL files of a data dir, name-sorted (generation order).
fn wal_files(dir: &Path) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| {
            let p = e.unwrap().path();
            let name = p.file_name()?.to_str()?.to_string();
            (name.starts_with("wal-") && name.ends_with(".jsonl"))
                .then_some(p)
        })
        .collect();
    out.sort();
    out
}

fn no_auto_compact() -> StoreOptions {
    StoreOptions {
        compact_threshold: 0,
        ..StoreOptions::default()
    }
}

/// Owned-`Json` view of a stored doc for equality asserts.
fn got(s: &MetaStore, ns: &str, key: &str) -> Option<Json> {
    s.get(ns, key).map(|d| d.json().clone())
}

#[test]
fn truncated_final_record_loses_exactly_one_write() {
    let dir = tmp_dir("torn-tail");
    const N: usize = 8;
    {
        let s = MetaStore::open_with(&dir, no_auto_compact()).unwrap();
        for i in 0..N {
            s.put("exp", &format!("e{i}"), Json::Num(i as f64))
                .unwrap();
        }
    }
    // crash mid-append: chop the last record in half
    let wal = wal_files(&dir).pop().unwrap();
    let bytes = fs::read(&wal).unwrap();
    let cut = bytes.len() - 9;
    fs::write(&wal, &bytes[..cut]).unwrap();

    let s = MetaStore::open_with(&dir, no_auto_compact()).unwrap();
    assert_eq!(s.count("exp"), N - 1, "exactly the torn write is lost");
    assert!(s.get("exp", &format!("e{}", N - 1)).is_none());
    assert_eq!(got(&s, "exp", "e0"), Some(Json::Num(0.0)));
    assert_eq!(s.stats().skipped_records, 1);

    // the store keeps working after a tolerated torn tail
    s.put("exp", "post-crash", Json::Bool(true)).unwrap();
    drop(s);
    let s = MetaStore::open(&dir).unwrap();
    assert_eq!(got(&s, "exp", "post-crash"), Some(Json::Bool(true)));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn blank_lines_and_torn_tail_are_counted_not_fatal() {
    let dir = tmp_dir("blank");
    fs::create_dir_all(&dir).unwrap();
    fs::write(
        dir.join("wal-000001.jsonl"),
        concat!(
            r#"{"op":"put","ns":"a","key":"k1","doc":1}"#,
            "\n\n   \n",
            r#"{"op":"put","ns":"a","key":"k2","doc":2}"#,
            "\n",
            r#"{"op":"put","ns":"a","key":"k3","#, // torn mid-record
        ),
    )
    .unwrap();
    let s = MetaStore::open(&dir).unwrap();
    assert_eq!(s.count("a"), 2);
    // two blank lines + one torn tail, uniformly counted
    assert_eq!(s.stats().skipped_records, 3);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn complete_record_missing_only_newline_is_recovered() {
    let dir = tmp_dir("no-newline");
    fs::create_dir_all(&dir).unwrap();
    // crash exactly between the payload write and its terminator
    fs::write(
        dir.join("wal-000001.jsonl"),
        concat!(
            r#"{"op":"put","ns":"a","key":"k1","doc":1}"#,
            "\n",
            r#"{"op":"put","ns":"a","key":"k2","doc":2}"#, // no \n
        ),
    )
    .unwrap();
    {
        let s = MetaStore::open(&dir).unwrap();
        assert_eq!(s.count("a"), 2, "complete tail record is applied");
        assert_eq!(s.stats().skipped_records, 0);
        // appends after the engine newline-terminates the tail must
        // not fuse with it
        s.put("a", "k3", Json::Num(3.0)).unwrap();
    }
    let s = MetaStore::open(&dir).unwrap();
    assert_eq!(s.count("a"), 3);
    assert_eq!(got(&s, "a", "k2"), Some(Json::Num(2.0)));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn interior_corruption_is_a_hard_error() {
    let dir = tmp_dir("interior");
    fs::create_dir_all(&dir).unwrap();
    for bad in [
        "garbage\n{\"op\":\"put\",\"ns\":\"a\",\"key\":\"k\"}\n",
        "{\"op\":\"frob\",\"ns\":\"a\",\"key\":\"k\"}\n{\"op\":\"del\",\
         \"ns\":\"a\",\"key\":\"k\"}\n",
        "{\"op\":\"put\",\"key\":\"no-ns\"}\n{\"op\":\"del\",\
         \"ns\":\"a\",\"key\":\"k\"}\n",
    ] {
        fs::write(dir.join("wal-000001.jsonl"), bad).unwrap();
        assert!(
            MetaStore::open(&dir).is_err(),
            "interior corruption must not be silently skipped: {bad:?}"
        );
    }
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn snapshot_plus_tail_equals_pure_wal_replay() {
    let compacting = tmp_dir("equiv-snap");
    let wal_only = tmp_dir("equiv-wal");
    {
        // same op script into both stores; one compacts every 10
        // records, the other never does
        let a = MetaStore::open_with(
            &compacting,
            StoreOptions {
                compact_threshold: 10,
                ..StoreOptions::default()
            },
        )
        .unwrap();
        let b = MetaStore::open_with(&wal_only, no_auto_compact()).unwrap();
        for s in [&a, &b] {
            for i in 0..60u32 {
                let ns = ["exp", "model", "template"][(i % 3) as usize];
                s.put(
                    ns,
                    &format!("k{:02}", i % 20),
                    Json::obj()
                        .set("v", Json::Num(i as f64))
                        .set(
                            "status",
                            Json::Str(
                                ["Accepted", "Running"][(i % 2) as usize]
                                    .into(),
                            ),
                        ),
                )
                .unwrap();
                if i % 7 == 0 {
                    s.delete("exp", &format!("k{:02}", i % 20)).unwrap();
                }
            }
        }
        assert!(a.stats().compactions >= 1, "{:?}", a.stats());
        assert_eq!(b.stats().compactions, 0);
    }
    let a = MetaStore::open(&compacting).unwrap();
    let b = MetaStore::open(&wal_only).unwrap();
    assert_eq!(
        a.dump().dump(),
        b.dump().dump(),
        "snapshot+tail recovery must equal pure WAL replay"
    );
    assert_eq!(a.stats().docs, b.stats().docs);
    let _ = fs::remove_dir_all(&compacting);
    let _ = fs::remove_dir_all(&wal_only);
}

#[test]
fn compaction_bounds_wal_and_drops_stale_generations() {
    let dir = tmp_dir("bounds");
    {
        let s = MetaStore::open_with(
            &dir,
            StoreOptions {
                compact_threshold: 16,
                ..StoreOptions::default()
            },
        )
        .unwrap();
        for i in 0..200 {
            s.put("ns", &format!("k{i:03}"), Json::Num(i as f64))
                .unwrap();
        }
        let st = s.stats();
        assert!(st.compactions >= 5, "{st:?}");
        assert!(st.wal_records <= 32, "log not bounded: {st:?}");
    }
    // exactly one live generation on disk: one snapshot + one wal
    let names: Vec<String> = fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    assert_eq!(names.len(), 2, "stale generations left behind: {names:?}");
    let s = MetaStore::open(&dir).unwrap();
    assert_eq!(s.count("ns"), 200);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_put_list_hammer_across_shards() {
    let dir = tmp_dir("hammer");
    const WRITERS: usize = 8;
    const PER_THREAD: usize = 120;
    {
        let s = Arc::new(
            MetaStore::open_with(
                &dir,
                StoreOptions {
                    compact_threshold: 64, // force compactions mid-storm
                    ..StoreOptions::default()
                },
            )
            .unwrap(),
        );
        let mut handles = Vec::new();
        for t in 0..WRITERS {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                let ns = format!("ns{}", t % 4);
                for i in 0..PER_THREAD {
                    let key = format!("t{t}-k{i:03}");
                    s.put(&ns, &key, Json::Num(i as f64)).unwrap();
                    // interleave reads with the writes
                    assert!(s.get(&ns, &key).is_some());
                    if i % 10 == 0 {
                        let _ = s.list(&ns);
                        let _ = s.count("ns0");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        for t in 0..4 {
            // 4 namespaces, 2 writer threads each
            assert_eq!(
                s.count(&format!("ns{t}")),
                2 * PER_THREAD,
                "ns{t} lost writes"
            );
        }
    }
    // every write survives reopen, through however many compactions
    let s = MetaStore::open(&dir).unwrap();
    for t in 0..4 {
        assert_eq!(s.count(&format!("ns{t}")), 2 * PER_THREAD);
    }
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn indexes_rebuild_from_recovered_state() {
    let dir = tmp_dir("index-rebuild");
    {
        let s = MetaStore::open(&dir).unwrap();
        s.define_index("exp", "status", true);
        for (k, st) in
            [("e1", "Running"), ("e2", "Running"), ("e3", "Failed")]
        {
            s.put(
                "exp",
                k,
                Json::obj().set("status", Json::Str(st.into())),
            )
            .unwrap();
        }
        s.delete("exp", "e2").unwrap();
        s.compact().unwrap();
    }
    let s = MetaStore::open(&dir).unwrap();
    // declarations are code-level; re-declare and expect a backfill
    s.define_index("exp", "status", true);
    assert_eq!(
        s.index_lookup("exp", "status", "running").unwrap(),
        vec!["e1"]
    );
    assert_eq!(
        s.index_lookup("exp", "status", "FAILED").unwrap(),
        vec!["e3"]
    );
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn legacy_single_file_wal_migrates_in_place() {
    let path = tmp_dir("legacy"); // used as a *file* path here
    fs::write(
        &path,
        concat!(
            r#"{"op":"put","ns":"exp","key":"e1","doc":{"name":"m"}}"#,
            "\n",
            r#"{"op":"put","ns":"exp","key":"e2","doc":2}"#,
            "\n",
            r#"{"op":"del","ns":"exp","key":"e2"}"#,
            "\n",
            r#"{"op":"put","ns":"exp","key":"e3","doc":3"#, // torn
        ),
    )
    .unwrap();
    let s = MetaStore::open(&path).unwrap();
    assert!(path.is_dir(), "file migrated into a data directory");
    assert_eq!(s.count("exp"), 1);
    assert_eq!(
        s.get("exp", "e1").unwrap().str_field("name"),
        Some("m")
    );
    assert_eq!(s.stats().skipped_records, 1);
    drop(s);
    // reopening the migrated directory is the normal v2 path
    let s = MetaStore::open(&path).unwrap();
    assert_eq!(s.count("exp"), 1);
    let _ = fs::remove_dir_all(&path);
}

#[test]
fn interrupted_migration_rolls_back_and_retries() {
    // simulate a crash after migrate's rename but before the snapshot:
    // the legacy data sits in <path>.migrating and <path> is a bare dir
    let path = tmp_dir("migrate-crash");
    let bak = PathBuf::from(format!(
        "{}.migrating",
        path.to_str().unwrap()
    ));
    let _ = fs::remove_file(&bak);
    fs::write(
        &bak,
        concat!(
            r#"{"op":"put","ns":"exp","key":"e1","doc":1}"#,
            "\n"
        ),
    )
    .unwrap();
    fs::create_dir_all(&path).unwrap();
    let s = MetaStore::open(&path).unwrap();
    assert_eq!(
        got(&s, "exp", "e1"),
        Some(Json::Num(1.0)),
        "legacy data must survive a crash mid-migration"
    );
    assert!(!bak.exists(), "backup consumed after successful retry");
    let _ = fs::remove_dir_all(&path);
}

#[test]
fn storage_inspect_is_read_only() {
    let dir = tmp_dir("inspect");
    {
        let s = MetaStore::open(&dir).unwrap();
        s.put("exp", "e1", Json::Num(1.0)).unwrap();
    }
    // leave a torn tail and a tmp leftover; inspect must report them
    // without repairing anything
    let wal = wal_files(&dir).pop().unwrap();
    let bytes = fs::read(&wal).unwrap();
    let torn =
        [&bytes[..], &b"{\"op\":\"put\",\"ns\":\"exp\""[..]].concat();
    fs::write(&wal, &torn).unwrap();
    fs::write(dir.join("snapshot-000009.json.tmp"), b"junk").unwrap();
    let st = MetaStore::inspect(&dir).unwrap();
    assert_eq!(st.docs, 1);
    assert_eq!(st.skipped_records, 1);
    assert_eq!(
        fs::read(&wal).unwrap(),
        torn,
        "inspect must not truncate the WAL"
    );
    assert!(
        dir.join("snapshot-000009.json.tmp").exists(),
        "inspect must not clean tmp files"
    );
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn crashed_snapshot_tmp_is_discarded() {
    let dir = tmp_dir("tmp-leftover");
    {
        let s = MetaStore::open(&dir).unwrap();
        s.put("ns", "k", Json::Num(1.0)).unwrap();
        s.compact().unwrap();
    }
    fs::write(dir.join("snapshot-000099.json.tmp"), "half-written")
        .unwrap();
    let s = MetaStore::open(&dir).unwrap();
    assert_eq!(got(&s, "ns", "k"), Some(Json::Num(1.0)));
    assert!(!dir.join("snapshot-000099.json.tmp").exists());
    let _ = fs::remove_dir_all(&dir);
}
