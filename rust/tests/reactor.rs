//! Integration tests for the epoll reactor's connection state machine
//! (ISSUE 7): slow-loris partial headers answered 408, idle keep-alive
//! reaping, pipelining, partial-write resumption on large framed
//! responses, chunked watch streams under client backpressure, and
//! slow-consumer eviction at the write-buffer cap.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use submarine::experiment::spec::ExperimentSpec;
use submarine::httpd::reactor::set_recv_buffer;
use submarine::httpd::server::{Server, ServerOptions, Services};
use submarine::httpd::ApiConfig;
use submarine::orchestrator::Submitter;
use submarine::storage::MetaStore;
use submarine::util::json::Json;

struct NullSubmitter;
impl Submitter for NullSubmitter {
    fn name(&self) -> &'static str {
        "null"
    }
    fn submit(&self, _: &str, _: &ExperimentSpec) -> submarine::Result<()> {
        Ok(())
    }
    fn kill(&self, _: &str) -> submarine::Result<()> {
        Ok(())
    }
}

fn services() -> Arc<Services> {
    Arc::new(Services::new(
        Arc::new(MetaStore::in_memory()),
        Arc::new(NullSubmitter),
    ))
}

fn start_with(
    opts: ServerOptions,
) -> (u16, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
    let srv = Arc::new(
        Server::bind_with_options(
            services(),
            0,
            &ApiConfig::default(),
            opts,
        )
        .unwrap(),
    );
    let port = srv.port();
    let stop = srv.stopper();
    let handle = srv.serve_background();
    (port, stop, handle)
}

fn shutdown(
    port: u16,
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<()>,
) {
    stop.store(true, Ordering::Relaxed);
    let _ = TcpStream::connect(("127.0.0.1", port));
    handle.join().unwrap();
}

/// Read one content-length-framed response off a buffered reader
/// (reusable across keep-alive requests on the same connection).
fn read_response<R: BufRead>(reader: &mut R) -> (u16, String) {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let status: u16 = line.split(' ').nth(1).unwrap().parse().unwrap();
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        let h = h.trim_end().to_ascii_lowercase();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.strip_prefix("content-length:") {
            len = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

fn post_template(port: u16, name: &str) {
    let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let body = format!(
        "{{\"name\":\"{name}\",\"experimentSpec\":{{\
         \"meta\":{{\"name\":\"m\"}},\"spec\":{{\"Worker\":{{\
         \"replicas\":1,\"resources\":\"cpu=1\"}}}}}}}}"
    );
    write!(
        &stream,
        "POST /api/v2/template HTTP/1.1\r\nhost: x\r\n\
         content-length: {}\r\nconnection: close\r\n\r\n{}",
        body.len(),
        body
    )
    .unwrap();
    let mut reader = BufReader::new(&stream);
    let (status, body) = read_response(&mut reader);
    assert_eq!(status, 200, "{body}");
}

/// A request that starts arriving but stalls mid-header (slow loris)
/// is answered 408 in the idle window, not held forever.
#[test]
fn slow_loris_partial_header_gets_408() {
    let (port, stop, handle) = start_with(ServerOptions {
        workers: Some(2),
        idle_timeout: Duration::from_millis(300),
        ..Default::default()
    });

    let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    // request line never completes
    write!(stream, "GET /api/v2/clu").unwrap();
    let started = Instant::now();
    let mut buf = String::new();
    stream.read_to_string(&mut buf).unwrap();
    assert!(buf.contains("408"), "expected 408, got: {buf}");
    assert!(buf.contains("Timeout"), "{buf}");
    assert!(
        started.elapsed() < Duration::from_secs(5),
        "408 took {:?}",
        started.elapsed()
    );
    shutdown(port, stop, handle);
}

/// A keep-alive connection that goes quiet past the idle window is
/// closed silently — no error bytes, just EOF.
#[test]
fn idle_keep_alive_connection_is_reaped_silently() {
    let (port, stop, handle) = start_with(ServerOptions {
        workers: Some(2),
        idle_timeout: Duration::from_millis(300),
        ..Default::default()
    });

    let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    write!(&stream, "GET /api/v2/cluster HTTP/1.1\r\nhost: x\r\n\r\n")
        .unwrap();
    let mut reader = BufReader::new(&stream);
    let (status, _) = read_response(&mut reader);
    assert_eq!(status, 200);
    // now sit idle past the window: the server closes with no bytes
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(
        rest.is_empty(),
        "idle reap must be silent, got {} bytes",
        rest.len()
    );
    shutdown(port, stop, handle);
}

/// Two requests written back-to-back in one burst are both served, in
/// order, on the same connection.
#[test]
fn pipelined_requests_are_served_in_order() {
    let (port, stop, handle) = start_with(ServerOptions {
        workers: Some(2),
        ..Default::default()
    });

    let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    write!(
        &stream,
        "GET /api/v2/cluster HTTP/1.1\r\nhost: x\r\n\r\n\
         GET /api/v2/template HTTP/1.1\r\nhost: x\r\n\
         connection: close\r\n\r\n"
    )
    .unwrap();
    let mut reader = BufReader::new(&stream);
    let (s1, b1) = read_response(&mut reader);
    assert_eq!(s1, 200);
    assert!(b1.contains("RUNNING"), "{b1}");
    let (s2, b2) = read_response(&mut reader);
    assert_eq!(s2, 200);
    assert!(b2.contains("items"), "{b2}");
    shutdown(port, stop, handle);
}

/// A framed response much larger than the client's receive window is
/// delivered completely: the reactor resumes the write on EPOLLOUT
/// after every partial write / EAGAIN.
#[test]
fn large_framed_response_resumes_after_partial_writes() {
    let (port, stop, handle) = start_with(ServerOptions {
        workers: Some(2),
        ..Default::default()
    });
    for i in 0..400 {
        post_template(port, &format!("t-{i}"));
    }

    let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    // shrink this end's receive window so the server cannot push the
    // whole list in one write
    set_recv_buffer(&stream, 4096);
    write!(
        &stream,
        "GET /api/v2/template HTTP/1.1\r\nhost: x\r\n\
         connection: close\r\n\r\n"
    )
    .unwrap();
    // drip-read so the server keeps hitting a full socket
    let mut reader = BufReader::with_capacity(1024, &stream);
    let (status, body) = {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let status: u16 =
            line.split(' ').nth(1).unwrap().parse().unwrap();
        let mut len = 0usize;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).unwrap();
            let h = h.trim_end().to_ascii_lowercase();
            if h.is_empty() {
                break;
            }
            if let Some(v) = h.strip_prefix("content-length:") {
                len = v.trim().parse().unwrap();
            }
        }
        let mut body = vec![0u8; len];
        let mut got = 0usize;
        while got < len {
            let step = (len - got).min(1024);
            reader.read_exact(&mut body[got..got + step]).unwrap();
            got += step;
            std::thread::sleep(Duration::from_micros(200));
        }
        (status, body)
    };
    assert_eq!(status, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(
        j.at(&["result", "total"]).and_then(Json::as_f64),
        Some(400.0),
        "every item must arrive intact"
    );
    shutdown(port, stop, handle);
}

/// A chunked watch stream under client backpressure still delivers
/// every event and the terminal BOOKMARK once the client catches up.
#[test]
fn stream_watcher_receives_all_events_through_backpressure() {
    let (port, stop, handle) = start_with(ServerOptions {
        workers: Some(2),
        ..Default::default()
    });

    let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    set_recv_buffer(&stream, 4096);
    write!(
        &stream,
        "GET /api/v2/template?watch=1&stream=1&since=0&\
         timeout_ms=8000 HTTP/1.1\r\nhost: x\r\n\r\n"
    )
    .unwrap();

    // publish while the watcher is not reading
    const EVENTS: usize = 500;
    for i in 0..EVENTS {
        post_template(port, &format!("bp-{i}"));
    }

    // now drain slowly and count
    let mut reader = BufReader::with_capacity(1024, &stream);
    let mut puts = 0usize;
    let mut bookmark = false;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {
                if line.contains("\"type\":\"PUT\"") {
                    puts += 1;
                }
                if line.contains("\"type\":\"BOOKMARK\"") {
                    bookmark = true;
                }
            }
            Err(e) => panic!("watcher read error: {e}"),
        }
    }
    assert_eq!(puts, EVENTS, "missing events");
    assert!(bookmark, "stream must end with a BOOKMARK line");
    shutdown(port, stop, handle);
}

/// A stream watcher that never reads while events pile up past the
/// write-buffer cap is evicted instead of buffering without bound.
#[test]
fn slow_consumer_stream_watcher_is_evicted() {
    let (port, stop, handle) = start_with(ServerOptions {
        workers: Some(2),
        write_buf_cap: 1024,
        ..Default::default()
    });

    let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    set_recv_buffer(&stream, 4096);
    write!(
        &stream,
        "GET /api/v2/template?watch=1&stream=1&since=0&\
         timeout_ms=60000 HTTP/1.1\r\nhost: x\r\n\r\n"
    )
    .unwrap();

    // never read; flood until the server's buffers can't absorb it
    for i in 0..1500 {
        post_template(port, &format!("ev-{i}"));
    }
    std::thread::sleep(Duration::from_millis(300));

    // the connection must terminate long before the 60s watch window,
    // and without the orderly BOOKMARK ending
    let started = Instant::now();
    let mut reader = BufReader::new(&stream);
    let mut bookmark = false;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {
                if line.contains("\"type\":\"BOOKMARK\"") {
                    bookmark = true;
                }
            }
            Err(_) => break, // reset also counts as eviction
        }
    }
    assert!(
        started.elapsed() < Duration::from_secs(10),
        "evicted stream should end promptly"
    );
    assert!(!bookmark, "evicted stream must not end with BOOKMARK");
    shutdown(port, stop, handle);
}

/// A long-poll watch resolves at its window and the connection stays
/// keep-alive for the next request.
#[test]
fn long_poll_resolves_and_connection_stays_usable() {
    let (port, stop, handle) = start_with(ServerOptions {
        workers: Some(2),
        ..Default::default()
    });

    let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut reader = BufReader::new(&stream);
    write!(
        &stream,
        "GET /api/v2/template?watch=1&timeout_ms=300 HTTP/1.1\r\n\
         host: x\r\n\r\n"
    )
    .unwrap();
    let (status, body) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert!(body.contains("\"events\""), "{body}");
    assert!(body.contains("resource_version"), "{body}");

    // same connection, next request
    write!(&stream, "GET /api/v2/cluster HTTP/1.1\r\nhost: x\r\n\r\n")
        .unwrap();
    let (status, body) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert!(body.contains("RUNNING"), "{body}");
    shutdown(port, stop, handle);
}

/// A `?stream=1` full-namespace drain delivers every document exactly
/// once through client backpressure, ends with a `done` line whose
/// count matches, and closes cleanly. The drip-read keeps the server
/// re-acquiring the shard lock chunk by chunk instead of pushing one
/// giant response.
#[test]
fn streamed_list_drain_is_complete_under_backpressure() {
    let (port, stop, handle) = start_with(ServerOptions {
        workers: Some(2),
        ..Default::default()
    });
    const DOCS: usize = 400;
    for i in 0..DOCS {
        post_template(port, &format!("d-{i:04}"));
    }

    let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    set_recv_buffer(&stream, 4096);
    write!(
        &stream,
        "GET /api/v2/template?stream=1 HTTP/1.1\r\nhost: x\r\n\r\n"
    )
    .unwrap();

    let mut reader = BufReader::with_capacity(1024, &stream);
    let mut keys = 0usize;
    let mut done: Option<Json> = None;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {
                let t = line.trim();
                if t.starts_with("{\"key\":") {
                    keys += 1;
                } else if t.starts_with("{\"done\":") {
                    done = Some(Json::parse(t).unwrap());
                }
                // pace the reads so the server keeps hitting a full
                // socket and must resume chunk by chunk
                if keys % 50 == 0 {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
            Err(e) => panic!("drain read error: {e}"),
        }
    }
    assert_eq!(keys, DOCS, "every document must arrive exactly once");
    let done = done.expect("drain must end with a done line");
    assert_eq!(done.num_field("count"), Some(DOCS as f64));
    assert!(done.num_field("resource_version").unwrap_or(0.0) > 0.0);
    shutdown(port, stop, handle);
}

/// A streamed list consumer that never reads is evicted at the
/// write-buffer cap — the drain must not buffer an entire namespace
/// for a dead client, and the orderly `done` line never arrives.
#[test]
fn slow_consumer_streamed_list_is_evicted() {
    let (port, stop, handle) = start_with(ServerOptions {
        workers: Some(2),
        write_buf_cap: 1024,
        ..Default::default()
    });
    for i in 0..600 {
        post_template(port, &format!("s-{i:04}"));
    }

    let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    set_recv_buffer(&stream, 4096);
    write!(
        &stream,
        "GET /api/v2/template?stream=1&timeout_ms=60000 \
         HTTP/1.1\r\nhost: x\r\n\r\n"
    )
    .unwrap();

    // never read; the namespace is far larger than the 1 KiB cap
    std::thread::sleep(Duration::from_millis(300));

    let started = Instant::now();
    let mut reader = BufReader::new(&stream);
    let mut done = false;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {
                if line.trim().starts_with("{\"done\":") {
                    done = true;
                }
            }
            Err(_) => break, // reset also counts as eviction
        }
    }
    assert!(
        started.elapsed() < Duration::from_secs(10),
        "evicted drain should end promptly"
    );
    assert!(!done, "evicted drain must not end with a done line");
    shutdown(port, stop, handle);
}
