//! Integration tests for the declarative resource API (ISSUE 4):
//! unified `meta` blocks, `ETag`/`If-Match` optimistic concurrency
//! (racing writers), label selectors, long-poll and chunked watch
//! streams with `410 Gone` resume-after-compaction, transport-error
//! envelope selection, and the acceptance path — a watcher observing
//! an execution-engine-driven status transition without polling.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use submarine::cluster::{ClusterSim, Resources};
use submarine::experiment::monitor::ExperimentMonitor;
use submarine::experiment::spec::ExperimentSpec;
use submarine::httpd::http::Request;
use submarine::httpd::server::{Server, Services};
use submarine::httpd::{ApiConfig, Router};
use submarine::orchestrator::engine::EngineConfig;
use submarine::orchestrator::sim_submitter::SimSubmitter;
use submarine::orchestrator::Submitter;
use submarine::scheduler::queue::QueueTree;
use submarine::scheduler::yarn::YarnScheduler;
use submarine::sdk::{ExperimentClient, WatchStep};
use submarine::storage::{MetaStore, MetricStore, StoreOptions};
use submarine::util::clock::SimTime;
use submarine::util::json::Json;

struct NullSubmitter;
impl Submitter for NullSubmitter {
    fn name(&self) -> &'static str {
        "null"
    }
    fn submit(&self, _: &str, _: &ExperimentSpec) -> submarine::Result<()> {
        Ok(())
    }
    fn kill(&self, _: &str) -> submarine::Result<()> {
        Ok(())
    }
}

fn services_over(store: Arc<MetaStore>) -> Arc<Services> {
    Arc::new(Services::new(store, Arc::new(NullSubmitter)))
}

fn api(store: Arc<MetaStore>) -> Router {
    submarine::httpd::server::build_router(services_over(store))
}

fn dispatch(r: &Router, method: &str, path: &str, body: &str) -> (u16, Json) {
    let mut req = Request::synthetic(method, path);
    req.body = body.as_bytes().to_vec();
    let resp = r.dispatch(&req);
    let j = Json::parse(std::str::from_utf8(&resp.body).unwrap_or("null"))
        .unwrap_or(Json::Null);
    (resp.status, j)
}

const SPEC: &str = r#"{"meta":{"name":"mnist"},
    "spec":{"Worker":{"replicas":1,"resources":"cpu=1"}}}"#;

fn post_experiment(r: &Router, body: &str) -> String {
    let (st, j) = dispatch(r, "POST", "/api/v2/experiment", body);
    assert_eq!(st, 200, "{j:?}");
    j.at(&["result", "experimentId"])
        .unwrap()
        .as_str()
        .unwrap()
        .to_string()
}

// ------------------------------------------------------------ concurrency

#[test]
fn racing_if_match_puts_exactly_one_wins() {
    let r = Arc::new(api(Arc::new(MetaStore::in_memory())));
    let id = post_experiment(&r, SPEC);
    let (_, j) =
        dispatch(&r, "GET", &format!("/api/v2/experiment/{id}"), "");
    let rv = j
        .at(&["result", "meta", "resource_version"])
        .and_then(Json::as_u64)
        .unwrap();

    let put = |r: &Router, replicas: u32, rv: u64| -> u16 {
        let mut req = Request::synthetic(
            "PUT",
            &format!("/api/v2/experiment/{id}"),
        );
        req.body = format!(
            r#"{{"spec":{{"meta":{{"name":"mnist"}},
                "spec":{{"Worker":{{"replicas":{replicas},
                                    "resources":"cpu=1"}}}}}}}}"#
        )
        .into_bytes();
        req.headers
            .insert("if-match".into(), format!("\"{rv}\""));
        r.dispatch(&req).status
    };

    // two writers race with the same base revision: the storage layer
    // checks If-Match under the shard write lock, so exactly one wins
    let mut handles = Vec::new();
    for replicas in [2u32, 3u32] {
        let r = Arc::clone(&r);
        let id = id.clone();
        handles.push(std::thread::spawn(move || {
            let mut req = Request::synthetic(
                "PUT",
                &format!("/api/v2/experiment/{id}"),
            );
            req.body = format!(
                r#"{{"spec":{{"meta":{{"name":"mnist"}},
                    "spec":{{"Worker":{{"replicas":{replicas},
                                        "resources":"cpu=1"}}}}}}}}"#
            )
            .into_bytes();
            req.headers
                .insert("if-match".into(), format!("\"{rv}\""));
            r.dispatch(&req).status
        }));
    }
    let mut statuses: Vec<u16> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    statuses.sort_unstable();
    assert_eq!(statuses, vec![200, 412], "one winner, one loser");

    // the loser can rebase: re-read and retry with the fresh revision
    let (_, j) =
        dispatch(&r, "GET", &format!("/api/v2/experiment/{id}"), "");
    let fresh = j
        .at(&["result", "meta", "resource_version"])
        .and_then(Json::as_u64)
        .unwrap();
    assert!(fresh > rv);
    assert_eq!(put(&r, 5, fresh), 200);
}

#[test]
fn conditional_delete_and_create_conflict() {
    let r = api(Arc::new(MetaStore::in_memory()));
    // duplicate environment create is 409
    let env = r#"{"name":"tf","image":"i","dependencies":[]}"#;
    let (st, _) = dispatch(&r, "POST", "/api/v2/environment", env);
    assert_eq!(st, 200);
    let (st, j) = dispatch(&r, "POST", "/api/v2/environment", env);
    assert_eq!(st, 409, "{j:?}");
    // stale If-Match delete is 412; fresh one succeeds
    let (_, j) = dispatch(&r, "GET", "/api/v2/environment/tf", "");
    let rv = j
        .at(&["result", "meta", "resource_version"])
        .and_then(Json::as_u64)
        .unwrap();
    let del = |if_match: &str| -> u16 {
        let mut req =
            Request::synthetic("DELETE", "/api/v2/environment/tf");
        req.headers
            .insert("if-match".into(), if_match.to_string());
        r.dispatch(&req).status
    };
    assert_eq!(del(&format!("\"{}\"", rv + 999)), 412);
    assert_eq!(del(&format!("\"{rv}\"")), 200);
    let (st, _) = dispatch(&r, "GET", "/api/v2/environment/tf", "");
    assert_eq!(st, 404);
}

// ------------------------------------------------------------------ watch

#[test]
fn long_poll_watch_delivers_and_resumes_after_compaction() {
    // tiny feed so compaction is easy to trigger
    let store = Arc::new(MetaStore::in_memory_with(StoreOptions {
        feed_capacity: 4,
        ..StoreOptions::default()
    }));
    let r = api(store);
    let (_, j) = dispatch(&r, "GET", "/api/v2/experiment", "");
    let rv0 = j
        .at(&["result", "resource_version"])
        .and_then(Json::as_u64)
        .unwrap();
    let id = post_experiment(&r, SPEC);
    // watch from the pre-create bookmark sees the create event
    let (st, j) = dispatch(
        &r,
        "GET",
        &format!("/api/v2/experiment?watch=1&since={rv0}&timeout_ms=1000"),
        "",
    );
    assert_eq!(st, 200, "{j:?}");
    let events = j.at(&["result", "events"]).unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    assert_eq!(events[0].str_field("type"), Some("PUT"));
    assert_eq!(events[0].str_field("name"), Some(id.as_str()));
    assert_eq!(
        events[0].at(&["object", "status"]).and_then(Json::as_str),
        Some("Accepted")
    );
    let resume = j
        .at(&["result", "resource_version"])
        .and_then(Json::as_u64)
        .unwrap();
    assert!(resume > rv0);

    // overflow the feed: the old position is now 410 Gone
    for _ in 0..8 {
        post_experiment(&r, SPEC);
    }
    let (st, j) = dispatch(
        &r,
        "GET",
        &format!("/api/v2/experiment?watch=1&since={rv0}&timeout_ms=10"),
        "",
    );
    assert_eq!(st, 410, "{j:?}");
    assert_eq!(
        j.at(&["error", "type"]).and_then(Json::as_str),
        Some("Gone")
    );
    // the documented recovery: relist (fresh bookmark), then rewatch
    let (st, j) = dispatch(&r, "GET", "/api/v2/experiment", "");
    assert_eq!(st, 200);
    let fresh = j
        .at(&["result", "resource_version"])
        .and_then(Json::as_u64)
        .unwrap();
    assert_eq!(
        j.at(&["result", "total"]).and_then(Json::as_f64),
        Some(9.0)
    );
    let (st, j) = dispatch(
        &r,
        "GET",
        &format!(
            "/api/v2/experiment?watch=1&since={fresh}&timeout_ms=10"
        ),
        "",
    );
    assert_eq!(st, 200, "{j:?}");
    assert!(j
        .at(&["result", "events"])
        .unwrap()
        .as_arr()
        .unwrap()
        .is_empty());
}

#[test]
fn watch_validates_params_and_scopes_deletes() {
    let r = api(Arc::new(MetaStore::in_memory()));
    let (st, _) = dispatch(
        &r,
        "GET",
        "/api/v2/experiment?watch=1&since=abc",
        "",
    );
    assert_eq!(st, 400);
    // deletes surface as tombstone events
    let (_, j) = dispatch(&r, "GET", "/api/v2/experiment", "");
    let rv = j
        .at(&["result", "resource_version"])
        .and_then(Json::as_u64)
        .unwrap();
    let id = post_experiment(&r, SPEC);
    let (st, _) = dispatch(
        &r,
        "DELETE",
        &format!("/api/v2/experiment/{id}"),
        "",
    );
    assert_eq!(st, 200);
    let (st, j) = dispatch(
        &r,
        "GET",
        &format!("/api/v2/experiment?watch=1&since={rv}&timeout_ms=10"),
        "",
    );
    assert_eq!(st, 200);
    let events = j.at(&["result", "events"]).unwrap().as_arr().unwrap();
    let types: Vec<&str> = events
        .iter()
        .filter_map(|e| e.str_field("type"))
        .collect();
    // create (PUT), kill status write (PUT), tombstone (DELETE)
    assert!(types.contains(&"DELETE"), "{types:?}");
    assert_eq!(types.last(), Some(&"DELETE"));
}

// ------------------------------------------------------- selectors + meta

#[test]
fn label_selectors_walk_the_index() {
    let r = api(Arc::new(MetaStore::in_memory()));
    let labeled = |team: &str, tier: &str| -> String {
        format!(
            r#"{{"meta":{{"name":"m","labels":{{"team":"{team}",
                "tier":"{tier}"}}}},
                "spec":{{"Worker":{{"replicas":1,
                                    "resources":"cpu=1"}}}}}}"#
        )
    };
    post_experiment(&r, &labeled("vision", "prod"));
    post_experiment(&r, &labeled("vision", "dev"));
    post_experiment(&r, &labeled("nlp", "prod"));
    post_experiment(&r, SPEC); // unlabeled

    let total = |path: &str| -> f64 {
        let (st, j) = dispatch(&r, "GET", path, "");
        assert_eq!(st, 200, "{path}: {j:?}");
        j.at(&["result", "total"]).and_then(Json::as_f64).unwrap()
    };
    assert_eq!(total("/api/v2/experiment?label=team=vision"), 2.0);
    assert_eq!(
        total("/api/v2/experiment?label=team=vision,tier=prod"),
        1.0
    );
    assert_eq!(total("/api/v2/experiment?label=team=robotics"), 0.0);
    assert_eq!(total("/api/v2/experiment"), 4.0);
    // selector composes with the status index filter
    assert_eq!(
        total("/api/v2/experiment?label=team=vision&status=accepted"),
        2.0
    );
    // malformed selector is a 400
    let (st, _) =
        dispatch(&r, "GET", "/api/v2/experiment?label=oops", "");
    assert_eq!(st, 400);
    // selectors work on templates/environments too
    let (st, _) = dispatch(
        &r,
        "POST",
        "/api/v2/environment",
        r#"{"name":"e1","image":"i","dependencies":[],
            "labels":{"team":"vision"}}"#,
    );
    assert_eq!(st, 200);
    assert_eq!(total("/api/v2/environment?label=team=vision"), 1.0);
    assert_eq!(total("/api/v2/environment?label=team=nlp"), 0.0);
}

// --------------------------------------------------- transport envelopes

#[test]
fn transport_errors_pick_envelope_from_request_line() {
    let store = Arc::new(MetaStore::in_memory());
    let server = Arc::new(
        Server::bind_with_config(
            services_over(store),
            0,
            &ApiConfig::default(),
        )
        .unwrap(),
    );
    let port = server.port();
    let stop = server.stopper();
    let handle = Arc::clone(&server).serve_background();

    let roundtrip = |raw: &str| -> String {
        let mut stream =
            TcpStream::connect(("127.0.0.1", port)).unwrap();
        stream.write_all(raw.as_bytes()).unwrap();
        let _ = stream.shutdown(std::net::Shutdown::Write);
        let mut buf = String::new();
        let _ = stream.read_to_string(&mut buf);
        buf
    };
    // a v2 request line that fails to parse answers in the v2 envelope
    let v2 = roundtrip("GET /api/v2/experiment SPDY/9\r\n\r\n");
    assert!(v2.contains("400"), "{v2}");
    assert!(v2.contains(r#""code":400"#), "{v2}");
    assert!(v2.contains(r#""type":"InvalidSpec""#), "{v2}");
    // a v1 request line keeps the flat envelope
    let v1 = roundtrip("GET /api/v1/experiment SPDY/9\r\n\r\n");
    assert!(v1.contains("400"), "{v1}");
    assert!(!v1.contains(r#""code":400"#), "{v1}");
    assert!(v1.contains(r#""message""#), "{v1}");

    stop.store(true, Ordering::Relaxed);
    let _ = TcpStream::connect(("127.0.0.1", port));
    handle.join().unwrap();
}

// --------------------------------------------------------- SDK over TCP

struct TestServer {
    port: u16,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl TestServer {
    fn start(store: Arc<MetaStore>) -> TestServer {
        let server = Arc::new(
            Server::bind_with_config(
                services_over(store),
                0,
                &ApiConfig::default(),
            )
            .unwrap(),
        );
        let port = server.port();
        let stop = server.stopper();
        let handle = Arc::clone(&server).serve_background();
        TestServer {
            port,
            stop,
            handle: Some(handle),
        }
    }

    fn client(&self) -> ExperimentClient {
        ExperimentClient::v2("127.0.0.1", self.port)
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(("127.0.0.1", self.port));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[test]
fn sdk_update_if_and_patch_roundtrip() {
    let srv = TestServer::start(Arc::new(MetaStore::in_memory()));
    let client = srv.client();
    let spec = ExperimentSpec::parse(SPEC).unwrap();
    let id = client.create_experiment(&spec).unwrap();

    let doc = client.get_resource("experiment", &id).unwrap();
    let rv = doc
        .at(&["meta", "resource_version"])
        .and_then(Json::as_u64)
        .unwrap();
    // conditional update with the fresh revision wins
    let put_doc = Json::obj().set(
        "spec",
        Json::parse(
            r#"{"meta":{"name":"mnist"},
                "spec":{"Worker":{"replicas":2,"resources":"cpu=2"}}}"#,
        )
        .unwrap(),
    );
    let updated = client
        .update_if("experiment", &id, &put_doc, rv)
        .unwrap();
    let new_rv = updated
        .at(&["meta", "resource_version"])
        .and_then(Json::as_u64)
        .unwrap();
    assert!(new_rv > rv);
    // ...and the stale revision now surfaces as PreconditionFailed
    let err = client
        .update_if("experiment", &id, &put_doc, rv)
        .unwrap_err();
    assert!(
        matches!(
            err,
            submarine::SubmarineError::PreconditionFailed(_)
        ),
        "{err}"
    );
    // merge-patch labels, then find it by selector
    client
        .patch_resource(
            "experiment",
            &id,
            &Json::parse(r#"{"meta":{"labels":{"team":"vision"}}}"#)
                .unwrap(),
        )
        .unwrap();
    let res = client
        .list_resources("experiment", Some("team=vision"))
        .unwrap();
    assert_eq!(res.num_field("total"), Some(1.0));
}

#[test]
fn sdk_watcher_resyncs_after_compaction() {
    let store = Arc::new(MetaStore::in_memory_with(StoreOptions {
        feed_capacity: 4,
        ..StoreOptions::default()
    }));
    let srv = TestServer::start(store);
    let client = srv.client();
    let spec = ExperimentSpec::parse(SPEC).unwrap();
    for _ in 0..9 {
        client.create_experiment(&spec).unwrap();
    }
    // revision 1 has long been compacted: the watcher recovers with a
    // relist and resumes cleanly
    let mut w = client.watcher("experiment", 1).with_timeout_ms(500);
    match w.next().unwrap() {
        WatchStep::Resync(items) => assert_eq!(items.len(), 9),
        other => panic!("expected resync, got {other:?}"),
    }
    let resumed = w.since;
    assert!(resumed > 1);
    // new events flow normally after the resync
    let id = client.create_experiment(&spec).unwrap();
    match w.next().unwrap() {
        WatchStep::Events(events) => {
            assert!(events
                .iter()
                .any(|e| e.str_field("name") == Some(id.as_str())));
        }
        other => panic!("expected events, got {other:?}"),
    }
}

#[test]
fn chunked_stream_watch_over_tcp() {
    let srv = TestServer::start(Arc::new(MetaStore::in_memory()));
    let client = srv.client();
    let bookmark = client.resource_bookmark("experiment").unwrap();
    let spec = ExperimentSpec::parse(SPEC).unwrap();
    let id = client.create_experiment(&spec).unwrap();

    let mut stream =
        TcpStream::connect(("127.0.0.1", srv.port)).unwrap();
    write!(
        stream,
        "GET /api/v2/experiment?watch=1&stream=1&since={bookmark}\
         &timeout_ms=300 HTTP/1.1\r\nhost: x\r\n\r\n"
    )
    .unwrap();
    let mut buf = String::new();
    stream.read_to_string(&mut buf).unwrap(); // server closes at timeout
    assert!(buf.contains("transfer-encoding: chunked"), "{buf}");
    assert!(buf.contains(r#""type":"PUT""#), "{buf}");
    assert!(buf.contains(&id), "{buf}");
    assert!(buf.contains(r#""type":"BOOKMARK""#), "{buf}");
    // terminal zero-length chunk ends the stream
    assert!(buf.ends_with("0\r\n\r\n"), "{buf}");
}

// ------------------------------------------------- acceptance: execution

/// Full-stack acceptance: a watcher started at `since=REV` observes an
/// execution-engine-driven status transition (Accepted → Running →
/// Succeeded) **without a single status poll**.
#[test]
fn watcher_sees_engine_driven_transition_without_polling() {
    let sim =
        ClusterSim::homogeneous(2, Resources::new(16, 65536, 4), 2);
    let submitter = Arc::new(
        SimSubmitter::new(
            Box::new(YarnScheduler::new(QueueTree::flat())),
            sim,
            Arc::new(ExperimentMonitor::new()),
        )
        .with_container_duration(SimTime::from_millis(200)),
    );
    let services = Arc::new(Services::with_sim_executor(
        Arc::new(MetaStore::in_memory()),
        submitter,
        Arc::new(MetricStore::new()),
        EngineConfig {
            tick: std::time::Duration::from_millis(1),
            sim_step: SimTime::from_millis(50),
        },
    ));
    let server = Arc::new(
        Server::bind_with_config(services, 0, &ApiConfig::default())
            .unwrap(),
    );
    let port = server.port();
    let stop = server.stopper();
    let handle = Arc::clone(&server).serve_background();

    let client = ExperimentClient::v2("127.0.0.1", port);
    let since = client.resource_bookmark("experiment").unwrap();
    let spec = ExperimentSpec::parse(
        r#"{"meta":{"name":"watched"},
            "spec":{"Worker":{"replicas":2,
                              "resources":"cpu=1,gpu=1"}}}"#,
    )
    .unwrap();
    let id = client.create_experiment(&spec).unwrap();

    // only the watch stream from here on — no GET /experiment/:id
    let mut w =
        client.watcher("experiment", since).with_timeout_ms(2_000);
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(30);
    let mut seen: Vec<String> = Vec::new();
    while std::time::Instant::now() < deadline {
        match w.next().unwrap() {
            WatchStep::Events(events) => {
                for e in events {
                    if e.str_field("name") != Some(id.as_str()) {
                        continue;
                    }
                    if let Some(st) = e
                        .at(&["object", "status"])
                        .and_then(Json::as_str)
                    {
                        seen.push(st.to_string());
                    }
                }
            }
            WatchStep::Resync(_) => {
                panic!("feed compacted mid-test (capacity too small?)")
            }
        }
        if seen.iter().any(|s| s == "Succeeded") {
            break;
        }
    }
    assert!(
        seen.iter().any(|s| s == "Running"),
        "never saw Running: {seen:?}"
    );
    assert!(
        seen.iter().any(|s| s == "Succeeded"),
        "never saw Succeeded: {seen:?}"
    );

    stop.store(true, Ordering::Relaxed);
    let _ = TcpStream::connect(("127.0.0.1", port));
    handle.join().unwrap();
}

// ---------------------------------------------------------------- cursors

fn env_body(name: &str) -> String {
    format!(r#"{{"name":"{name}","image":"i","dependencies":[]}}"#)
}

fn env_names(j: &Json) -> Vec<String> {
    j.at(&["result", "items"])
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|i| i.as_str().unwrap().to_string())
        .collect()
}

/// Tentpole acceptance: a cursor walk never skips or duplicates a
/// surviving key, even with deletes of already-returned keys and
/// inserts on both sides of the cursor position between pages.
/// Environments key by name, which makes the expected page boundaries
/// exact.
#[test]
fn cursor_walk_is_stable_under_interleaved_writes() {
    let r = api(Arc::new(MetaStore::in_memory()));
    for i in 0..9 {
        let (st, j) = dispatch(
            &r,
            "POST",
            "/api/v2/environment",
            &env_body(&format!("e0{i}")),
        );
        assert_eq!(st, 200, "{j:?}");
    }

    let (st, j) = dispatch(&r, "GET", "/api/v2/environment?limit=3", "");
    assert_eq!(st, 200, "{j:?}");
    assert_eq!(env_names(&j), ["e00", "e01", "e02"]);
    let cur1 = j
        .at(&["result", "next_cursor"])
        .and_then(Json::as_str)
        .expect("full page mints a continuation cursor")
        .to_string();

    // interleave: delete an already-returned key, insert one key on
    // each side of the cursor position ("e015" < "e02" < "e025")
    let (st, _) =
        dispatch(&r, "DELETE", "/api/v2/environment/e01", "");
    assert_eq!(st, 200);
    for name in ["e015", "e025"] {
        let (st, _) = dispatch(
            &r,
            "POST",
            "/api/v2/environment",
            &env_body(name),
        );
        assert_eq!(st, 200);
    }

    // page 2 seeks past the cursor key: the insert behind the cursor
    // is not revisited, the insert ahead of it appears in order
    let (st, j) = dispatch(
        &r,
        "GET",
        &format!("/api/v2/environment?limit=3&cursor={cur1}"),
        "",
    );
    assert_eq!(st, 200, "{j:?}");
    assert_eq!(env_names(&j), ["e025", "e03", "e04"]);
    let cur2 = j
        .at(&["result", "next_cursor"])
        .and_then(Json::as_str)
        .unwrap()
        .to_string();

    let (st, j) = dispatch(
        &r,
        "GET",
        &format!("/api/v2/environment?limit=3&cursor={cur2}"),
        "",
    );
    assert_eq!(st, 200, "{j:?}");
    assert_eq!(env_names(&j), ["e05", "e06", "e07"]);
    let cur3 = j
        .at(&["result", "next_cursor"])
        .and_then(Json::as_str)
        .unwrap()
        .to_string();

    // final page is short, so no further cursor is minted
    let (st, j) = dispatch(
        &r,
        "GET",
        &format!("/api/v2/environment?limit=3&cursor={cur3}"),
        "",
    );
    assert_eq!(st, 200, "{j:?}");
    assert_eq!(env_names(&j), ["e08"]);
    assert!(j.at(&["result", "next_cursor"]).is_none());
}

#[test]
fn cursor_misuse_answers_410_or_400() {
    use submarine::httpd::cursor::{fingerprint, Cursor};
    let r = api(Arc::new(MetaStore::in_memory()));
    for name in ["a", "b", "c"] {
        let (st, _) = dispatch(
            &r,
            "POST",
            "/api/v2/environment",
            &env_body(name),
        );
        assert_eq!(st, 200);
    }
    let (st, j) =
        dispatch(&r, "GET", "/api/v2/environment?limit=2", "");
    assert_eq!(st, 200);
    let cur = j
        .at(&["result", "next_cursor"])
        .and_then(Json::as_str)
        .unwrap()
        .to_string();

    // reusing a cursor under a different query shape: the fingerprint
    // no longer matches, and the answer is the watch-style 410 relist
    // signal, not silently wrong pages
    let (st, j) = dispatch(
        &r,
        "GET",
        &format!("/api/v2/environment?limit=2&label=x=1&cursor={cur}"),
        "",
    );
    assert_eq!(st, 410, "{j:?}");

    // an anchor revision from the future (server restarted and lost
    // revisions) is also 410: the walk cannot be consistent
    let ahead = Cursor {
        rev: u64::MAX,
        fingerprint: fingerprint(&["environment"]),
        last_key: "a".into(),
    }
    .encode();
    let (st, j) = dispatch(
        &r,
        "GET",
        &format!("/api/v2/environment?limit=2&cursor={ahead}"),
        "",
    );
    assert_eq!(st, 410, "{j:?}");

    // malformed tokens were never minted by this server: 400, because
    // answering 410 would send well-behaved clients into relist loops
    let (st, _) = dispatch(
        &r,
        "GET",
        "/api/v2/environment?limit=2&cursor=garbage",
        "",
    );
    assert_eq!(st, 400);

    // cursor and offset are rival positioning schemes
    let (st, _) = dispatch(
        &r,
        "GET",
        &format!("/api/v2/environment?offset=1&cursor={cur}"),
        "",
    );
    assert_eq!(st, 400);

    // limit=0 historically meant "unlimited"; it is now rejected so
    // the cap is explicit
    let (st, _) =
        dispatch(&r, "GET", "/api/v2/environment?limit=0", "");
    assert_eq!(st, 400);

    // oversized limits clamp to the documented max instead of erroring
    let (st, _) =
        dispatch(&r, "GET", "/api/v2/environment?limit=999999", "");
    assert_eq!(st, 200);
}

/// SDK drain helpers against a live server: `list_all` follows
/// `next_cursor` to the end, and `stream_list` consumes the chunked
/// `?stream=1` drain — both must agree with each other and with the
/// seeded keys.
#[test]
fn sdk_list_all_and_stream_list_drain_everything() {
    let services = services_over(Arc::new(MetaStore::in_memory()));
    let server = Arc::new(
        Server::bind_with_config(services, 0, &ApiConfig::default())
            .unwrap(),
    );
    let port = server.port();
    let stop = server.stopper();
    let handle = Arc::clone(&server).serve_background();

    let client = ExperimentClient::v2("127.0.0.1", port);
    let mut want: Vec<String> = Vec::new();
    for i in 0..23 {
        let name = format!("env-{i:03}");
        let body = Json::parse(&env_body(&name)).unwrap();
        let (st, _) = client
            .request("POST", "/api/v2/environment", Some(&body))
            .unwrap();
        assert_eq!(st, 200);
        want.push(name);
    }

    // cursor drain: page size 5 forces 5 pages; items arrive in key
    // order with nothing lost or repeated
    let (items, rv) =
        client.list_all("environment", "", 5).unwrap();
    let got: Vec<String> = items
        .iter()
        .map(|i| i.as_str().unwrap().to_string())
        .collect();
    assert_eq!(got, want);
    assert!(rv > 0);

    // streamed drain: one request, every key exactly once, and the
    // done line's count agrees
    let mut streamed: Vec<String> = Vec::new();
    let done = client
        .stream_list("environment", "", &mut |key, _obj| {
            streamed.push(key.to_string());
        })
        .unwrap();
    assert_eq!(streamed, want);
    assert_eq!(
        done.num_field("count"),
        Some(want.len() as f64),
        "{done:?}"
    );
    assert!(done.num_field("resource_version").unwrap_or(0.0) > 0.0);

    stop.store(true, Ordering::Relaxed);
    let _ = TcpStream::connect(("127.0.0.1", port));
    handle.join().unwrap();
}
