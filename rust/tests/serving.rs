//! Integration tests for the online inference serving tier (ISSUE 9):
//! micro-batching over the epoll reactor (deadline flush of a partial
//! batch, inline full-batch flush under concurrent load), weighted
//! canary routing, 503 queue shedding in the v2 envelope, and stage
//! promotion hot-swapping the served version without dropping
//! in-flight requests.

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use submarine::experiment::spec::ExperimentSpec;
use submarine::httpd::http::Request;
use submarine::httpd::server::{build_router, Server, Services};
use submarine::model::Stage;
use submarine::orchestrator::Submitter;
use submarine::sdk::ExperimentClient;
use submarine::storage::MetaStore;
use submarine::util::json::Json;

struct NullSubmitter;
impl Submitter for NullSubmitter {
    fn name(&self) -> &'static str {
        "null"
    }
    fn submit(&self, _: &str, _: &ExperimentSpec) -> submarine::Result<()> {
        Ok(())
    }
    fn kill(&self, _: &str) -> submarine::Result<()> {
        Ok(())
    }
}

fn services() -> Arc<Services> {
    Arc::new(Services::new(
        Arc::new(MetaStore::in_memory()),
        Arc::new(NullSubmitter),
    ))
}

/// Register a 2-input / 1-output MLP (`sigmoid(w·x + b)`) and walk it
/// to the requested stage. Returns the registered version number.
fn register_mlp(s: &Services, bias: f32, stage: Stage) -> u32 {
    let params = vec![vec![1.0, -1.0], vec![bias]];
    let v = s.models.register("ctr", "exp-1", &params, &[]).unwrap();
    if stage == Stage::Staging || stage == Stage::Production {
        s.models.transition("ctr", v, Stage::Staging).unwrap();
    }
    if stage == Stage::Production {
        s.models.transition("ctr", v, Stage::Production).unwrap();
    }
    v
}

struct TestServer {
    port: u16,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl TestServer {
    fn start(svcs: Arc<Services>) -> TestServer {
        let server =
            Arc::new(Server::bind(svcs, 0, None).unwrap());
        let port = server.port();
        let stop = server.stopper();
        let handle = Arc::clone(&server).serve_background();
        TestServer {
            port,
            stop,
            handle: Some(handle),
        }
    }

    fn client(&self) -> ExperimentClient {
        ExperimentClient::v2("127.0.0.1", self.port)
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(("127.0.0.1", self.port));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn one_row_vals(a: f64, b: f64) -> Json {
    Json::Arr(vec![Json::obj().set(
        "vals",
        Json::Arr(vec![Json::Num(a), Json::Num(b)]),
    )])
}

// --------------------------------------------------- deadline flush

#[test]
fn deadline_flush_completes_a_partial_batch() {
    let svcs = services();
    register_mlp(&svcs, 0.25, Stage::Production);
    // batch of 8 never fills with one request; only the 50ms deadline
    // (driven by the reactor sweep stepping the parked tail) flushes it
    svcs.serving.set_knobs(8, 50, 256);
    let srv = TestServer::start(Arc::clone(&svcs));
    let client = srv.client();

    let res = client.predict("ctr", &one_row_vals(1.0, 0.0)).unwrap();
    assert_eq!(res.str_field("model"), Some("ctr"));
    assert_eq!(res.num_field("version"), Some(1.0));
    let preds = res.get("predictions").and_then(Json::as_arr).unwrap();
    assert_eq!(preds.len(), 1);
    // sigmoid(1*1 - 1*0 + 0.25) = sigmoid(1.25)
    let p = preds[0].as_f64().unwrap();
    assert!((p - 0.777_3).abs() < 1e-3, "{p}");

    let st = client.serving_status("ctr").unwrap();
    assert_eq!(st.get("loaded").and_then(Json::as_bool), Some(true));
    assert_eq!(st.num_field("primary_version"), Some(1.0));
    assert!(st.num_field("requests").unwrap() >= 1.0);
    assert!(st.num_field("batches").unwrap() >= 1.0);
}

// ------------------------------------------------- full-batch flush

#[test]
fn full_batch_flushes_inline_under_load() {
    let svcs = services();
    register_mlp(&svcs, 0.0, Stage::Production);
    // deadline is far away (10s): only the fourth arrival filling the
    // batch can complete these requests quickly
    svcs.serving.set_knobs(4, 10_000, 256);
    let srv = TestServer::start(Arc::clone(&svcs));
    let port = srv.port;

    let begin = Instant::now();
    let threads: Vec<_> = (0..4)
        .map(|i| {
            std::thread::spawn(move || {
                let client = ExperimentClient::v2("127.0.0.1", port);
                client
                    .predict("ctr", &one_row_vals(f64::from(i), 1.0))
                    .unwrap()
            })
        })
        .collect();
    for t in threads {
        let res = t.join().unwrap();
        let preds =
            res.get("predictions").and_then(Json::as_arr).unwrap();
        assert_eq!(preds.len(), 1);
    }
    // well under the 10s deadline: the batch flushed on fullness
    assert!(
        begin.elapsed() < Duration::from_secs(5),
        "batch did not flush inline: {:?}",
        begin.elapsed()
    );

    let st = srv.client().serving_status("ctr").unwrap();
    assert_eq!(st.num_field("requests"), Some(4.0));
    // all four rows went through one (or, under extreme scheduling
    // skew, at most a few) batched forward(s)
    assert!(st.num_field("batches").unwrap() <= 4.0);
}

// ---------------------------------------------------- canary routing

#[test]
fn canary_split_is_statistically_honored() {
    let svcs = services();
    register_mlp(&svcs, 0.25, Stage::Production); // v1
    register_mlp(&svcs, -0.25, Stage::Staging); // v2 (canary)
    svcs.serving.set_knobs(8, 10, 256);
    let srv = TestServer::start(Arc::clone(&svcs));
    let client = srv.client();

    // PATCH /api/v2/serve/ctr — 50/50 split between v1 and v2
    let cfg = client
        .patch_resource(
            "serve",
            "ctr",
            &Json::obj()
                .set("canary_version", Json::Num(2.0))
                .set("canary_weight", Json::Num(50.0)),
        )
        .unwrap();
    assert_eq!(cfg.num_field("canary_weight"), Some(50.0));

    let mut by_version = [0u32; 3];
    for _ in 0..40 {
        let res =
            client.predict("ctr", &one_row_vals(1.0, 0.0)).unwrap();
        let v = res.num_field("version").unwrap() as usize;
        assert!(v == 1 || v == 2, "unexpected version {v}");
        by_version[v] += 1;
    }
    // the stride router hands the canary exactly 50 of every 100
    // consecutive requests, interleaved; over 40 the split is 19/21
    assert_eq!(by_version[1] + by_version[2], 40);
    assert!(
        by_version[1] >= 15 && by_version[2] >= 15,
        "lopsided split: v1={} v2={}",
        by_version[1],
        by_version[2]
    );

    let st = client.serving_status("ctr").unwrap();
    assert_eq!(st.num_field("canary_version"), Some(2.0));
    assert_eq!(st.num_field("canary_weight"), Some(50.0));
}

// -------------------------------------------------------- shedding

#[test]
fn full_queue_sheds_503_in_v2_envelope() {
    let svcs = services();
    register_mlp(&svcs, 0.0, Stage::Production);
    // queue bound of 4 rows; a 5-row request cannot ever fit
    svcs.serving.set_knobs(8, 5_000, 4);

    // envelope shape, checked at the router level
    let router = build_router(Arc::clone(&svcs));
    let rows: Vec<Json> = (0..5)
        .map(|_| {
            Json::obj().set(
                "vals",
                Json::Arr(vec![Json::Num(1.0), Json::Num(0.0)]),
            )
        })
        .collect();
    let body = Json::obj().set("rows", Json::Arr(rows)).dump();
    let mut req = Request::synthetic("POST", "/api/v2/serve/ctr");
    req.body = body.clone().into_bytes();
    let resp = router.dispatch(&req);
    assert_eq!(resp.status, 503);
    let j = Json::parse(std::str::from_utf8(&resp.body).unwrap())
        .unwrap();
    assert_eq!(j.str_field("status"), Some("ERROR"));
    assert_eq!(j.num_field("code"), Some(503.0));
    assert_eq!(
        j.at(&["error", "type"]).and_then(Json::as_str),
        Some("ResourcesUnavailable")
    );

    // and end-to-end over TCP through the SDK
    let srv = TestServer::start(Arc::clone(&svcs));
    let rows_j = Json::parse(&body).unwrap();
    let err = srv
        .client()
        .predict("ctr", rows_j.get("rows").unwrap())
        .unwrap_err();
    assert!(err.to_string().contains("503"), "{err}");

    let st = srv.client().serving_status("ctr").unwrap();
    assert!(st.num_field("shed").unwrap() >= 1.0);
}

// -------------------------------------------------------- hot swap

#[test]
fn promotion_hot_swaps_without_dropping_inflight() {
    let svcs = services();
    register_mlp(&svcs, 0.25, Stage::Production); // v1
    register_mlp(&svcs, -0.25, Stage::Staging); // v2
    // long deadline so the first request is still parked when the
    // promotion lands mid-flight
    svcs.serving.set_knobs(8, 1_200, 256);
    let srv = TestServer::start(Arc::clone(&svcs));
    let port = srv.port;

    let parked = std::thread::spawn(move || {
        let client = ExperimentClient::v2("127.0.0.1", port);
        client.predict("ctr", &one_row_vals(1.0, 0.0)).unwrap()
    });
    // let the first request enqueue, then promote v2 over the API
    std::thread::sleep(Duration::from_millis(250));
    let client = srv.client();
    let doc = client
        .patch_resource(
            "model",
            "ctr/2",
            &Json::obj().set("stage", Json::Str("Production".into())),
        )
        .unwrap();
    assert_eq!(
        doc.str_field("stage"),
        Some("Production"),
        "{doc:?}"
    );

    // the in-flight request finishes on the version it was routed to
    let first = parked.join().unwrap();
    assert_eq!(first.num_field("version"), Some(1.0), "{first:?}");

    // new requests score on the promoted version
    let second =
        client.predict("ctr", &one_row_vals(1.0, 0.0)).unwrap();
    assert_eq!(second.num_field("version"), Some(2.0), "{second:?}");

    // the old Production version was archived by the promotion
    assert_eq!(s_stage(&svcs, 1), Stage::Archived);
    assert_eq!(s_stage(&svcs, 2), Stage::Production);
}

fn s_stage(svcs: &Services, version: u32) -> Stage {
    svcs.models.get("ctr", version).unwrap().stage
}
