//! Integration tests for the v2 REST surface over a real TCP socket:
//! auth, pagination, status filtering, the typed error envelope,
//! keep-alive connections, `Allow`/`HEAD` handling, and the v1 compat
//! shim — all driven through the SDK client (no PJRT artifacts needed).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use submarine::experiment::spec::ExperimentSpec;
use submarine::httpd::server::{Server, Services};
use submarine::httpd::ApiConfig;
use submarine::orchestrator::Submitter;
use submarine::sdk::ExperimentClient;
use submarine::storage::MetaStore;
use submarine::util::json::Json;

struct NullSubmitter;
impl Submitter for NullSubmitter {
    fn name(&self) -> &'static str {
        "null"
    }
    fn submit(&self, _: &str, _: &ExperimentSpec) -> submarine::Result<()> {
        Ok(())
    }
    fn kill(&self, _: &str) -> submarine::Result<()> {
        Ok(())
    }
}

fn services() -> Arc<Services> {
    Arc::new(Services::new(
        Arc::new(MetaStore::in_memory()),
        Arc::new(NullSubmitter),
    ))
}

struct TestServer {
    services: Arc<Services>,
    port: u16,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl TestServer {
    fn start(cfg: &ApiConfig) -> TestServer {
        let services = services();
        let server = Arc::new(
            Server::bind_with_config(Arc::clone(&services), 0, cfg)
                .unwrap(),
        );
        let port = server.port();
        let stop = server.stopper();
        let handle = Arc::clone(&server).serve_background();
        TestServer {
            services,
            port,
            stop,
            handle: Some(handle),
        }
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(("127.0.0.1", self.port));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn spec(name: &str) -> ExperimentSpec {
    ExperimentSpec::parse(&format!(
        r#"{{"meta":{{"name":"{name}"}},
            "spec":{{"Worker":{{"replicas":1,"resources":"cpu=1"}}}}}}"#
    ))
    .unwrap()
}

#[test]
fn v2_pagination_and_status_filtering_through_sdk() {
    let srv = TestServer::start(&ApiConfig::default());
    let client = ExperimentClient::v2("127.0.0.1", srv.port);

    let mut ids = Vec::new();
    for i in 0..5 {
        ids.push(client.create_experiment(&spec(&format!("e{i}"))).unwrap());
    }
    // full list
    let (rows, total) =
        client.list_experiments_paged(None, 0, None).unwrap();
    assert_eq!(total, 5);
    assert_eq!(rows.len(), 5);
    // a window
    let (rows, total) =
        client.list_experiments_paged(Some(2), 1, None).unwrap();
    assert_eq!(total, 5);
    assert_eq!(rows.len(), 2);
    // status filter: kill one, then filter by Killed (case-insensitive)
    client.kill(&ids[0]).unwrap();
    let (rows, total) = client
        .list_experiments_paged(None, 0, Some("killed"))
        .unwrap();
    assert_eq!(total, 1);
    assert_eq!(rows[0].0, ids[0]);
    assert_eq!(rows[0].1, "Killed");
    let (_, accepted) = client
        .list_experiments_paged(None, 0, Some("Accepted"))
        .unwrap();
    assert_eq!(accepted, 4);
}

#[test]
fn v1_compat_shim_still_answers() {
    let srv = TestServer::start(&ApiConfig::default());
    let v1 = ExperimentClient::new("127.0.0.1", srv.port);
    assert_eq!(v1.api_base(), "/api/v1");
    let id = v1.create_experiment(&spec("compat")).unwrap();
    let rows = v1.list_experiments().unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].0, id);
    assert_eq!(
        v1.status(&id).unwrap(),
        submarine::experiment::spec::ExperimentStatus::Accepted
    );
    // raw v1 response keeps the flat envelope (no `code` field)
    let (st, j) = v1.request("GET", "/api/v1/experiment", None).unwrap();
    assert_eq!(st, 200);
    assert_eq!(j.str_field("status"), Some("OK"));
    assert!(j.get("code").is_none());
    assert!(j.get("result").unwrap().as_arr().is_some());
}

#[test]
fn auth_is_enforced_with_typed_error() {
    let cfg = ApiConfig {
        auth_token: Some("sekrit".into()),
        rate_limit: None,
    };
    let srv = TestServer::start(&cfg);
    let anon = ExperimentClient::v2("127.0.0.1", srv.port);
    let err = anon.list_experiments().unwrap_err().to_string();
    assert!(err.contains("401"), "{err}");
    assert!(err.contains("missing or bad token"), "{err}");
    // the raw body carries the structured error object
    let (st, j) = anon.request("GET", "/api/v2/cluster", None).unwrap();
    assert_eq!(st, 401);
    assert_eq!(
        j.at(&["error", "type"]).and_then(Json::as_str),
        Some("Unauthorized")
    );
    let authed =
        ExperimentClient::v2("127.0.0.1", srv.port).with_token("sekrit");
    assert!(authed.list_experiments().is_ok());
}

#[test]
fn v2_error_envelope_on_bad_input() {
    let srv = TestServer::start(&ApiConfig::default());
    let client = ExperimentClient::v2("127.0.0.1", srv.port);
    let (st, j) = client
        .request("POST", "/api/v2/experiment", Some(&Json::obj()))
        .unwrap();
    assert_eq!(st, 400);
    assert_eq!(j.str_field("status"), Some("ERROR"));
    assert_eq!(j.num_field("code"), Some(400.0));
    assert!(
        j.at(&["error", "type"]).and_then(Json::as_str).is_some(),
        "{j:?}"
    );
    assert!(
        j.at(&["error", "message"]).and_then(Json::as_str).is_some(),
        "{j:?}"
    );
    // unknown routes are typed too
    let (st, j) = client.request("GET", "/api/v2/nope", None).unwrap();
    assert_eq!(st, 404);
    assert_eq!(
        j.at(&["error", "type"]).and_then(Json::as_str),
        Some("NotFound")
    );
}

#[test]
fn sdk_reuses_one_connection_across_requests() {
    let srv = TestServer::start(&ApiConfig::default());
    let client = ExperimentClient::v2("127.0.0.1", srv.port);
    for _ in 0..10 {
        let (st, _) =
            client.request("GET", "/api/v2/cluster", None).unwrap();
        assert_eq!(st, 200);
    }
    // per-route middleware metrics saw all 10 requests
    let series = srv.services.metrics.series(
        submarine::httpd::middleware::HTTP_METRICS_KEY,
        "GET /api/v2/cluster",
    );
    assert_eq!(series.len(), 10);
}

/// Read one content-length-framed response off a raw socket.
fn read_response(reader: &mut BufReader<&TcpStream>) -> (u16, String, Vec<String>) {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let status: u16 = line.split(' ').nth(1).unwrap().parse().unwrap();
    let mut headers = Vec::new();
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        let h = h.trim_end().to_string();
        if h.is_empty() {
            break;
        }
        let lower = h.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("content-length:") {
            len = v.trim().parse().unwrap();
        }
        headers.push(h);
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap(), headers)
}

#[test]
fn keep_alive_head_and_allow_over_raw_socket() {
    let srv = TestServer::start(&ApiConfig::default());
    let stream = TcpStream::connect(("127.0.0.1", srv.port)).unwrap();
    let mut reader = BufReader::new(&stream);

    // two requests on one connection
    for _ in 0..2 {
        write!(&stream, "GET /api/v2/cluster HTTP/1.1\r\nhost: x\r\n\r\n")
            .unwrap();
        let (st, body, headers) = read_response(&mut reader);
        assert_eq!(st, 200);
        assert!(body.contains("RUNNING"));
        assert!(headers
            .iter()
            .any(|h| h.to_ascii_lowercase()
                == "connection: keep-alive"));
    }

    // HEAD: headers advertise the GET body length, but no body follows
    write!(&stream, "HEAD /api/v2/cluster HTTP/1.1\r\nhost: x\r\n\r\n")
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("200"), "{line}");
    let mut advertised = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        let h = h.trim_end().to_ascii_lowercase();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.strip_prefix("content-length:") {
            advertised = v.trim().parse().unwrap();
        }
    }
    assert!(advertised > 0);

    // 405 with an Allow header (no body was sent after HEAD, so the
    // stream is positioned at the next response)
    write!(
        &stream,
        "DELETE /api/v2/cluster HTTP/1.1\r\nhost: x\r\n\r\n"
    )
    .unwrap();
    let (st, body, headers) = read_response(&mut reader);
    assert_eq!(st, 405);
    assert!(
        headers.iter().any(|h| h == "Allow: GET, HEAD"),
        "{headers:?}"
    );
    let j = Json::parse(&body).unwrap();
    assert_eq!(
        j.at(&["error", "type"]).and_then(Json::as_str),
        Some("MethodNotAllowed")
    );
}
