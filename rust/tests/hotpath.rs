//! Integration tests for the serving hot path (ISSUE 5, reworked for
//! the ISSUE 7 reactor): saturation behavior (parked watchers must not
//! starve request workers; the 503 shed still triggers at the
//! connection cap), C10k+ watch fan-out on the epoll reactor, the HEAD
//! fast path over the cached encoded body, and the `Arc<Doc>`
//! no-torn-reads guarantee under racing conditional writers.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;
use submarine::experiment::spec::ExperimentSpec;
use submarine::httpd::server::{Server, ServerOptions, Services};
use submarine::httpd::ApiConfig;
use submarine::orchestrator::Submitter;
use submarine::storage::MetaStore;
use submarine::util::json::Json;

struct NullSubmitter;
impl Submitter for NullSubmitter {
    fn name(&self) -> &'static str {
        "null"
    }
    fn submit(&self, _: &str, _: &ExperimentSpec) -> submarine::Result<()> {
        Ok(())
    }
    fn kill(&self, _: &str) -> submarine::Result<()> {
        Ok(())
    }
}

fn services() -> Arc<Services> {
    Arc::new(Services::new(
        Arc::new(MetaStore::in_memory()),
        Arc::new(NullSubmitter),
    ))
}

fn start_with(
    opts: ServerOptions,
) -> (u16, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
    let srv = Arc::new(
        Server::bind_with_options(
            services(),
            0,
            &ApiConfig::default(),
            opts,
        )
        .unwrap(),
    );
    let port = srv.port();
    let stop = srv.stopper();
    let handle = srv.serve_background();
    (port, stop, handle)
}

fn shutdown(
    port: u16,
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<()>,
) {
    stop.store(true, Ordering::Relaxed);
    let _ = TcpStream::connect(("127.0.0.1", port));
    handle.join().unwrap();
}

/// Read one content-length-framed response off a stream.
fn read_response(stream: &TcpStream) -> (u16, Vec<String>, Vec<u8>) {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let status: u16 = line.split(' ').nth(1).unwrap().parse().unwrap();
    let mut headers = Vec::new();
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        let h = h.trim_end().to_string();
        if h.is_empty() {
            break;
        }
        if let Some(v) =
            h.to_ascii_lowercase().strip_prefix("content-length:")
        {
            len = v.trim().parse().unwrap();
        }
        headers.push(h);
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).unwrap();
    (status, headers, body)
}

fn plain_get(port: u16, path: &str) -> (u16, Vec<String>, Vec<u8>) {
    let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    write!(
        &stream,
        "GET {path} HTTP/1.1\r\nhost: x\r\nconnection: close\r\n\r\n"
    )
    .unwrap();
    read_response(&stream)
}

/// With a 2-worker pool and more open watch connections than workers,
/// plain GETs must still complete: watch requests migrate off the pool
/// onto their dedicated lane the moment they are recognized.
#[test]
fn parked_watchers_do_not_starve_request_workers() {
    let (port, stop, handle) = start_with(ServerOptions {
        workers: Some(2),
        max_connections: 32,
        ..Default::default()
    });

    // 3 long-polls + 1 chunked stream, all parked for several seconds
    let mut watchers = Vec::new();
    for i in 0..4 {
        let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        let extra = if i == 3 { "&stream=1" } else { "" };
        write!(
            &stream,
            "GET /api/v2/experiment?watch=1&timeout_ms=4000{extra} \
             HTTP/1.1\r\nhost: x\r\n\r\n"
        )
        .unwrap();
        watchers.push(stream);
    }
    // give the pool a moment to pick all four up (and migrate them)
    std::thread::sleep(Duration::from_millis(300));

    // every request worker would be occupied if watchers pinned them;
    // these must answer promptly anyway
    for _ in 0..3 {
        let (status, _, body) = plain_get(port, "/api/v2/cluster");
        assert_eq!(status, 200);
        assert!(!body.is_empty());
    }

    drop(watchers);
    shutdown(port, stop, handle);
}

/// The C10k claim, end to end: hold 10k concurrently open `?watch=1`
/// chunked streams as parked reactor entries (no thread each), publish
/// one event, and assert every watcher's stream carries it — while
/// plain GETs keep being serviced by the 2-worker pool throughout.
/// `SUBMARINE_FANOUT_WATCHERS` overrides the watcher count (the TSan
/// job shrinks it); the count also self-caps to the fd budget
/// `raise_nofile_limit` can actually obtain.
#[test]
fn fanout_10k_watchers_all_receive_event_and_gets_stay_serviced() {
    let want: usize = std::env::var("SUBMARINE_FANOUT_WATCHERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10_000);
    // each watcher costs two fds in this process (client + server end)
    let effective = submarine::httpd::reactor::raise_nofile_limit(
        (want as u64) * 2 + 1024,
    );
    let budget = ((effective.saturating_sub(1024)) / 2) as usize;
    let n = want.min(budget).max(1);
    if n < want {
        eprintln!(
            "fanout: fd limit {effective} caps watchers at {n} \
             (wanted {want})"
        );
    }

    let (port, stop, handle) = start_with(ServerOptions {
        workers: Some(2),
        max_connections: n + 64,
        ..Default::default()
    });

    // `since=0` pins the cursor before any event, so a watcher
    // registered after the POST still sees it — no startup race.
    let mut watchers = Vec::with_capacity(n);
    for _ in 0..n {
        let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .unwrap();
        write!(
            &stream,
            "GET /api/v2/template?watch=1&stream=1&since=0&\
             timeout_ms=30000 HTTP/1.1\r\nhost: x\r\n\r\n"
        )
        .unwrap();
        watchers.push(stream);
    }

    // plain GETs answered while all watchers are parked
    for _ in 0..10 {
        let (status, _, _) = plain_get(port, "/api/v2/cluster");
        assert_eq!(status, 200);
    }

    // one event, fanned out to every parked stream
    let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    let body = r#"{"name":"t-fan","experimentSpec":{"meta":{"name":"m"},
        "spec":{"Worker":{"replicas":1,"resources":"cpu=1"}}}}"#;
    write!(
        &stream,
        "POST /api/v2/template HTTP/1.1\r\nhost: x\r\n\
         content-length: {}\r\nconnection: close\r\n\r\n{}",
        body.len(),
        body
    )
    .unwrap();
    let (status, _, _) = read_response(&stream);
    assert_eq!(status, 200);

    // plain GETs still answered while the fan-out is in flight
    for _ in 0..10 {
        let (status, _, _) = plain_get(port, "/api/v2/cluster");
        assert_eq!(status, 200);
    }

    // every watcher's chunked stream carries the PUT event
    for (i, w) in watchers.iter().enumerate() {
        let mut reader = BufReader::with_capacity(1024, w);
        let mut saw_event = false;
        let mut line = String::new();
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) => break,
                Ok(_) => {
                    if line.contains("\"t-fan\"") {
                        saw_event = true;
                        break;
                    }
                }
                Err(e) => panic!("watcher {i}: read error: {e}"),
            }
        }
        assert!(saw_event, "watcher {i} never saw the event");
    }

    drop(watchers);
    shutdown(port, stop, handle);
}

/// Past `max_connections` live connections the server sheds with a
/// prompt 503 instead of queueing.
#[test]
fn shed_path_still_triggers_at_connection_cap() {
    let (port, stop, handle) = start_with(ServerOptions {
        workers: Some(2),
        max_connections: 6,
        ..Default::default()
    });

    // fill the cap: 4 parked watchers + 2 idle keep-alive connections
    let mut held = Vec::new();
    for _ in 0..4 {
        let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        write!(
            &stream,
            "GET /api/v2/experiment?watch=1&timeout_ms=4000 \
             HTTP/1.1\r\nhost: x\r\n\r\n"
        )
        .unwrap();
        held.push(stream);
    }
    for _ in 0..2 {
        held.push(TcpStream::connect(("127.0.0.1", port)).unwrap());
    }
    std::thread::sleep(Duration::from_millis(300));

    // one over the cap: 503 in the flat v1 envelope, then close
    let over = TcpStream::connect(("127.0.0.1", port)).unwrap();
    over.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = String::new();
    let _ = (&over).read_to_string(&mut buf);
    assert!(buf.contains("503"), "expected shed, got: {buf}");
    assert!(buf.contains("connection capacity"), "{buf}");

    drop(held);
    shutdown(port, stop, handle);
}

/// HEAD on a cached-body resource advertises exactly the GET body's
/// length without a body following, and repeat GETs serve identical
/// bytes and ETags from the revision-keyed cache.
#[test]
fn head_advertises_cached_body_length() {
    let (port, stop, handle) =
        start_with(ServerOptions::default());

    let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    let body = r#"{"name":"t1","experimentSpec":{"meta":{"name":"m"},
        "spec":{"Worker":{"replicas":1,"resources":"cpu=1"}}}}"#;
    write!(
        &stream,
        "POST /api/v2/template HTTP/1.1\r\nhost: x\r\n\
         content-length: {}\r\nconnection: close\r\n\r\n{}",
        body.len(),
        body
    )
    .unwrap();
    let (status, _, _) = read_response(&stream);
    assert_eq!(status, 200);

    let (status, headers, get_body) =
        plain_get(port, "/api/v2/template/t1");
    assert_eq!(status, 200);
    let etag_of = |headers: &[String]| {
        headers
            .iter()
            .find(|h| h.to_ascii_lowercase().starts_with("etag:"))
            .cloned()
    };
    let get_etag = etag_of(&headers);
    assert!(get_etag.is_some(), "{headers:?}");
    // body is the enveloped stored doc
    let j = Json::parse(std::str::from_utf8(&get_body).unwrap()).unwrap();
    assert_eq!(
        j.at(&["result", "name"]).and_then(Json::as_str),
        Some("t1")
    );

    // repeat GET: identical bytes (served from the cache)
    let (_, headers2, get_body2) =
        plain_get(port, "/api/v2/template/t1");
    assert_eq!(get_body, get_body2);
    assert_eq!(get_etag, etag_of(&headers2));

    // HEAD: same content-length, no body
    let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    write!(
        &stream,
        "HEAD /api/v2/template/t1 HTTP/1.1\r\nhost: x\r\n\
         connection: close\r\n\r\n"
    )
    .unwrap();
    let mut reader = BufReader::new(&stream);
    let mut head_text = String::new();
    reader.read_to_string(&mut head_text).unwrap();
    assert!(head_text.contains("200 OK"), "{head_text}");
    assert!(
        head_text
            .to_ascii_lowercase()
            .contains(&format!("content-length: {}", get_body.len())),
        "HEAD must advertise the GET body length: {head_text}"
    );
    assert!(head_text.trim_end().ends_with("connection: close"));

    shutdown(port, stop, handle);
}

/// Readers holding `Arc<Doc>` handles race a conditional writer that
/// replaces the document thousands of times: no reader may ever
/// observe a half-written ("torn") document.
#[test]
fn arc_reads_racing_writers_never_observe_torn_documents() {
    let store = Arc::new(MetaStore::in_memory());
    let pair = |i: u64| {
        Json::obj()
            .set("a", Json::Num(i as f64))
            .set("b", Json::Num(i as f64))
            .set("pad", Json::Str("x".repeat(256)))
    };
    store.put("ns", "doc", pair(0)).unwrap();
    let done = Arc::new(AtomicBool::new(false));

    let writer = {
        let store = Arc::clone(&store);
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            for i in 1..=2_000u64 {
                store
                    .update_rev("ns", "doc", |_, _| Ok(Some(pair(i))))
                    .unwrap();
            }
            done.store(true, Ordering::Relaxed);
        })
    };
    let readers: Vec<_> = (0..4)
        .map(|_| {
            let store = Arc::clone(&store);
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                let mut seen = 0u64;
                while !done.load(Ordering::Relaxed) {
                    let d = store.get("ns", "doc").unwrap();
                    let a = d.num_field("a").unwrap();
                    let b = d.num_field("b").unwrap();
                    assert_eq!(
                        a, b,
                        "torn document observed: a={a} b={b}"
                    );
                    // the cached encoding is torn-free too
                    let enc = d.encoded();
                    let parsed = Json::parse(
                        std::str::from_utf8(&enc).unwrap(),
                    )
                    .unwrap();
                    assert_eq!(
                        parsed.num_field("a"),
                        parsed.num_field("b")
                    );
                    seen += 1;
                }
                seen
            })
        })
        .collect();
    writer.join().unwrap();
    for r in readers {
        assert!(r.join().unwrap() > 0);
    }
    // monotone final state
    assert_eq!(
        store.get("ns", "doc").unwrap().num_field("a"),
        Some(2_000.0)
    );
}
