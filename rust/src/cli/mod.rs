//! Command-line interface (paper §3.1.1, Listing 1).
//!
//! ```text
//! submarine server   [--port 8080] [--artifacts DIR] [--token T]
//! submarine job run  --name mnist --framework TensorFlow \
//!                    --num_workers 4 \
//!                    --worker_resources memory=4G,gpu=4,vcores=4 \
//!                    --num_ps 1 --ps_resources memory=2G,vcores=2 \
//!                    --worker_launch_cmd "python mnist.py" \
//!                    [--model mnist_mlp --steps 100 --lr 0.05] \
//!                    [--server 127.0.0.1:8080]
//! submarine experiment list|get <id>|kill <id> [--server ...]
//! submarine template submit <name> -P key=value ... [--server ...]
//! ```

use crate::cluster::Resources;
use crate::experiment::spec::{
    EnvironmentRef, ExperimentMeta, ExperimentSpec, TaskSpec, WorkloadSpec,
};
use crate::sdk::ExperimentClient;
use std::collections::BTreeMap;

/// Parsed flag map: `--key value` plus positionals.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    /// Repeated `-P key=value` template parameters.
    pub params: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> crate::Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "-P" {
                let kv = argv.get(i + 1).ok_or_else(|| {
                    bad("-P requires key=value")
                })?;
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| bad("-P requires key=value"))?;
                out.params.insert(k.to_string(), v.to_string());
                i += 2;
            } else if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    i += 1;
                } else if matches!(
                    name,
                    "insecure" | "verbose" | "once" | "all" | "stream"
                ) {
                    out.flags.insert(name.to_string(), "true".into());
                    i += 1;
                } else {
                    let v = argv.get(i + 1).ok_or_else(|| {
                        bad(&format!("--{name} requires a value"))
                    })?;
                    out.flags.insert(name.to_string(), v.clone());
                    i += 2;
                }
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    /// True when a boolean flag (`--all`, `--stream`, ...) was given.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn server(&self) -> (String, u16) {
        let addr = self.flag("server").unwrap_or("127.0.0.1:8080");
        match addr.rsplit_once(':') {
            Some((h, p)) => {
                (h.to_string(), p.parse().unwrap_or(8080))
            }
            None => (addr.to_string(), 8080),
        }
    }
}

fn bad(msg: &str) -> crate::SubmarineError {
    crate::SubmarineError::InvalidSpec(msg.to_string())
}

/// Build the REST client from `--server` / `--api` / `--token` flags
/// (defaults to the typed `/api/v2` surface; `--api v1` targets old
/// servers).
fn client_from_flags(args: &Args) -> crate::Result<ExperimentClient> {
    let (host, port) = args.server();
    let mut client = match args.flag("api").unwrap_or("v2") {
        "v1" => ExperimentClient::new(&host, port),
        "v2" => ExperimentClient::v2(&host, port),
        other => return Err(bad(&format!("unknown --api {other:?}"))),
    };
    if let Some(t) = args.flag("token") {
        client = client.with_token(t);
    }
    Ok(client)
}

/// Build an [`ExperimentSpec`] from Listing-1 style `job run` flags.
pub fn spec_from_job_flags(args: &Args) -> crate::Result<ExperimentSpec> {
    let name = args
        .flag("name")
        .ok_or_else(|| bad("--name is required"))?
        .to_string();
    let mut tasks = Vec::new();
    let num_ps: u32 = args
        .flag("num_ps")
        .map(|v| v.parse().map_err(|_| bad("bad --num_ps")))
        .transpose()?
        .unwrap_or(0);
    if num_ps > 0 {
        tasks.push((
            "Ps".to_string(),
            TaskSpec {
                replicas: num_ps,
                resources: Resources::parse(
                    args.flag("ps_resources").unwrap_or("cpu=1,memory=1G"),
                )?,
            },
        ));
    }
    let num_workers: u32 = args
        .flag("num_workers")
        .map(|v| v.parse().map_err(|_| bad("bad --num_workers")))
        .transpose()?
        .unwrap_or(1);
    tasks.push((
        "Worker".to_string(),
        TaskSpec {
            replicas: num_workers.max(1),
            resources: Resources::parse(
                args.flag("worker_resources")
                    .unwrap_or("cpu=1,memory=1G"),
            )?,
        },
    ));
    let workload = args.flag("model").map(|m| WorkloadSpec {
        model: m.to_string(),
        steps: args
            .flag("steps")
            .and_then(|v| v.parse().ok())
            .unwrap_or(100),
        lr: args
            .flag("lr")
            .and_then(|v| v.parse().ok())
            .unwrap_or(0.05),
        seed: args
            .flag("seed")
            .and_then(|v| v.parse().ok())
            .unwrap_or(42),
    });
    Ok(ExperimentSpec {
        meta: ExperimentMeta {
            name,
            namespace: args
                .flag("namespace")
                .unwrap_or("default")
                .to_string(),
            framework: args
                .flag("framework")
                .unwrap_or("TensorFlow")
                .to_string(),
            cmd: args
                .flag("worker_launch_cmd")
                .unwrap_or("")
                .to_string(),
        },
        environment: EnvironmentRef {
            image: args.flag("image").unwrap_or("").to_string(),
            name: None,
        },
        tasks,
        queue: args.flag("queue").unwrap_or("root").to_string(),
        workload,
    })
}

/// Entry point used by `main.rs`. Returns the process exit code.
pub fn run(argv: &[String]) -> i32 {
    match dispatch(argv) {
        Ok(msg) => {
            if !msg.is_empty() {
                println!("{msg}");
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    }
}

fn dispatch(argv: &[String]) -> crate::Result<String> {
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "help" | "--help" | "-h" => Ok(usage()),
        "version" => Ok(format!("submarine {}", crate::version())),
        "server" => {
            let args = Args::parse(&argv[1..])?;
            serve(&args)
        }
        "job" if argv.get(1).map(String::as_str) == Some("run") => {
            let args = Args::parse(&argv[2..])?;
            let spec = spec_from_job_flags(&args)?;
            let client = client_from_flags(&args)?;
            let id = client.create_experiment(&spec)?;
            Ok(format!("submitted {id}"))
        }
        "experiment" => {
            let sub = argv.get(1).map(String::as_str).unwrap_or("list");
            let args = Args::parse(argv.get(2..).unwrap_or(&[]))?;
            let client = client_from_flags(&args)?;
            match sub {
                "list" => {
                    if args.has_flag("stream") {
                        // one-request full drain over ?stream=1; the
                        // server forbids composing it with filters or
                        // paging, so reject those combinations here
                        // with a CLI-shaped message
                        if args.flag("api") == Some("v1") {
                            return Err(bad("--stream needs --api v2"));
                        }
                        if args.has_flag("all") {
                            return Err(bad(
                                "--stream and --all are mutually \
                                 exclusive drain modes",
                            ));
                        }
                        for f in
                            ["selector", "status", "limit", "offset"]
                        {
                            if args.flag(f).is_some() {
                                return Err(bad(&format!(
                                    "--stream drains everything; \
                                     --{f} does not compose with it \
                                     (use --all for filtered drains)"
                                )));
                            }
                        }
                        let mut out = String::new();
                        let done = client.stream_list(
                            "experiment",
                            "",
                            &mut |key, obj| {
                                let state = obj
                                    .str_field("status")
                                    .unwrap_or("-");
                                out.push_str(&format!(
                                    "{key}\t{state}\n"
                                ));
                            },
                        )?;
                        out.push_str(&format!(
                            "({} experiments @ resource_version {})\n",
                            done.num_field("count").unwrap_or(0.0),
                            done.num_field("resource_version")
                                .unwrap_or(0.0),
                        ));
                        return Ok(out);
                    }
                    if args.has_flag("all") {
                        // cursor-paged full drain; composes with
                        // --selector/--status, and --limit becomes the
                        // page size instead of a result cap
                        if args.flag("api") == Some("v1") {
                            return Err(bad("--all needs --api v2"));
                        }
                        if args.flag("offset").is_some() {
                            return Err(bad(
                                "--all walks by cursor; --offset does \
                                 not compose with it",
                            ));
                        }
                        let page_size = match args.flag("limit") {
                            Some(v) => v.parse().map_err(|_| {
                                bad(&format!("bad --limit {v:?}"))
                            })?,
                            None => 500,
                        };
                        let mut query = String::new();
                        if let Some(sel) = args.flag("selector") {
                            query.push_str(&format!("label={sel}"));
                        }
                        if let Some(st) = args.flag("status") {
                            if !query.is_empty() {
                                query.push('&');
                            }
                            query.push_str(&format!("status={st}"));
                        }
                        let (items, rv) = client.list_all(
                            "experiment",
                            &query,
                            page_size,
                        )?;
                        let mut out = String::new();
                        for obj in &items {
                            let name = obj
                                .str_field("experimentId")
                                .unwrap_or("?");
                            let state = obj
                                .str_field("status")
                                .unwrap_or("-");
                            out.push_str(&format!(
                                "{name}\t{state}\n"
                            ));
                        }
                        out.push_str(&format!(
                            "({} experiments @ resource_version {rv})\n",
                            items.len()
                        ));
                        return Ok(out);
                    }
                    if let Some(sel) = args.flag("selector") {
                        // label selectors are a v2 resource feature;
                        // --status/--limit/--offset compose with them
                        if args.flag("api") == Some("v1") {
                            return Err(bad(
                                "--selector needs --api v2",
                            ));
                        }
                        let mut query = format!("label={sel}");
                        if let Some(st) = args.flag("status") {
                            query.push_str(&format!("&status={st}"));
                        }
                        for flag in ["limit", "offset"] {
                            if let Some(v) = args.flag(flag) {
                                let n: usize =
                                    v.parse().map_err(|_| {
                                        bad(&format!(
                                            "bad --{flag} {v:?}"
                                        ))
                                    })?;
                                query.push_str(&format!(
                                    "&{flag}={n}"
                                ));
                            }
                        }
                        let res = client.list_resources_query(
                            "experiment",
                            &query,
                        )?;
                        return Ok(format_resource_list(&res));
                    }
                    let paged = args.flag("limit").is_some()
                        || args.flag("offset").is_some()
                        || args.flag("status").is_some();
                    if paged && args.flag("api") == Some("v1") {
                        // the v1 surface ignores these params; erroring
                        // beats silently presenting unfiltered data
                        return Err(bad(
                            "--limit/--offset/--status need --api v2",
                        ));
                    }
                    let (rows, total) = if paged {
                        let limit = args
                            .flag("limit")
                            .map(|v| {
                                v.parse().map_err(|_| bad("bad --limit"))
                            })
                            .transpose()?;
                        let offset = args
                            .flag("offset")
                            .map(|v| {
                                v.parse().map_err(|_| bad("bad --offset"))
                            })
                            .transpose()?
                            .unwrap_or(0);
                        client.list_experiments_paged(
                            limit,
                            offset,
                            args.flag("status"),
                        )?
                    } else {
                        let rows = client.list_experiments()?;
                        let total = rows.len();
                        (rows, total)
                    };
                    let mut out = String::new();
                    for (id, st) in &rows {
                        out.push_str(&format!("{id}\t{st}\n"));
                    }
                    if paged {
                        out.push_str(&format!(
                            "({} of {total} experiments)\n",
                            rows.len()
                        ));
                    }
                    Ok(out)
                }
                "get" => {
                    let id = args
                        .positional
                        .first()
                        .ok_or_else(|| bad("experiment get <id>"))?;
                    let st = client.status(id)?;
                    Ok(format!("{id}\t{}", st.as_str()))
                }
                "kill" => {
                    let id = args
                        .positional
                        .first()
                        .ok_or_else(|| bad("experiment kill <id>"))?;
                    client.kill(id)?;
                    Ok(format!("killed {id}"))
                }
                "events" => {
                    let id = args
                        .positional
                        .first()
                        .ok_or_else(|| bad("experiment events <id>"))?;
                    let mut out = String::new();
                    for e in client.events(id)? {
                        let at = e.num_field("at_millis").unwrap_or(0.0)
                            as u64;
                        let ty = e
                            .at(&["event", "type"])
                            .and_then(crate::util::json::Json::as_str)
                            .unwrap_or("?");
                        let container = e
                            .at(&["event", "container"])
                            .and_then(crate::util::json::Json::as_str)
                            .unwrap_or("");
                        out.push_str(&format!(
                            "{at}\t{ty}\t{container}\n"
                        ));
                    }
                    Ok(out)
                }
                "tune" => {
                    // a tune call answers only after every trial ran;
                    // size the read timeout to the search budget
                    let trials: f64 = args
                        .flag("trials")
                        .and_then(|v| v.parse().ok())
                        .unwrap_or(8.0);
                    let per_ms: f64 = args
                        .flag("trial-timeout-ms")
                        .and_then(|v| v.parse().ok())
                        .unwrap_or(10_000.0);
                    let secs =
                        (trials * per_ms / 1000.0 + 30.0).min(3600.0);
                    let client = client_from_flags(&args)?
                        .with_read_timeout(
                            std::time::Duration::from_secs_f64(secs),
                        );
                    run_tune_command(&args, &client)
                }
                other => Err(bad(&format!(
                    "unknown experiment subcommand {other:?}"
                ))),
            }
        }
        "cluster" => {
            let sub = argv.get(1).map(String::as_str).unwrap_or("status");
            let args = Args::parse(argv.get(2..).unwrap_or(&[]))?;
            let client = client_from_flags(&args)?;
            match sub {
                "status" => {
                    let j = client.cluster_status()?;
                    Ok(format_cluster_status(&j))
                }
                other => Err(bad(&format!(
                    "unknown cluster subcommand {other:?} (status)"
                ))),
            }
        }
        "template" => {
            let sub = argv.get(1).map(String::as_str).unwrap_or("");
            let args = Args::parse(argv.get(2..).unwrap_or(&[]))?;
            let client = client_from_flags(&args)?;
            match sub {
                "submit" => {
                    let name = args
                        .positional
                        .first()
                        .ok_or_else(|| bad("template submit <name>"))?;
                    let id =
                        client.submit_template(name, &args.params)?;
                    Ok(format!("submitted {id}"))
                }
                other => Err(bad(&format!(
                    "unknown template subcommand {other:?}"
                ))),
            }
        }
        "storage" => {
            let sub = argv.get(1).map(String::as_str).unwrap_or("");
            let rest = argv.get(2..).unwrap_or(&[]);
            let args = Args::parse(rest)?;
            storage_admin(sub, &args)
        }
        "get" => {
            // generic declarative read: any kind, any name, selectors
            let args = Args::parse(argv.get(1..).unwrap_or(&[]))?;
            let kind = args
                .positional
                .first()
                .ok_or_else(|| {
                    bad("get <kind> [name] [--selector k=v,...]")
                })?
                .clone();
            let client = client_from_flags(&args)?;
            match args.positional.get(1) {
                Some(name) => {
                    Ok(client.get_resource(&kind, name)?.pretty())
                }
                None => {
                    let res = client
                        .list_resources(&kind, args.flag("selector"))?;
                    Ok(format_resource_list(&res))
                }
            }
        }
        "watch" => {
            let args = Args::parse(argv.get(1..).unwrap_or(&[]))?;
            let kind = args
                .positional
                .first()
                .ok_or_else(|| {
                    bad("watch <kind> [--since REV] [--once]")
                })?
                .clone();
            let client = client_from_flags(&args)?;
            let since = match args.flag("since") {
                Some(v) => {
                    v.parse().map_err(|_| bad("bad --since"))?
                }
                None => client.resource_bookmark(&kind)?,
            };
            let once = args.flag("once").is_some();
            let mut w = client.watcher(&kind, since);
            loop {
                match w.next()? {
                    crate::sdk::WatchStep::Events(events) => {
                        for e in &events {
                            println!("{}", format_watch_event(e));
                        }
                    }
                    crate::sdk::WatchStep::Resync(items) => {
                        println!(
                            "-- watch position compacted; resynced \
                             {} items, resuming at rv {} --",
                            items.len(),
                            w.since
                        );
                    }
                }
                if once {
                    break;
                }
            }
            Ok(String::new())
        }
        "label" => {
            // submarine label <kind> <name> k=v ... (k- removes)
            let args = Args::parse(argv.get(1..).unwrap_or(&[]))?;
            if args.positional.len() < 3 {
                return Err(bad(
                    "label <kind> <name> key=value ... (key- removes)",
                ));
            }
            let kind = args.positional[0].clone();
            let name = args.positional[1].clone();
            let mut labels = crate::util::json::Json::obj();
            for term in &args.positional[2..] {
                if let Some(k) = term.strip_suffix('-') {
                    if k.is_empty() || k.contains('=') {
                        return Err(bad(&format!(
                            "bad label removal {term:?}"
                        )));
                    }
                    labels =
                        labels.set(k, crate::util::json::Json::Null);
                } else {
                    let (k, v) =
                        term.split_once('=').ok_or_else(|| {
                            bad(&format!(
                                "label term {term:?} is not key=value \
                                 or key-"
                            ))
                        })?;
                    labels = labels.set(
                        k,
                        crate::util::json::Json::Str(v.to_string()),
                    );
                }
            }
            let patch = crate::util::json::Json::obj().set(
                "meta",
                crate::util::json::Json::obj().set("labels", labels),
            );
            let client = client_from_flags(&args)?;
            let doc = client.patch_resource(&kind, &name, &patch)?;
            Ok(format!(
                "labeled {kind}/{name} (resource_version {})",
                crate::resource::resource_version(&doc)
            ))
        }
        "serve" => {
            // online inference tier (v2 only): status + one-shot predict
            let sub = argv.get(1).map(String::as_str).unwrap_or("status");
            let args = Args::parse(argv.get(2..).unwrap_or(&[]))?;
            if args.flag("api") == Some("v1") {
                return Err(bad("serve needs --api v2"));
            }
            let model = args
                .flag("model")
                .ok_or_else(|| bad("serve needs --model NAME"))?
                .to_string();
            let client = client_from_flags(&args)?;
            match sub {
                "status" => {
                    Ok(client.serving_status(&model)?.pretty())
                }
                "predict" => {
                    use crate::util::json::Json;
                    let mut row = Json::obj();
                    if let Some(ids) = args.flag("ids") {
                        row = row
                            .set("ids", parse_num_list(ids, "ids")?);
                    }
                    if let Some(vals) = args.flag("vals") {
                        row = row
                            .set("vals", parse_num_list(vals, "vals")?);
                    }
                    if row.as_obj().map(|o| o.is_empty()).unwrap_or(true)
                    {
                        return Err(bad(
                            "serve predict needs --ids and/or --vals \
                             (comma-separated)",
                        ));
                    }
                    let rows = Json::Arr(vec![row]);
                    Ok(client.predict(&model, &rows)?.pretty())
                }
                other => Err(bad(&format!(
                    "unknown serve subcommand {other:?}; \
                     try status | predict"
                ))),
            }
        }
        other => Err(bad(&format!(
            "unknown command {other:?}; try `submarine help`"
        ))),
    }
}

/// `"1,2,3"` / `"0.5,1.0"` -> JSON number array (for `serve predict`).
fn parse_num_list(
    csv: &str,
    flag: &str,
) -> crate::Result<crate::util::json::Json> {
    let mut out = Vec::new();
    for term in csv.split(',') {
        let n: f64 = term.trim().parse().map_err(|_| {
            bad(&format!("bad --{flag} entry {term:?}"))
        })?;
        out.push(crate::util::json::Json::Num(n));
    }
    if out.is_empty() {
        return Err(bad(&format!("--{flag} is empty")));
    }
    Ok(crate::util::json::Json::Arr(out))
}

/// `-P key=log:lo:hi | uniform:lo:hi | choice:a|b|c` -> search-space
/// entry JSON for the tune request.
fn parse_space_flag(spec: &str) -> crate::Result<crate::util::json::Json> {
    use crate::util::json::Json;
    let range = |kind: &str, rest: &str| -> crate::Result<Json> {
        let (lo, hi) = rest.split_once(':').ok_or_else(|| {
            bad(&format!("{kind} space needs {kind}:lo:hi"))
        })?;
        let lo: f64 = lo
            .parse()
            .map_err(|_| bad(&format!("bad lo in {spec:?}")))?;
        let hi: f64 = hi
            .parse()
            .map_err(|_| bad(&format!("bad hi in {spec:?}")))?;
        Ok(Json::Arr(vec![Json::Num(lo), Json::Num(hi)]))
    };
    if let Some(rest) = spec.strip_prefix("log:") {
        Ok(crate::util::json::Json::obj()
            .set("log_uniform", range("log", rest)?))
    } else if let Some(rest) = spec.strip_prefix("uniform:") {
        Ok(crate::util::json::Json::obj()
            .set("uniform", range("uniform", rest)?))
    } else if let Some(rest) = spec.strip_prefix("choice:") {
        let choices: Vec<crate::util::json::Json> = rest
            .split('|')
            .filter(|c| !c.is_empty())
            .map(|c| crate::util::json::Json::Str(c.to_string()))
            .collect();
        if choices.is_empty() {
            return Err(bad(&format!("empty choice list in {spec:?}")));
        }
        Ok(crate::util::json::Json::obj()
            .set("choice", crate::util::json::Json::Arr(choices)))
    } else {
        Err(bad(&format!(
            "space {spec:?} must start with log: | uniform: | choice:"
        )))
    }
}

/// `submarine experiment tune`: build the tune request from flags and
/// run it through the server's AutoML endpoint.
fn run_tune_command(
    args: &Args,
    client: &ExperimentClient,
) -> crate::Result<String> {
    use crate::util::json::Json;
    if args.params.is_empty() {
        return Err(bad(
            "experiment tune needs at least one -P name=log:lo:hi | \
             uniform:lo:hi | choice:a|b|c",
        ));
    }
    let mut space = Json::obj();
    for (name, spec) in &args.params {
        space = space.set(name, parse_space_flag(spec)?);
    }
    let mut req = Json::obj().set("space", space);
    for (flag, key) in [
        ("strategy", "strategy"),
        ("template", "template"),
    ] {
        if let Some(v) = args.flag(flag) {
            req = req.set(key, Json::Str(v.to_string()));
        }
    }
    for (flag, key) in [
        ("trials", "trials"),
        ("budget", "budget"),
        ("min-budget", "min_budget"),
        ("max-budget", "max_budget"),
        ("seed", "seed"),
        ("trial-timeout-ms", "trial_timeout_ms"),
    ] {
        if let Some(v) = args.flag(flag) {
            let n: f64 = v
                .parse()
                .map_err(|_| bad(&format!("bad --{flag} {v:?}")))?;
            req = req.set(key, Json::Num(n));
        }
    }
    if args.flag("template").is_none() {
        // no template: tune over a Listing-1-style base spec built from
        // the job flags (requires --name)
        req = req.set("spec", spec_from_job_flags(args)?.to_json());
    }
    let result = client.tune(&req)?;
    let mut out = String::new();
    let trials = result
        .get("trials")
        .and_then(Json::as_arr)
        .unwrap_or(&[]);
    for t in trials {
        out.push_str(&format!(
            "{}\t{}\tscore={:.4}\tbudget={}\t{}\n",
            t.str_field("experimentId").unwrap_or("-"),
            t.str_field("status").unwrap_or("?"),
            t.num_field("score").unwrap_or(f64::NAN),
            t.num_field("budget").unwrap_or(0.0),
            t.get("params").map(|p| p.dump()).unwrap_or_default(),
        ));
    }
    if let Some(best) = result.get("best") {
        out.push_str(&format!(
            "best: {} score={:.4} params={}\n",
            best.str_field("experimentId").unwrap_or("-"),
            best.num_field("score").unwrap_or(f64::NAN),
            best.get("params").map(|p| p.dump()).unwrap_or_default(),
        ));
    }
    Ok(out)
}

/// Tabular rendering of a v2 resource list payload.
fn format_resource_list(res: &crate::util::json::Json) -> String {
    use crate::util::json::Json;
    let items = res.get("items").and_then(Json::as_arr).unwrap_or(&[]);
    let mut out = String::new();
    for item in items {
        match item {
            Json::Str(name) => out.push_str(&format!("{name}\n")),
            obj => {
                let name = obj
                    .str_field("experimentId")
                    .map(str::to_string)
                    .or_else(|| {
                        obj.num_field("version")
                            .map(|v| format!("v{v}"))
                    })
                    .unwrap_or_else(|| obj.dump());
                let state = obj
                    .str_field("status")
                    .or_else(|| obj.str_field("stage"))
                    .unwrap_or("-");
                let labels = obj
                    .get("labels")
                    .map(|l| l.dump())
                    .unwrap_or_default();
                out.push_str(&format!("{name}\t{state}\t{labels}\n"));
            }
        }
    }
    out.push_str(&format!(
        "({} of {} @ resource_version {})\n",
        items.len(),
        res.num_field("total").unwrap_or(items.len() as f64),
        res.num_field("resource_version").unwrap_or(0.0),
    ));
    out
}

/// One-line rendering of a watch event.
fn format_watch_event(e: &crate::util::json::Json) -> String {
    use crate::util::json::Json;
    let ty = e.str_field("type").unwrap_or("?");
    let name = e.str_field("name").unwrap_or("?");
    let rv = e.num_field("resource_version").unwrap_or(0.0);
    let state = e
        .at(&["object", "status"])
        .and_then(Json::as_str)
        .or_else(|| e.at(&["object", "stage"]).and_then(Json::as_str))
        .unwrap_or("");
    format!("{rv}\t{ty}\t{name}\t{state}")
}

/// Human-readable `cluster status` output.
fn format_cluster_status(j: &crate::util::json::Json) -> String {
    use crate::util::json::Json;
    let mut out = format!(
        "version:   {}\nstatus:    {}\n",
        j.str_field("version").unwrap_or("?"),
        j.str_field("status").unwrap_or("?"),
    );
    let Some(sched) = j.str_field("scheduler") else {
        out.push_str(
            "(no execution engine attached; start the server with \
             --scheduler yarn|k8s for cluster detail)\n",
        );
        return out;
    };
    out.push_str(&format!("scheduler: {sched}\n"));
    out.push_str(&format!(
        "sim time:  {:.1}s   gpu util: {:.1}%\n",
        j.num_field("sim_now_s").unwrap_or(0.0),
        j.num_field("gpu_utilization").unwrap_or(0.0) * 100.0,
    ));
    out.push_str(&format!(
        "running:   {} containers   pending: {} jobs   \
         unknown-queue submissions: {}\n",
        j.num_field("running_containers").unwrap_or(0.0),
        j.num_field("pending_jobs").unwrap_or(0.0),
        j.num_field("unknown_queue_count").unwrap_or(0.0),
    ));
    if let Some(nodes) = j.get("nodes").and_then(Json::as_arr) {
        out.push_str(&format!("nodes ({}):\n", nodes.len()));
        for n in nodes {
            out.push_str(&format!(
                "  {}  alloc {} / cap {}\n",
                n.str_field("id").unwrap_or("?"),
                n.get("allocated").map(|r| r.dump()).unwrap_or_default(),
                n.get("capacity").map(|r| r.dump()).unwrap_or_default(),
            ));
        }
    }
    if let Some(queues) = j.get("queues").and_then(Json::as_arr) {
        out.push_str("queues:\n");
        for q in queues {
            out.push_str(&format!(
                "  {}  used {:.3} / cap {:.3} (max {:.3}){}\n",
                q.str_field("name").unwrap_or("?"),
                q.num_field("used_share").unwrap_or(0.0),
                q.num_field("capacity").unwrap_or(0.0),
                q.num_field("max_capacity").unwrap_or(0.0),
                if q.get("leaf").and_then(Json::as_bool)
                    == Some(true)
                {
                    ""
                } else {
                    "  [parent]"
                },
            ));
        }
    }
    out
}

/// The server/admin data directory from `--data-dir` (preferred) or the
/// pre-v2 `--db` alias; either may also point at a legacy single-file
/// WAL, which the engine migrates in place.
fn data_dir(args: &Args) -> Option<&str> {
    args.flag("data-dir").or_else(|| args.flag("db"))
}

/// `submarine storage stats|compact --data-dir DIR`: admin over a
/// storage engine data directory. `stats` is a read-only inspection
/// (safe while a server owns the directory); `compact` performs full
/// recovery + rewrite and must only run with the server stopped.
fn storage_admin(sub: &str, args: &Args) -> crate::Result<String> {
    use crate::storage::MetaStore;
    let dir = data_dir(args)
        .ok_or_else(|| bad("storage commands need --data-dir DIR"))?;
    match sub {
        "stats" => {
            let st = MetaStore::inspect(std::path::Path::new(dir))?;
            Ok(format!(
                "data dir:          {dir}\n\
                 namespaces:        {}\n\
                 documents:         {}\n\
                 snapshot gen:      {}\n\
                 wal records:       {} (replayable)\n\
                 wal bytes:         {}\n\
                 skipped records:   {} (blank/torn lines, tolerated)",
                st.namespaces,
                st.docs,
                st.snapshot_gen,
                st.wal_records,
                st.wal_bytes,
                st.skipped_records,
            ))
        }
        "compact" => {
            // full recovery + rewrite: requires exclusive ownership of
            // the directory (stop the server first)
            let store = MetaStore::open(std::path::Path::new(dir))?;
            let rep = store.compact()?;
            Ok(format!(
                "compacted {dir}: snapshot gen {} ({} docs, {} stale \
                 files removed)",
                rep.gen, rep.docs, rep.removed_files
            ))
        }
        other => Err(bad(&format!(
            "unknown storage subcommand {other:?} (stats|compact)"
        ))),
    }
}

/// Parse `--queues "eng=0.5:0.8,sci=0.5:0.6"` into children of `root`
/// (capacity:max_capacity, both fractions of root).
fn parse_queue_config(
    queues: &mut crate::scheduler::queue::QueueTree,
    spec: &str,
) -> crate::Result<()> {
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, shares) = part.split_once('=').ok_or_else(|| {
            bad(&format!("queue token {part:?} is not name=cap:max"))
        })?;
        let (cap, max) = shares.split_once(':').ok_or_else(|| {
            bad(&format!("queue token {part:?} is not name=cap:max"))
        })?;
        let cap: f64 = cap
            .parse()
            .map_err(|_| bad(&format!("bad capacity in {part:?}")))?;
        let max: f64 = max
            .parse()
            .map_err(|_| bad(&format!("bad max_capacity in {part:?}")))?;
        queues.add("root", name.trim(), cap, max)?;
    }
    Ok(())
}

/// `submarine server`: full stack. `--scheduler yarn|k8s` (default
/// yarn) runs experiments through the simulated execution pipeline
/// (background scheduler loop + cluster sim); `--scheduler local` runs
/// bound workloads for real on the PJRT runtime.
fn serve(args: &Args) -> crate::Result<String> {
    use crate::cluster::ClusterSim;
    use crate::experiment::monitor::ExperimentMonitor;
    use crate::httpd::server::{Server, ServerOptions, Services};
    use crate::orchestrator::engine::EngineConfig;
    use crate::orchestrator::local::LocalSubmitter;
    use crate::orchestrator::sim_submitter::SimSubmitter;
    use crate::scheduler::k8s::K8sScheduler;
    use crate::scheduler::queue::QueueTree;
    use crate::scheduler::yarn::YarnScheduler;
    use crate::scheduler::Scheduler;
    use crate::storage::{MetaStore, MetricStore};
    use crate::util::clock::SimTime;
    use std::sync::Arc;

    let port: u16 = args
        .flag("port")
        .and_then(|p| p.parse().ok())
        .unwrap_or(8080);
    let artifacts = args.flag("artifacts").unwrap_or("artifacts");
    let store = match data_dir(args) {
        Some(path) => {
            Arc::new(MetaStore::open(std::path::Path::new(path))?)
        }
        None => Arc::new(MetaStore::in_memory()),
    };
    let metrics = Arc::new(MetricStore::new());
    let scheduler_kind = args.flag("scheduler").unwrap_or("yarn");
    let services = match scheduler_kind {
        "local" => {
            let monitor = Arc::new(ExperimentMonitor::new());
            let submitter = Arc::new(LocalSubmitter::new(
                Arc::clone(&monitor),
                Arc::clone(&metrics),
                std::path::Path::new(artifacts),
            ));
            Services::with_parts(store, monitor, metrics, submitter)
        }
        "yarn" | "k8s" => {
            let nodes: usize = args
                .flag("nodes")
                .map(|v| v.parse().map_err(|_| bad("bad --nodes")))
                .transpose()?
                .unwrap_or(4);
            let node_res = crate::cluster::Resources::parse(
                args.flag("node-resources")
                    .unwrap_or("cpu=16,memory=64G,gpu=4"),
            )?;
            let sockets: u32 = args
                .flag("sockets")
                .map(|v| v.parse().map_err(|_| bad("bad --sockets")))
                .transpose()?
                .unwrap_or(2);
            let sim = ClusterSim::homogeneous(
                nodes.max(1),
                node_res,
                sockets,
            );
            let monitor = Arc::new(ExperimentMonitor::new());
            let scheduler: Box<dyn Scheduler + Send> =
                if scheduler_kind == "yarn" {
                    let mut queues = QueueTree::flat();
                    if let Some(qspec) = args.flag("queues") {
                        parse_queue_config(&mut queues, qspec)?;
                    }
                    if let Some(d) = args.flag("default-queue") {
                        queues.set_default_queue(d)?;
                    }
                    Box::new(YarnScheduler::new(queues))
                } else {
                    Box::new(K8sScheduler::new())
                };
            let task_secs: f64 = args
                .flag("sim-task-secs")
                .map(|v| {
                    v.parse().map_err(|_| bad("bad --sim-task-secs"))
                })
                .transpose()?
                .unwrap_or(10.0);
            if task_secs <= 0.0 || !task_secs.is_finite() {
                return Err(bad("--sim-task-secs must be > 0"));
            }
            let submitter = Arc::new(
                SimSubmitter::new(scheduler, sim, monitor)
                    .with_container_duration(SimTime::from_secs_f64(
                        task_secs,
                    )),
            );
            Services::with_sim_executor(
                store,
                submitter,
                metrics,
                EngineConfig::default(),
            )
        }
        other => {
            return Err(bad(&format!(
                "unknown --scheduler {other:?} (yarn | k8s | local)"
            )))
        }
    };
    let services = Arc::new(services);
    // built-in template, as the community templates of §3.2.3
    let _ = services
        .templates
        .register(&crate::template::tf_mnist_template());
    let rate_limit = match args.flag("rate-limit") {
        None => None,
        Some(v) => {
            let r: f64 = v.parse().map_err(|_| {
                bad(&format!("--rate-limit {v:?} is not a number"))
            })?;
            if r <= 0.0 || !r.is_finite() {
                return Err(bad("--rate-limit must be > 0"));
            }
            Some((r, (2.0 * r).max(1.0)))
        }
    };
    let cfg = crate::httpd::ApiConfig {
        auth_token: args.flag("token").map(str::to_string),
        rate_limit,
    };
    // reactor knobs: flags override the SUBMARINE_HTTP_* env defaults
    let mut http_opts = ServerOptions::default();
    if let Some(v) = args.flag("http-workers") {
        let n: usize =
            v.parse().map_err(|_| bad("bad --http-workers"))?;
        if n == 0 {
            return Err(bad("--http-workers must be > 0"));
        }
        http_opts.workers = Some(n);
    }
    if let Some(v) = args.flag("http-max-conns") {
        let n: usize =
            v.parse().map_err(|_| bad("bad --http-max-conns"))?;
        if n == 0 {
            return Err(bad("--http-max-conns must be > 0"));
        }
        http_opts.max_connections = n;
    }
    let server = Arc::new(Server::bind_with_options(
        services, port, &cfg, http_opts,
    )?);
    println!("submarine server on 127.0.0.1:{}", server.port());
    server.serve()?;
    Ok(String::new())
}

fn usage() -> String {
    "usage: submarine <command>\n\
     commands:\n\
       server      [--port 8080] [--data-dir DIR] [--artifacts DIR] [--token T]\n\
                   [--rate-limit REQS_PER_SEC]\n\
                   [--http-workers N] [--http-max-conns N]\n\
                   [--scheduler yarn|k8s|local] [--nodes N]\n\
                   [--node-resources cpu=16,memory=64G,gpu=4] [--sockets S]\n\
                   [--queues eng=0.5:0.8,sci=0.5:0.6] [--default-queue root.eng]\n\
                   [--sim-task-secs SECS]\n\
       job run     --name N [--framework F] [--num_workers K] [--num_ps K]\n\
                   [--worker_resources R] [--ps_resources R] [--queue Q]\n\
                   [--worker_launch_cmd C] [--model M --steps S --lr LR]\n\
                   [--server host:port]\n\
       experiment  list [--limit N] [--offset N] [--status S]\n\
                   [--selector k=v,k2=v2]\n\
                   [--all]     (drain every page by cursor; --limit\n\
                                becomes the page size)\n\
                   [--stream]  (one-request streamed drain; no filters)\n\
                   | get <id> | kill <id> | events <id>\n\
                   | tune [--template T] [--strategy random_search|successive_halving]\n\
                          [--trials N] [--budget B] [--min-budget B] [--max-budget B]\n\
                          -P param=log:lo:hi|uniform:lo:hi|choice:a|b|c ...\n\
                                                 [--server host:port]\n\
       cluster     status                        [--server host:port]\n\
       template    submit <name> -P key=value... [--server host:port]\n\
       get         <kind> [name] [--selector k=v,...]   (kind: experiment|\n\
                   template|environment; `get <kind> <name>` prints the\n\
                   full document with its meta block)\n\
       watch       <kind> [--since REV] [--once]  (long-poll change feed;\n\
                   auto-relists after a 410 Gone compaction)\n\
       label       <kind> <name> key=value ... key-   (merge-patch labels)\n\
       serve       status  --model M            [--server host:port]\n\
                   | predict --model M --ids 1,2,3 [--vals 0.5,1.0,2.0]\n\
                   (online inference against the Production version;\n\
                    canary weights via PATCH /api/v2/serve/<model>)\n\
       storage     stats | compact --data-dir DIR\n\
                   (stats is read-only; compact needs the server stopped)\n\
       version\n\
     client flags: [--server host:port] [--api v1|v2] [--token T]\n\
     (--db is a deprecated alias for --data-dir; legacy single-file\n\
      WALs are migrated into the directory layout on first open;\n\
      --scheduler yarn runs experiments on the simulated cluster via the\n\
      execution engine, local runs bound workloads on the PJRT runtime)"
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_listing1_flags() {
        // the paper's Listing 1 command, translated
        let args = Args::parse(&argv(&[
            "--name", "mnist",
            "--framework", "TensorFlow",
            "--num_workers", "4",
            "--worker_resources", "memory=4G,gpu=4,vcores=4",
            "--num_ps", "1",
            "--ps_resources", "memory=2G,vcores=2",
            "--worker_launch_cmd", "python mnist.py",
            "--insecure",
        ]))
        .unwrap();
        let spec = spec_from_job_flags(&args).unwrap();
        assert_eq!(spec.meta.name, "mnist");
        assert_eq!(spec.total_containers(), 5);
        let (ps_name, ps) = &spec.tasks[0];
        assert_eq!(ps_name, "Ps");
        assert_eq!(ps.resources.memory_mb, 2048);
        let (_, w) = &spec.tasks[1];
        assert_eq!(w.resources.gpus, 4);
        assert_eq!(spec.meta.cmd, "python mnist.py");
    }

    #[test]
    fn equals_form_and_params() {
        let args = Args::parse(&argv(&[
            "--name=x",
            "-P", "learning_rate=0.01",
            "-P", "batch_size=64",
            "pos1",
        ]))
        .unwrap();
        assert_eq!(args.flag("name"), Some("x"));
        assert_eq!(args.params["learning_rate"], "0.01");
        assert_eq!(args.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&argv(&["--name"])).is_err());
        assert!(Args::parse(&argv(&["-P", "noequals"])).is_err());
    }

    #[test]
    fn job_flags_require_name() {
        let args = Args::parse(&argv(&["--num_workers", "2"])).unwrap();
        assert!(spec_from_job_flags(&args).is_err());
    }

    #[test]
    fn server_address_parsing() {
        let args =
            Args::parse(&argv(&["--server", "10.0.0.5:9000"])).unwrap();
        assert_eq!(args.server(), ("10.0.0.5".to_string(), 9000));
        let args = Args::parse(&argv(&[])).unwrap();
        assert_eq!(args.server().1, 8080);
    }

    #[test]
    fn workload_flags_flow_through() {
        let args = Args::parse(&argv(&[
            "--name", "ctr", "--model", "deepfm", "--steps", "250",
            "--lr", "0.02",
        ]))
        .unwrap();
        let spec = spec_from_job_flags(&args).unwrap();
        let w = spec.workload.unwrap();
        assert_eq!(w.model, "deepfm");
        assert_eq!(w.steps, 250);
    }

    #[test]
    fn api_flag_selects_base() {
        let args = Args::parse(&argv(&["--api", "v1"])).unwrap();
        assert_eq!(
            client_from_flags(&args).unwrap().api_base(),
            "/api/v1"
        );
        let args = Args::parse(&argv(&[])).unwrap();
        assert_eq!(
            client_from_flags(&args).unwrap().api_base(),
            "/api/v2"
        );
        let args = Args::parse(&argv(&["--api", "v9"])).unwrap();
        assert!(client_from_flags(&args).is_err());
    }

    #[test]
    fn unknown_command_fails() {
        assert_eq!(run(&argv(&["frobnicate"])), 2);
        assert_eq!(run(&argv(&["version"])), 0);
    }

    #[test]
    fn space_flag_parsing() {
        let j = parse_space_flag("log:0.0001:1.0").unwrap();
        assert!(j.get("log_uniform").is_some());
        let j = parse_space_flag("uniform:0:1").unwrap();
        assert_eq!(
            j.get("uniform").unwrap().as_arr().unwrap().len(),
            2
        );
        let j = parse_space_flag("choice:64|128|256").unwrap();
        assert_eq!(
            j.get("choice").unwrap().as_arr().unwrap().len(),
            3
        );
        assert!(parse_space_flag("grid:1:2").is_err());
        assert!(parse_space_flag("log:oops:1").is_err());
        assert!(parse_space_flag("choice:").is_err());
    }

    #[test]
    fn queue_config_parsing() {
        let mut q = crate::scheduler::queue::QueueTree::flat();
        parse_queue_config(&mut q, "eng=0.5:0.8, sci=0.5:0.6").unwrap();
        assert!(q.is_leaf("root.eng"));
        assert!((q.get("root.sci").unwrap().capacity - 0.5).abs() < 1e-9);
        let mut q = crate::scheduler::queue::QueueTree::flat();
        assert!(parse_queue_config(&mut q, "eng=0.5").is_err());
        assert!(parse_queue_config(&mut q, "eng").is_err());
        // invalid shares are rejected by the tree's validation
        assert!(parse_queue_config(&mut q, "eng=0.5:0.1").is_err());
    }

    #[test]
    fn label_command_validates_terms_before_any_network_call() {
        assert!(dispatch(&argv(&["label", "experiment"])).is_err());
        assert!(dispatch(&argv(&[
            "label",
            "experiment",
            "e-1",
            "nokv"
        ]))
        .is_err());
        assert!(
            dispatch(&argv(&["label", "experiment", "e-1", "-"]))
                .is_err()
        );
    }

    #[test]
    fn get_and_watch_require_a_kind() {
        assert!(dispatch(&argv(&["get"])).is_err());
        assert!(dispatch(&argv(&["watch"])).is_err());
        // selector on the v1 surface is rejected client-side
        assert!(dispatch(&argv(&[
            "experiment",
            "list",
            "--selector",
            "a=b",
            "--api",
            "v1"
        ]))
        .is_err());
    }

    #[test]
    fn drain_flags_validate_before_any_network_call() {
        // --all and --stream are boolean flags: no value consumed
        let args =
            Args::parse(&argv(&["--all", "--limit", "2"])).unwrap();
        assert!(args.has_flag("all"));
        assert_eq!(args.flag("limit"), Some("2"));
        // the v1 surface has neither cursors nor streamed drains
        assert!(dispatch(&argv(&[
            "experiment", "list", "--all", "--api", "v1"
        ]))
        .is_err());
        assert!(dispatch(&argv(&[
            "experiment", "list", "--stream", "--api", "v1"
        ]))
        .is_err());
        // a cursor walk cannot compose with offset paging
        assert!(dispatch(&argv(&[
            "experiment", "list", "--all", "--offset", "3"
        ]))
        .is_err());
        // --stream drains everything: filters, paging, and --all are
        // rejected before any connection is opened
        assert!(dispatch(&argv(&[
            "experiment", "list", "--stream", "--selector", "a=b"
        ]))
        .is_err());
        assert!(dispatch(&argv(&[
            "experiment", "list", "--stream", "--limit", "5"
        ]))
        .is_err());
        assert!(dispatch(&argv(&[
            "experiment", "list", "--stream", "--all"
        ]))
        .is_err());
    }

    #[test]
    fn storage_admin_stats_and_compact() {
        let dir = std::env::temp_dir().join(format!(
            "submarine-cli-store-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let s = crate::storage::MetaStore::open(&dir).unwrap();
            s.put("exp", "e1", crate::util::json::Json::Num(1.0))
                .unwrap();
        }
        let d = dir.to_str().unwrap().to_string();
        let out =
            dispatch(&argv(&["storage", "stats", "--data-dir", &d]))
                .unwrap();
        assert!(out.contains("documents:"), "{out}");
        assert!(out.contains("skipped records:"), "{out}");
        let out =
            dispatch(&argv(&["storage", "compact", "--data-dir", &d]))
                .unwrap();
        assert!(out.contains("compacted"), "{out}");
        assert!(
            dispatch(&argv(&["storage", "frob", "--data-dir", &d]))
                .is_err()
        );
        // --data-dir is required for offline admin
        assert!(dispatch(&argv(&["storage", "stats"])).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
