//! Submarine Experiment Service (paper §3.2.2, Figs. 3–4): spec types,
//! the experiment manager, and the experiment monitor.

pub mod manager;
pub mod monitor;
pub mod spec;

pub use manager::ExperimentManager;
pub use monitor::{Event, ExperimentMonitor};
pub use spec::{ExperimentSpec, ExperimentStatus};
