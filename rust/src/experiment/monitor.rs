//! Experiment monitor (paper Fig. 4): "tracks the status of experiments
//! and records important events and sends them to the experiment manager.
//! This information plays a key role to predict the success or failure of
//! the in-progress experiment."

use super::spec::ExperimentStatus;
use crate::util::clock::unix_millis;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Events emitted by submitters/runtimes.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    Accepted,
    ContainerStarted { container: String },
    ContainerFinished { container: String },
    ContainerFailed { container: String, reason: String },
    MetricLogged { metric: String, step: u64, value: f64 },
    Killed,
}

impl Event {
    /// JSON shape served by `GET /experiment/:id/events`.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        match self {
            Event::Accepted => {
                Json::obj().set("type", Json::Str("Accepted".into()))
            }
            Event::ContainerStarted { container } => Json::obj()
                .set("type", Json::Str("ContainerStarted".into()))
                .set("container", Json::Str(container.clone())),
            Event::ContainerFinished { container } => Json::obj()
                .set("type", Json::Str("ContainerFinished".into()))
                .set("container", Json::Str(container.clone())),
            Event::ContainerFailed { container, reason } => Json::obj()
                .set("type", Json::Str("ContainerFailed".into()))
                .set("container", Json::Str(container.clone()))
                .set("reason", Json::Str(reason.clone())),
            Event::MetricLogged {
                metric,
                step,
                value,
            } => Json::obj()
                .set("type", Json::Str("MetricLogged".into()))
                .set("metric", Json::Str(metric.clone()))
                .set("step", Json::Num(*step as f64))
                .set("value", Json::Num(*value)),
            Event::Killed => {
                Json::obj().set("type", Json::Str("Killed".into()))
            }
        }
    }
}

/// A recorded event with timestamp.
#[derive(Debug, Clone)]
pub struct Recorded {
    pub at_millis: u64,
    pub event: Event,
}

impl Recorded {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj()
            .set("at_millis", Json::Num(self.at_millis as f64))
            .set("event", self.event.to_json())
    }
}

#[derive(Default)]
struct ExpState {
    events: Vec<Recorded>,
    containers_expected: u32,
    containers_started: u32,
    containers_finished: u32,
    containers_failed: u32,
    killed: bool,
}

/// Fig. 4 lifecycle, derived from container counters.
fn derive(st: &ExpState) -> ExperimentStatus {
    if st.killed {
        ExperimentStatus::Killed
    } else if st.containers_failed > 0 {
        ExperimentStatus::Failed
    } else if st.containers_expected > 0
        && st.containers_finished >= st.containers_expected
    {
        ExperimentStatus::Succeeded
    } else if st.containers_started > 0 {
        ExperimentStatus::Running
    } else {
        ExperimentStatus::Accepted
    }
}

/// Callback invoked with `(id, derived_status)` after every state
/// change — the hook the storage layer uses to keep the persisted
/// status (and its secondary index) in lockstep with the monitor.
pub type StatusObserver = Box<dyn Fn(&str, ExperimentStatus) + Send + Sync>;

/// Tracks per-experiment container progress and derives status.
#[derive(Default)]
pub struct ExperimentMonitor {
    state: Mutex<BTreeMap<String, ExpState>>,
    observer: Mutex<Option<StatusObserver>>,
}

impl ExperimentMonitor {
    pub fn new() -> ExperimentMonitor {
        ExperimentMonitor::default()
    }

    /// Install the status observer (replaces any previous one). Wired by
    /// `Services` so doc status / the status index track the monitor.
    pub fn set_observer(&self, observer: StatusObserver) {
        *self.observer.lock().unwrap() = Some(observer);
    }

    /// Invoke the observer outside the state lock (it may hit storage).
    /// The status is re-derived *under the observer lock*: two racing
    /// events then can't persist out of order (each notification sees a
    /// status at least as fresh as its own transition, and the last one
    /// to run wins with the latest state).
    fn notify(&self, id: &str) {
        let g = self.observer.lock().unwrap();
        if let Some(f) = g.as_ref() {
            f(id, self.status(id));
        }
    }

    /// Register a new experiment expecting `containers` containers.
    pub fn watch(&self, id: &str, containers: u32) {
        {
            let mut g = self.state.lock().unwrap();
            let st = g.entry(id.to_string()).or_default();
            st.containers_expected = containers;
            st.events.push(Recorded {
                at_millis: unix_millis(),
                event: Event::Accepted,
            });
        }
        self.notify(id);
    }

    /// Record an event for `id`.
    pub fn record(&self, id: &str, event: Event) {
        {
            let mut g = self.state.lock().unwrap();
            let st = g.entry(id.to_string()).or_default();
            match &event {
                Event::ContainerStarted { .. } => {
                    st.containers_started += 1
                }
                Event::ContainerFinished { .. } => {
                    st.containers_finished += 1
                }
                Event::ContainerFailed { .. } => {
                    st.containers_failed += 1
                }
                Event::Killed => st.killed = true,
                _ => {}
            }
            st.events.push(Recorded {
                at_millis: unix_millis(),
                event,
            });
        }
        self.notify(id);
    }

    /// Derived status per Fig. 4's lifecycle.
    pub fn status(&self, id: &str) -> ExperimentStatus {
        let g = self.state.lock().unwrap();
        match g.get(id) {
            None => ExperimentStatus::Accepted,
            Some(st) => derive(st),
        }
    }

    /// Whether this (volatile) monitor has any state for `id`. After a
    /// restart it does not, and callers should trust the persisted doc
    /// status instead of the `Accepted` default.
    pub fn is_watched(&self, id: &str) -> bool {
        self.state.lock().unwrap().contains_key(id)
    }

    /// Success-likelihood prediction for an in-progress experiment (the
    /// paper's monitor "predict[s] the success or failure"): fraction of
    /// containers finished cleanly, penalized by failures.
    pub fn success_estimate(&self, id: &str) -> f64 {
        let g = self.state.lock().unwrap();
        match g.get(id) {
            None => 0.5,
            Some(st) => {
                if st.killed || st.containers_failed > 0 {
                    0.0
                } else if st.containers_expected == 0 {
                    0.5
                } else {
                    let done = st.containers_finished as f64
                        / st.containers_expected as f64;
                    0.5 + 0.5 * done
                }
            }
        }
    }

    pub fn events(&self, id: &str) -> Vec<Recorded> {
        self.state
            .lock()
            .unwrap()
            .get(id)
            .map(|st| st.events.clone())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_accepted_running_succeeded() {
        let m = ExperimentMonitor::new();
        m.watch("e", 2);
        assert_eq!(m.status("e"), ExperimentStatus::Accepted);
        m.record("e", Event::ContainerStarted { container: "c0".into() });
        m.record("e", Event::ContainerStarted { container: "c1".into() });
        assert_eq!(m.status("e"), ExperimentStatus::Running);
        m.record("e", Event::ContainerFinished { container: "c0".into() });
        assert_eq!(m.status("e"), ExperimentStatus::Running);
        m.record("e", Event::ContainerFinished { container: "c1".into() });
        assert_eq!(m.status("e"), ExperimentStatus::Succeeded);
    }

    #[test]
    fn failure_dominates() {
        let m = ExperimentMonitor::new();
        m.watch("e", 2);
        m.record("e", Event::ContainerStarted { container: "c0".into() });
        m.record(
            "e",
            Event::ContainerFailed {
                container: "c0".into(),
                reason: "OOM".into(),
            },
        );
        assert_eq!(m.status("e"), ExperimentStatus::Failed);
        assert_eq!(m.success_estimate("e"), 0.0);
    }

    #[test]
    fn kill_is_terminal() {
        let m = ExperimentMonitor::new();
        m.watch("e", 1);
        m.record("e", Event::Killed);
        assert_eq!(m.status("e"), ExperimentStatus::Killed);
    }

    #[test]
    fn success_estimate_grows_with_progress() {
        let m = ExperimentMonitor::new();
        m.watch("e", 4);
        let base = m.success_estimate("e");
        m.record("e", Event::ContainerStarted { container: "c".into() });
        m.record("e", Event::ContainerFinished { container: "c".into() });
        assert!(m.success_estimate("e") > base);
    }

    #[test]
    fn observer_sees_status_transitions() {
        use std::sync::Arc;
        let m = ExperimentMonitor::new();
        let seen: Arc<Mutex<Vec<(String, ExperimentStatus)>>> =
            Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&seen);
        m.set_observer(Box::new(move |id, st| {
            sink.lock().unwrap().push((id.to_string(), st));
        }));
        m.watch("e", 1);
        m.record(
            "e",
            Event::ContainerStarted { container: "c".into() },
        );
        m.record("e", Event::Killed);
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), 3);
        assert_eq!(seen[0].1, ExperimentStatus::Accepted);
        assert_eq!(seen[1].1, ExperimentStatus::Running);
        assert_eq!(seen[2].1, ExperimentStatus::Killed);
    }

    #[test]
    fn unknown_experiment_defaults() {
        let m = ExperimentMonitor::new();
        assert_eq!(m.status("ghost"), ExperimentStatus::Accepted);
        assert_eq!(m.success_estimate("ghost"), 0.5);
        assert!(m.events("ghost").is_empty());
    }
}
