//! Experiment specification types (paper §3.2.2, Fig. 3; JSON format of
//! Listings 2 and 4).
//!
//! An experiment = meta (name/framework/cmd) + environment + a map of task
//! groups (`Ps`, `Worker`, ...) with replicas and resources, plus the
//! optional scheduling fields Submarine-RS adds (queue, workload binding
//! for the local PJRT runtime).

use crate::cluster::Resources;
use crate::util::json::Json;

/// Experiment metadata (Listing 2 `ExperimentMeta`).
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentMeta {
    pub name: String,
    pub namespace: String,
    pub framework: String,
    pub cmd: String,
}

/// One task group (Listing 2 `ExperimentTaskSpec`).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    pub replicas: u32,
    pub resources: Resources,
}

/// Environment reference (Listing 2 `EnvironmentSpec`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct EnvironmentRef {
    pub image: String,
    /// Optional named environment in the Environment Service.
    pub name: Option<String>,
}

/// Binding to a real AOT-compiled workload for the local runtime
/// (Submarine proper launches user code; Submarine-RS launches the AOT
/// models from `artifacts/` — see DESIGN.md §Substitutions).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// Model name in `artifacts/manifest.json` (e.g. `"deepfm"`).
    pub model: String,
    pub steps: u32,
    pub lr: f32,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            model: "mnist_mlp".into(),
            steps: 100,
            lr: 0.05,
            seed: 42,
        }
    }
}

/// Full experiment spec.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentSpec {
    pub meta: ExperimentMeta,
    pub environment: EnvironmentRef,
    /// Task-group name -> spec (`Ps`, `Worker`, ...).
    pub tasks: Vec<(String, TaskSpec)>,
    /// Scheduler queue (defaults to `root`).
    pub queue: String,
    /// Optional real workload to run via the PJRT runtime.
    pub workload: Option<WorkloadSpec>,
}

impl ExperimentSpec {
    /// Parse the Listing-2/4 JSON shape.
    pub fn from_json(j: &Json) -> crate::Result<ExperimentSpec> {
        let meta = j.get("meta").ok_or_else(|| bad("missing meta"))?;
        let name = meta
            .str_field("name")
            .ok_or_else(|| bad("meta.name required"))?
            .to_string();
        if name.is_empty() {
            return Err(bad("meta.name must be non-empty"));
        }
        let spec = ExperimentSpec {
            meta: ExperimentMeta {
                name,
                namespace: meta
                    .str_field("namespace")
                    .unwrap_or("default")
                    .to_string(),
                framework: meta
                    .str_field("framework")
                    .unwrap_or("TensorFlow")
                    .to_string(),
                cmd: meta.str_field("cmd").unwrap_or("").to_string(),
            },
            environment: EnvironmentRef {
                image: j
                    .at(&["environment", "image"])
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                name: j
                    .at(&["environment", "name"])
                    .and_then(Json::as_str)
                    .map(str::to_string),
            },
            tasks: {
                let mut tasks = Vec::new();
                if let Some(Json::Obj(groups)) = j.get("spec") {
                    for (gname, g) in groups {
                        let replicas = g
                            .num_field("replicas")
                            .ok_or_else(|| bad("task replicas required"))?
                            as u32;
                        if replicas == 0 {
                            return Err(bad("task replicas must be >= 1"));
                        }
                        let res = g
                            .str_field("resources")
                            .ok_or_else(|| bad("task resources required"))?;
                        tasks.push((
                            gname.clone(),
                            TaskSpec {
                                replicas,
                                resources: Resources::parse(res)?,
                            },
                        ));
                    }
                }
                if tasks.is_empty() {
                    return Err(bad("spec must define at least one task"));
                }
                tasks
            },
            queue: j
                .str_field("queue")
                .unwrap_or("root")
                .to_string(),
            workload: j.get("workload").map(|w| WorkloadSpec {
                model: w
                    .str_field("model")
                    .unwrap_or("mnist_mlp")
                    .to_string(),
                steps: num_or_str(w, "steps").unwrap_or(100.0) as u32,
                lr: num_or_str(w, "lr").unwrap_or(0.05) as f32,
                seed: num_or_str(w, "seed").unwrap_or(42.0) as u64,
            }),
        };
        Ok(spec)
    }

    pub fn parse(text: &str) -> crate::Result<ExperimentSpec> {
        Self::from_json(&Json::parse(text)?)
    }

    pub fn to_json(&self) -> Json {
        let mut groups = Json::obj();
        for (name, t) in &self.tasks {
            groups = groups.set(
                name,
                Json::obj()
                    .set("replicas", Json::Num(t.replicas as f64))
                    .set(
                        "resources",
                        Json::Str(t.resources.to_string()),
                    ),
            );
        }
        let mut j = Json::obj()
            .set(
                "meta",
                Json::obj()
                    .set("name", Json::Str(self.meta.name.clone()))
                    .set(
                        "namespace",
                        Json::Str(self.meta.namespace.clone()),
                    )
                    .set(
                        "framework",
                        Json::Str(self.meta.framework.clone()),
                    )
                    .set("cmd", Json::Str(self.meta.cmd.clone())),
            )
            .set(
                "environment",
                Json::obj()
                    .set("image", Json::Str(self.environment.image.clone())),
            )
            .set("spec", groups)
            .set("queue", Json::Str(self.queue.clone()));
        if let Some(w) = &self.workload {
            j = j.set(
                "workload",
                Json::obj()
                    .set("model", Json::Str(w.model.clone()))
                    .set("steps", Json::Num(w.steps as f64))
                    .set("lr", Json::Num(w.lr as f64))
                    .set("seed", Json::Num(w.seed as f64)),
            );
        }
        j
    }

    /// Convert to a scheduler job request.
    pub fn to_job(
        &self,
        id: &str,
        duration: crate::util::clock::SimTime,
    ) -> crate::scheduler::JobRequest {
        crate::scheduler::JobRequest {
            id: id.to_string(),
            queue: self.queue.clone(),
            gang: true,
            tasks: self
                .tasks
                .iter()
                .map(|(name, t)| crate::scheduler::TaskGroup {
                    name: name.clone(),
                    replicas: t.replicas,
                    resources: t.resources,
                    duration,
                })
                .collect(),
        }
    }

    pub fn total_containers(&self) -> u32 {
        self.tasks.iter().map(|(_, t)| t.replicas).sum()
    }
}

fn bad(msg: &str) -> crate::SubmarineError {
    crate::SubmarineError::InvalidSpec(msg.to_string())
}

/// Numeric field that may arrive as a JSON number *or* a numeric string
/// (template `{{param}}` substitutions always produce strings).
fn num_or_str(j: &Json, key: &str) -> Option<f64> {
    match j.get(key)? {
        Json::Num(n) => Some(*n),
        Json::Str(s) => s.trim().parse().ok(),
        _ => None,
    }
}

/// Experiment lifecycle status (Fig. 4 monitor states).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExperimentStatus {
    Accepted,
    Running,
    Succeeded,
    Failed,
    Killed,
}

impl ExperimentStatus {
    pub fn as_str(&self) -> &'static str {
        match self {
            ExperimentStatus::Accepted => "Accepted",
            ExperimentStatus::Running => "Running",
            ExperimentStatus::Succeeded => "Succeeded",
            ExperimentStatus::Failed => "Failed",
            ExperimentStatus::Killed => "Killed",
        }
    }
    pub fn parse(s: &str) -> Option<ExperimentStatus> {
        Some(match s {
            "Accepted" => ExperimentStatus::Accepted,
            "Running" => ExperimentStatus::Running,
            "Succeeded" => ExperimentStatus::Succeeded,
            "Failed" => ExperimentStatus::Failed,
            "Killed" => ExperimentStatus::Killed,
            _ => return None,
        })
    }
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            ExperimentStatus::Succeeded
                | ExperimentStatus::Failed
                | ExperimentStatus::Killed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Listing-2 experiment, as JSON.
    pub(crate) const LISTING2: &str = r#"{
      "meta": {"name": "mnist", "namespace": "default",
               "framework": "TensorFlow", "cmd": "python mnist.py"},
      "environment": {"image": "submarine:tf-mnist"},
      "spec": {
        "Ps":     {"replicas": 1, "resources": "cpu=2,memory=2G"},
        "Worker": {"replicas": 4, "resources": "cpu=4,gpu=4,memory=4G"}
      }
    }"#;

    #[test]
    fn parses_listing2() {
        let s = ExperimentSpec::parse(LISTING2).unwrap();
        assert_eq!(s.meta.name, "mnist");
        assert_eq!(s.meta.framework, "TensorFlow");
        assert_eq!(s.tasks.len(), 2);
        let (name, ps) = &s.tasks[0];
        assert_eq!(name, "Ps");
        assert_eq!(ps.replicas, 1);
        assert_eq!(ps.resources.memory_mb, 2048);
        assert_eq!(s.total_containers(), 5);
        assert_eq!(s.queue, "root");
    }

    #[test]
    fn json_roundtrip() {
        let s = ExperimentSpec::parse(LISTING2).unwrap();
        let j = s.to_json().dump();
        let s2 = ExperimentSpec::parse(&j).unwrap();
        assert_eq!(s, s2);
    }

    #[test]
    fn workload_binding_parses() {
        let text = r#"{
          "meta": {"name": "ctr"},
          "spec": {"Worker": {"replicas": 1, "resources": "cpu=1"}},
          "workload": {"model": "deepfm", "steps": 300, "lr": 0.02}
        }"#;
        let s = ExperimentSpec::parse(text).unwrap();
        let w = s.workload.unwrap();
        assert_eq!(w.model, "deepfm");
        assert_eq!(w.steps, 300);
        assert!((w.lr - 0.02).abs() < 1e-6);
        assert_eq!(w.seed, 42); // default
    }

    #[test]
    fn rejects_invalid_specs() {
        assert!(ExperimentSpec::parse("{}").is_err());
        assert!(ExperimentSpec::parse(
            r#"{"meta":{"name":""},"spec":{"W":{"replicas":1,"resources":"cpu=1"}}}"#
        )
        .is_err());
        assert!(ExperimentSpec::parse(
            r#"{"meta":{"name":"x"},"spec":{}}"#
        )
        .is_err());
        assert!(ExperimentSpec::parse(
            r#"{"meta":{"name":"x"},"spec":{"W":{"replicas":0,"resources":"cpu=1"}}}"#
        )
        .is_err());
    }

    #[test]
    fn to_job_preserves_structure() {
        let s = ExperimentSpec::parse(LISTING2).unwrap();
        let job =
            s.to_job("exp-1", crate::util::clock::SimTime::from_millis(10));
        assert_eq!(job.total_containers(), 5);
        assert!(job.gang);
        assert_eq!(job.tasks[1].resources.gpus, 4);
    }

    #[test]
    fn status_roundtrip_and_terminality() {
        for s in [
            ExperimentStatus::Accepted,
            ExperimentStatus::Running,
            ExperimentStatus::Succeeded,
            ExperimentStatus::Failed,
            ExperimentStatus::Killed,
        ] {
            assert_eq!(ExperimentStatus::parse(s.as_str()), Some(s));
        }
        assert!(!ExperimentStatus::Running.is_terminal());
        assert!(ExperimentStatus::Failed.is_terminal());
    }
}
