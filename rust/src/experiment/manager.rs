//! Experiment manager (paper Fig. 4): accepts experiment requests,
//! persists metadata in the [`MetaStore`] ("so that experiments become
//! easy to compare and reproducible"), and forwards to the configured
//! submitter.

use super::monitor::ExperimentMonitor;
use super::spec::{ExperimentSpec, ExperimentStatus};
use crate::orchestrator::Submitter;
use crate::storage::MetaStore;
use crate::util::json::Json;
use std::sync::Arc;

const NS: &str = "experiment";

/// The control-plane entry point for experiments.
pub struct ExperimentManager {
    store: Arc<MetaStore>,
    monitor: Arc<ExperimentMonitor>,
    submitter: Arc<dyn Submitter>,
}

impl ExperimentManager {
    pub fn new(
        store: Arc<MetaStore>,
        monitor: Arc<ExperimentMonitor>,
        submitter: Arc<dyn Submitter>,
    ) -> ExperimentManager {
        ExperimentManager {
            store,
            monitor,
            submitter,
        }
    }

    pub fn monitor(&self) -> &Arc<ExperimentMonitor> {
        &self.monitor
    }

    /// Accept + persist + submit. Returns the experiment id.
    pub fn submit(&self, spec: &ExperimentSpec) -> crate::Result<String> {
        let id = crate::util::id::next("experiment");
        let doc = Json::obj()
            .set("id", Json::Str(id.clone()))
            .set("spec", spec.to_json())
            .set(
                "submitter",
                Json::Str(self.submitter.name().to_string()),
            )
            .set(
                "accepted_at",
                Json::Num(crate::util::clock::unix_millis() as f64),
            );
        self.store.put(NS, &id, doc)?;
        self.monitor.watch(&id, spec.total_containers());
        self.submitter.submit(&id, spec)?;
        crate::info!("experiment-manager", "accepted {id} ({})",
                     spec.meta.name);
        Ok(id)
    }

    pub fn get(&self, id: &str) -> crate::Result<Json> {
        let mut doc = self.store.get(NS, id).ok_or_else(|| {
            crate::SubmarineError::NotFound(format!("experiment {id}"))
        })?;
        doc = doc.set(
            "status",
            Json::Str(self.status(id).as_str().to_string()),
        );
        Ok(doc)
    }

    pub fn spec_of(&self, id: &str) -> crate::Result<ExperimentSpec> {
        let doc = self.store.get(NS, id).ok_or_else(|| {
            crate::SubmarineError::NotFound(format!("experiment {id}"))
        })?;
        ExperimentSpec::from_json(doc.get("spec").ok_or_else(|| {
            crate::SubmarineError::Storage("experiment doc missing spec"
                .into())
        })?)
    }

    pub fn status(&self, id: &str) -> ExperimentStatus {
        self.monitor.status(id)
    }

    pub fn list(&self) -> Vec<(String, ExperimentStatus)> {
        self.store
            .list(NS)
            .into_iter()
            .map(|(id, _)| {
                let st = self.monitor.status(&id);
                (id, st)
            })
            .collect()
    }

    pub fn kill(&self, id: &str) -> crate::Result<()> {
        if self.store.get(NS, id).is_none() {
            return Err(crate::SubmarineError::NotFound(format!(
                "experiment {id}"
            )));
        }
        self.submitter.kill(id)?;
        // Submitters stop the containers; the terminal state is the
        // manager's responsibility (idempotent if the submitter already
        // reported it).
        self.monitor
            .record(id, super::monitor::Event::Killed);
        Ok(())
    }

    /// Delete a *terminal* experiment's metadata.
    pub fn delete(&self, id: &str) -> crate::Result<()> {
        let st = self.monitor.status(id);
        if !st.is_terminal() && self.store.get(NS, id).is_some() {
            return Err(crate::SubmarineError::InvalidSpec(format!(
                "experiment {id} is {}; kill it first",
                st.as_str()
            )));
        }
        if !self.store.delete(NS, id)? {
            return Err(crate::SubmarineError::NotFound(format!(
                "experiment {id}"
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::monitor::Event;

    /// No-op submitter for manager unit tests.
    struct NullSubmitter;
    impl Submitter for NullSubmitter {
        fn name(&self) -> &'static str {
            "null"
        }
        fn submit(&self, _id: &str, _spec: &ExperimentSpec)
            -> crate::Result<()>
        {
            Ok(())
        }
        fn kill(&self, _id: &str) -> crate::Result<()> {
            Ok(())
        }
    }

    fn manager() -> ExperimentManager {
        ExperimentManager::new(
            Arc::new(MetaStore::in_memory()),
            Arc::new(ExperimentMonitor::new()),
            Arc::new(NullSubmitter),
        )
    }

    fn spec() -> ExperimentSpec {
        ExperimentSpec::parse(
            r#"{"meta":{"name":"mnist"},
                "spec":{"Worker":{"replicas":2,"resources":"cpu=1"}}}"#,
        )
        .unwrap()
    }

    #[test]
    fn submit_persists_and_lists() {
        let m = manager();
        let id = m.submit(&spec()).unwrap();
        let doc = m.get(&id).unwrap();
        assert_eq!(doc.str_field("status"), Some("Accepted"));
        assert_eq!(
            doc.at(&["spec", "meta", "name"]).unwrap().as_str(),
            Some("mnist")
        );
        assert_eq!(m.list().len(), 1);
        let round = m.spec_of(&id).unwrap();
        assert_eq!(round.meta.name, "mnist");
    }

    #[test]
    fn delete_requires_terminal_state() {
        let m = manager();
        let id = m.submit(&spec()).unwrap();
        m.monitor().record(
            &id,
            Event::ContainerStarted { container: "c".into() },
        );
        assert!(m.delete(&id).is_err()); // Running
        m.monitor().record(&id, Event::Killed);
        m.delete(&id).unwrap();
        assert!(m.get(&id).is_err());
    }

    #[test]
    fn unknown_ids_error() {
        let m = manager();
        assert!(m.get("nope").is_err());
        assert!(m.kill("nope").is_err());
        assert!(m.delete("nope").is_err());
    }
}
