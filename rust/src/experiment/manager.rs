//! Experiment manager (paper Fig. 4): accepts experiment requests,
//! persists metadata in the [`MetaStore`] ("so that experiments become
//! easy to compare and reproducible"), and forwards to the configured
//! submitter.

use super::monitor::ExperimentMonitor;
use super::spec::{ExperimentSpec, ExperimentStatus};
use crate::orchestrator::Submitter;
use crate::storage::MetaStore;
use crate::util::json::Json;
use std::sync::Arc;

const NS: &str = "experiment";

/// Mirror a monitor-derived status into the experiment document (and
/// thus the `status` secondary index). No-ops when the doc is gone or
/// already current; storage failures are logged, not raised — the
/// monitor remains the live authority. A real transition bumps
/// `meta.resource_version` and lands on the change feed, which is what
/// lets `?watch=1` clients observe the execution pipeline's lifecycle
/// without polling.
pub fn persist_status(
    store: &MetaStore,
    id: &str,
    status: ExperimentStatus,
) {
    // atomic update: a concurrent delete() wins — a stale get-then-put
    // here must never resurrect a deleted experiment doc
    let res = store.update_rev(NS, id, |doc, rev| {
        if doc.str_field("status") == Some(status.as_str()) {
            Ok(None)
        } else {
            let doc = doc.clone().set(
                "status",
                Json::Str(status.as_str().to_string()),
            );
            // status churn moves resource_version, not generation
            Ok(Some(crate::resource::stamp_update(doc, id, rev, false)))
        }
    });
    if let Err(e) = res {
        crate::warnlog!(
            "experiment-manager",
            "failed to persist status of {id}: {e}"
        );
    }
}

/// The control-plane entry point for experiments.
pub struct ExperimentManager {
    store: Arc<MetaStore>,
    monitor: Arc<ExperimentMonitor>,
    submitter: Arc<dyn Submitter>,
}

impl ExperimentManager {
    pub fn new(
        store: Arc<MetaStore>,
        monitor: Arc<ExperimentMonitor>,
        submitter: Arc<dyn Submitter>,
    ) -> ExperimentManager {
        // filtered v2 lists walk this instead of scanning the namespace
        store.define_index(NS, "status", true);
        // label selectors (`?label=k=v`) walk k=v postings over meta
        store.define_index(NS, "meta.labels", false);
        // Docs persisted before the status field (or the unified meta
        // block) existed would vanish from filtered lists or carry no
        // resource_version; backfill both with the defaults the rest
        // of the system assumes.
        for (id, doc) in store.list(NS) {
            let needs_status = doc.str_field("status").is_none();
            let needs_meta = doc.get("meta").is_none();
            if needs_status || needs_meta {
                let accepted = ExperimentStatus::Accepted.as_str();
                let doc = doc.json().clone();
                let doc = if needs_status {
                    doc.set("status", Json::Str(accepted.into()))
                } else {
                    doc
                };
                if let Err(e) = store.put_rev(NS, &id, |rev| {
                    crate::resource::stamp_update(
                        doc, &id, rev, false,
                    )
                }) {
                    crate::warnlog!(
                        "experiment-manager",
                        "status/meta backfill of {id} failed: {e}"
                    );
                }
            }
        }
        ExperimentManager {
            store,
            monitor,
            submitter,
        }
    }

    pub fn monitor(&self) -> &Arc<ExperimentMonitor> {
        &self.monitor
    }

    /// Accept + persist + submit. Returns the experiment id.
    pub fn submit(&self, spec: &ExperimentSpec) -> crate::Result<String> {
        self.submit_labeled(spec, None)
    }

    /// [`Self::submit`] with client-supplied resource labels; the doc
    /// is stamped with the unified `meta` block (name, labels,
    /// resource_version, generation, timestamps).
    pub fn submit_labeled(
        &self,
        spec: &ExperimentSpec,
        labels: Option<&Json>,
    ) -> crate::Result<String> {
        let id = crate::util::id::next("experiment");
        let doc = Json::obj()
            .set("id", Json::Str(id.clone()))
            .set(
                "status",
                Json::Str(ExperimentStatus::Accepted.as_str().into()),
            )
            .set("spec", spec.to_json())
            .set(
                "submitter",
                Json::Str(self.submitter.name().to_string()),
            )
            .set(
                "accepted_at",
                Json::Num(crate::util::clock::unix_millis() as f64),
            );
        // validate labels before the write so a bad label map is a
        // clean 400 with nothing persisted
        let labels = match labels {
            Some(l) => Some(crate::resource::sanitize_labels(l)?),
            None => None,
        };
        self.store.put_rev(NS, &id, |rev| {
            crate::resource::stamp_new(doc, &id, labels.as_ref(), rev)
                .expect("labels sanitized above")
        })?;
        self.monitor.watch(&id, spec.total_containers());
        self.submitter.submit(&id, spec)?;
        crate::info!("experiment-manager", "accepted {id} ({})",
                     spec.meta.name);
        Ok(id)
    }

    pub fn get(&self, id: &str) -> crate::Result<Json> {
        let doc = self.store.get(NS, id).ok_or_else(|| {
            crate::SubmarineError::NotFound(format!("experiment {id}"))
        })?;
        Ok(doc.json().clone().set(
            "status",
            Json::Str(self.status(id).as_str().to_string()),
        ))
    }

    pub fn spec_of(&self, id: &str) -> crate::Result<ExperimentSpec> {
        let doc = self.store.get(NS, id).ok_or_else(|| {
            crate::SubmarineError::NotFound(format!("experiment {id}"))
        })?;
        ExperimentSpec::from_json(doc.get("spec").ok_or_else(|| {
            crate::SubmarineError::Storage("experiment doc missing spec"
                .into())
        })?)
    }

    /// Live status: the monitor when it has state for `id`, else the
    /// status persisted in the doc — so a Killed experiment is still
    /// Killed (and deletable) after a server restart, matching what
    /// the filtered lists report.
    pub fn status(&self, id: &str) -> ExperimentStatus {
        if self.monitor.is_watched(id) {
            return self.monitor.status(id);
        }
        self.store
            .get(NS, id)
            .and_then(|d| {
                d.str_field("status").and_then(ExperimentStatus::parse)
            })
            .unwrap_or(ExperimentStatus::Accepted)
    }

    /// [`Self::status`] when the caller already holds the doc (the
    /// generic resource layer renders rows this way — one monitor
    /// probe, no second store read).
    pub fn status_of_doc(
        &self,
        id: &str,
        doc: &Json,
    ) -> ExperimentStatus {
        if self.monitor.is_watched(id) {
            return self.monitor.status(id);
        }
        doc.str_field("status")
            .and_then(ExperimentStatus::parse)
            .unwrap_or(ExperimentStatus::Accepted)
    }

    pub fn list(&self) -> Vec<(String, ExperimentStatus)> {
        self.store
            .list(NS)
            .into_iter()
            .map(|(id, doc)| {
                let st = self.status_of_doc(&id, &doc);
                (id, st)
            })
            .collect()
    }

    /// One page of `(id, status)`, optionally filtered by status. The
    /// filter walks the `status` secondary index (O(log n + page))
    /// instead of scanning and filtering the namespace; the unfiltered
    /// path pages the primary map without cloning it whole.
    pub fn list_page(
        &self,
        status: Option<&str>,
        offset: usize,
        limit: Option<usize>,
    ) -> (Vec<(String, ExperimentStatus)>, usize) {
        let rows = |page: Vec<(String, std::sync::Arc<crate::storage::Doc>)>| {
            page.into_iter()
                .map(|(id, doc)| {
                    let st = self.status_of_doc(&id, &doc);
                    (id, st)
                })
                .collect()
        };
        match status {
            None => {
                let (page, total) = self.store.page(NS, offset, limit);
                (rows(page), total)
            }
            Some(filter) => {
                match self
                    .store
                    .index_page(NS, "status", filter, offset, limit)
                {
                    Ok((page, total)) => (rows(page), total),
                    // the index is declared in `new`; unreachable
                    Err(_) => (Vec::new(), 0),
                }
            }
        }
    }

    pub fn kill(&self, id: &str) -> crate::Result<()> {
        if self.store.get(NS, id).is_none() {
            return Err(crate::SubmarineError::NotFound(format!(
                "experiment {id}"
            )));
        }
        self.submitter.kill(id)?;
        // Submitters stop the containers; the terminal state is the
        // manager's responsibility (idempotent if the submitter already
        // reported it).
        self.monitor
            .record(id, super::monitor::Event::Killed);
        Ok(())
    }

    /// Delete a *terminal* experiment's metadata.
    pub fn delete(&self, id: &str) -> crate::Result<()> {
        let st = self.status(id);
        if !st.is_terminal() && self.store.get(NS, id).is_some() {
            return Err(crate::SubmarineError::InvalidSpec(format!(
                "experiment {id} is {}; kill it first",
                st.as_str()
            )));
        }
        if !self.store.delete(NS, id)? {
            return Err(crate::SubmarineError::NotFound(format!(
                "experiment {id}"
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::monitor::Event;

    /// No-op submitter for manager unit tests.
    struct NullSubmitter;
    impl Submitter for NullSubmitter {
        fn name(&self) -> &'static str {
            "null"
        }
        fn submit(&self, _id: &str, _spec: &ExperimentSpec)
            -> crate::Result<()>
        {
            Ok(())
        }
        fn kill(&self, _id: &str) -> crate::Result<()> {
            Ok(())
        }
    }

    fn manager() -> ExperimentManager {
        ExperimentManager::new(
            Arc::new(MetaStore::in_memory()),
            Arc::new(ExperimentMonitor::new()),
            Arc::new(NullSubmitter),
        )
    }

    fn spec() -> ExperimentSpec {
        ExperimentSpec::parse(
            r#"{"meta":{"name":"mnist"},
                "spec":{"Worker":{"replicas":2,"resources":"cpu=1"}}}"#,
        )
        .unwrap()
    }

    #[test]
    fn submit_persists_and_lists() {
        let m = manager();
        let id = m.submit(&spec()).unwrap();
        let doc = m.get(&id).unwrap();
        assert_eq!(doc.str_field("status"), Some("Accepted"));
        assert_eq!(
            doc.at(&["spec", "meta", "name"]).unwrap().as_str(),
            Some("mnist")
        );
        assert_eq!(m.list().len(), 1);
        let round = m.spec_of(&id).unwrap();
        assert_eq!(round.meta.name, "mnist");
    }

    #[test]
    fn delete_requires_terminal_state() {
        let m = manager();
        let id = m.submit(&spec()).unwrap();
        m.monitor().record(
            &id,
            Event::ContainerStarted { container: "c".into() },
        );
        assert!(m.delete(&id).is_err()); // Running
        m.monitor().record(&id, Event::Killed);
        m.delete(&id).unwrap();
        assert!(m.get(&id).is_err());
    }

    #[test]
    fn list_page_filters_via_status_index() {
        let store = Arc::new(MetaStore::in_memory());
        let monitor = Arc::new(ExperimentMonitor::new());
        let m = ExperimentManager::new(
            Arc::clone(&store),
            Arc::clone(&monitor),
            Arc::new(NullSubmitter),
        );
        // the same wiring Services installs
        let sink = Arc::clone(&store);
        monitor.set_observer(Box::new(move |id, st| {
            persist_status(&sink, id, st)
        }));
        let ids: Vec<_> =
            (0..4).map(|_| m.submit(&spec()).unwrap()).collect();
        m.monitor().record(&ids[0], Event::Killed);
        let (rows, total) = m.list_page(Some("accepted"), 0, None);
        assert_eq!(total, 3);
        assert!(rows
            .iter()
            .all(|(_, st)| *st == ExperimentStatus::Accepted));
        let (rows, total) = m.list_page(Some("killed"), 0, None);
        assert_eq!((rows.len(), total), (1, 1));
        assert_eq!(rows[0].0, ids[0]);
        let (rows, total) = m.list_page(None, 1, Some(2));
        assert_eq!(total, 4);
        assert_eq!(rows.len(), 2);
    }

    #[test]
    fn terminal_status_survives_restart() {
        let store = Arc::new(MetaStore::in_memory());
        let monitor = Arc::new(ExperimentMonitor::new());
        let a = ExperimentManager::new(
            Arc::clone(&store),
            Arc::clone(&monitor),
            Arc::new(NullSubmitter),
        );
        let sink = Arc::clone(&store);
        monitor.set_observer(Box::new(move |id, st| {
            persist_status(&sink, id, st)
        }));
        let id = a.submit(&spec()).unwrap();
        a.kill(&id).unwrap();
        // "restart": same store, fresh monitor with no state — the
        // persisted status must win over the Accepted default, and the
        // experiment must stay deletable
        let b = ExperimentManager::new(
            Arc::clone(&store),
            Arc::new(ExperimentMonitor::new()),
            Arc::new(NullSubmitter),
        );
        assert_eq!(b.status(&id), ExperimentStatus::Killed);
        b.delete(&id).unwrap();
        assert!(b.get(&id).is_err());
    }

    #[test]
    fn unknown_ids_error() {
        let m = manager();
        assert!(m.get("nope").is_err());
        assert!(m.kill("nope").is_err());
        assert!(m.delete("nope").is_err());
    }
}
