//! Per-connection state machine for the epoll reactor.
//!
//! A [`Conn`] owns one nonblocking socket plus its reusable read/write
//! buffers and tracks where the connection is in the request cycle:
//!
//! ```text
//! ReadHeaders -> ReadBody -> Handle -> WriteResponse -+-> KeepAliveIdle
//!      ^                                              |      |
//!      +----------------------------------------------+------+
//!                                                     +-> Tail (parked
//!                                                         watch/stream)
//! ```
//!
//! All reads and writes are partial-tolerant: `EAGAIN` leaves the
//! buffers where they were and the reactor resumes on the next
//! readiness event. Parsing reuses [`Request::read_next_tracked`] over
//! the buffered bytes, so the wire dialect (header folding, body caps,
//! envelope tracking for transport errors) is identical to the
//! blocking path.

use super::http::Request;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Instant;

/// Reject header blocks beyond this size (slow-loris cap).
pub const MAX_HEADER_BYTES: usize = 256 * 1024;
/// Body cap, matching [`Request::read_next_tracked`]'s limit.
pub const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;
/// Read granularity for the per-connection buffer.
const READ_CHUNK: usize = 16 * 1024;

/// Where a connection is in its request/response cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnState {
    /// Accumulating bytes until the header terminator appears.
    ReadHeaders,
    /// Headers parsed structurally; waiting for `content-length`
    /// bytes of body.
    ReadBody,
    /// A full request was handed to the worker pool; awaiting its
    /// response.
    Handle,
    /// Draining the framed response from `wbuf`.
    WriteResponse,
    /// Between keep-alive requests.
    KeepAliveIdle,
    /// Parked on a resumable watch/stream tail.
    Tail,
}

/// Result of one nonblocking read pass.
#[derive(Debug, PartialEq, Eq)]
pub enum ReadOutcome {
    /// New bytes were buffered.
    Progress,
    /// Nothing available right now (`EAGAIN`).
    WouldBlock,
    /// Orderly peer close.
    Eof,
    /// Hard socket error; close the connection.
    Err,
}

/// Result of one nonblocking write pass.
#[derive(Debug, PartialEq, Eq)]
pub enum WriteOutcome {
    /// `wbuf` fully drained.
    Done,
    /// Partial write; resume on the next writability event.
    Blocked,
    /// Hard socket error; close the connection.
    Err,
}

/// Result of attempting to parse one request from the read buffer.
pub enum ParseOutcome {
    /// Not enough bytes yet. `in_body` distinguishes "still reading
    /// headers" from "headers done, body incomplete" for state
    /// accounting.
    Partial { in_body: bool },
    /// One complete request; its bytes were consumed from the buffer.
    Complete(Box<Request>),
    /// Malformed or oversized request — answer 400 and close.
    Bad(crate::SubmarineError),
}

/// One reactor-managed connection.
pub struct Conn {
    pub stream: TcpStream,
    pub state: ConnState,
    /// Buffered inbound bytes; `rpos..` is the unconsumed region.
    pub rbuf: Vec<u8>,
    pub rpos: usize,
    /// Outbound bytes; `wpos..` is the unwritten region.
    pub wbuf: Vec<u8>,
    pub wpos: usize,
    /// Requests served on this connection (keep-alive budget).
    pub served: u32,
    /// Keep the connection open once the current response drains.
    pub keep: bool,
    /// Last moment the connection went idle (for the reap sweep).
    pub idle_since: Instant,
    /// Set when the first byte of a new request arrives; cleared when
    /// the request completes. Drives the 408 sweep.
    pub req_start: Option<Instant>,
    /// Path of the request currently being read, as soon as the
    /// request line parses — picks the error envelope for 400/408.
    pub seen_path: Option<String>,
    /// Cached epoll interest mask, so re-arms only issue `EPOLL_CTL_MOD`
    /// when the mask actually changes.
    pub interest: u32,
    /// Peer closed its write side: serve whatever is already
    /// buffered, then close instead of re-entering keep-alive.
    pub eof: bool,
}

impl Conn {
    pub fn new(stream: TcpStream, now: Instant) -> Conn {
        Conn {
            stream,
            state: ConnState::ReadHeaders,
            rbuf: Vec::with_capacity(4 * 1024),
            rpos: 0,
            wbuf: Vec::with_capacity(4 * 1024),
            wpos: 0,
            served: 0,
            keep: true,
            idle_since: now,
            req_start: None,
            seen_path: None,
            interest: 0,
            eof: false,
        }
    }

    /// Pull whatever the socket has into `rbuf` (one bounded pass —
    /// the reactor loops while this reports progress).
    pub fn read_some(&mut self) -> ReadOutcome {
        if self.rpos > 0
            && (self.rpos == self.rbuf.len() || self.rpos >= READ_CHUNK)
        {
            self.rbuf.drain(..self.rpos);
            self.rpos = 0;
        }
        let old = self.rbuf.len();
        self.rbuf.resize(old + READ_CHUNK, 0);
        let got = self.stream.read(&mut self.rbuf[old..]);
        match got {
            Ok(0) => {
                self.rbuf.truncate(old);
                ReadOutcome::Eof
            }
            Ok(n) => {
                self.rbuf.truncate(old + n);
                ReadOutcome::Progress
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::Interrupted =>
            {
                self.rbuf.truncate(old);
                ReadOutcome::WouldBlock
            }
            Err(_) => {
                self.rbuf.truncate(old);
                ReadOutcome::Err
            }
        }
    }

    /// Drain as much of `wbuf` as the socket accepts right now.
    pub fn flush_out(&mut self) -> WriteOutcome {
        while self.wpos < self.wbuf.len() {
            let put = self.stream.write(&self.wbuf[self.wpos..]);
            match put {
                Ok(0) => return WriteOutcome::Err,
                Ok(n) => self.wpos += n,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    return WriteOutcome::Blocked;
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => return WriteOutcome::Err,
            }
        }
        self.wbuf.clear();
        self.wpos = 0;
        WriteOutcome::Done
    }

    /// Bytes queued but not yet written.
    pub fn pending_out(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// Unconsumed inbound bytes (pipelined next request, usually).
    pub fn pending_in(&self) -> bool {
        self.rpos < self.rbuf.len()
    }

    /// Attempt to parse one request from the buffered bytes,
    /// consuming them on success and updating the 408 bookkeeping.
    pub fn try_parse(&mut self) -> ParseOutcome {
        if self.pending_in() && self.req_start.is_none() {
            self.req_start = Some(Instant::now());
        }
        let (consumed, outcome) =
            parse_ready(&self.rbuf[self.rpos..], &mut self.seen_path);
        match &outcome {
            ParseOutcome::Complete(_) => {
                self.rpos += consumed;
                self.req_start = None;
            }
            ParseOutcome::Partial { in_body } => {
                self.set_state(if *in_body {
                    ConnState::ReadBody
                } else {
                    ConnState::ReadHeaders
                });
            }
            ParseOutcome::Bad(_) => {}
        }
        outcome
    }

    /// Reset per-request bookkeeping after a response fully drains.
    pub fn await_next_request(&mut self, now: Instant) {
        self.set_state(ConnState::KeepAliveIdle);
        self.req_start = None;
        self.seen_path = None;
        self.idle_since = now;
        if self.rpos > 0 && !self.pending_in() {
            self.rbuf.clear();
            self.rpos = 0;
        }
    }

    /// The single funnel for state changes. The lint's conn-state pass
    /// rejects direct `.state = ...` stores anywhere else, and debug
    /// builds check every change against the declared transition table
    /// in `analysis::conn_contract` — the same table the static pass
    /// verifies the reactor against. Re-asserting the current state is
    /// a no-op (self-loops are always legal).
    pub fn set_state(&mut self, to: ConnState) {
        if self.state == to {
            return;
        }
        #[cfg(debug_assertions)]
        assert!(
            crate::analysis::conn_contract::transition_allowed(
                self.state, to
            ),
            "undeclared conn state transition {:?} -> {:?}",
            self.state,
            to
        );
        self.state = to;
    }
}

/// Index one past the blank line terminating the header block, if the
/// buffer holds one.
fn header_end(buf: &[u8]) -> Option<usize> {
    let mut line_start = 0;
    for (i, &b) in buf.iter().enumerate() {
        if b != b'\n' {
            continue;
        }
        let mut line = &buf[line_start..i];
        if let [rest @ .., b'\r'] = line {
            line = rest;
        }
        if line.is_empty() && line_start > 0 {
            return Some(i + 1);
        }
        line_start = i + 1;
    }
    None
}

/// Declared `content-length` of a complete header block (last
/// occurrence wins, matching the map-based parser).
fn content_length(head: &[u8]) -> usize {
    let mut len = 0usize;
    for line in head.split(|&b| b == b'\n') {
        let line = std::str::from_utf8(line).unwrap_or("");
        let line = line.trim_end_matches('\r');
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                len = v.trim().parse().unwrap_or(0);
            }
        }
    }
    len
}

/// Parse one request out of `buf` if it is complete, returning how
/// many bytes it occupied. Shared with unit tests; [`Conn::try_parse`]
/// wraps it with buffer bookkeeping.
pub fn parse_ready(
    buf: &[u8],
    seen_path: &mut Option<String>,
) -> (usize, ParseOutcome) {
    let Some(head_end) = header_end(buf) else {
        if buf.len() > MAX_HEADER_BYTES {
            return (
                0,
                ParseOutcome::Bad(crate::SubmarineError::InvalidSpec(
                    "http: header block too large".to_string(),
                )),
            );
        }
        return (0, ParseOutcome::Partial { in_body: false });
    };
    let body_len = content_length(&buf[..head_end]);
    if body_len > MAX_BODY_BYTES {
        // run the shared parser over just the headers so the
        // canonical "body too large" error (and envelope tracking)
        // comes from one place
        let mut slice = &buf[..head_end];
        let err = match Request::read_next_tracked(&mut slice, seen_path)
        {
            Err(e) => e,
            Ok(_) => crate::SubmarineError::InvalidSpec(
                "http: body too large".to_string(),
            ),
        };
        return (0, ParseOutcome::Bad(err));
    }
    let total = head_end + body_len;
    if buf.len() < total {
        return (0, ParseOutcome::Partial { in_body: true });
    }
    let mut slice = &buf[..total];
    match Request::read_next_tracked(&mut slice, seen_path) {
        Ok(Some(req)) => (total, ParseOutcome::Complete(Box::new(req))),
        Ok(None) => (
            0,
            ParseOutcome::Bad(crate::SubmarineError::InvalidSpec(
                "http: empty request".to_string(),
            )),
        ),
        Err(e) => (0, ParseOutcome::Bad(e)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(buf: &[u8]) -> (usize, ParseOutcome) {
        let mut seen = None;
        parse_ready(buf, &mut seen)
    }

    #[test]
    fn partial_headers_wait_for_more() {
        let (n, out) = parse(b"GET /x HTTP/1.1\r\nHost: a\r\n");
        assert_eq!(n, 0);
        assert!(matches!(out, ParseOutcome::Partial { in_body: false }));
    }

    #[test]
    fn partial_body_waits_for_more() {
        let raw = b"POST /x HTTP/1.1\r\ncontent-length: 10\r\n\r\nabc";
        let (n, out) = parse(raw);
        assert_eq!(n, 0);
        assert!(matches!(out, ParseOutcome::Partial { in_body: true }));
    }

    #[test]
    fn complete_request_consumes_exactly_its_bytes() {
        let raw =
            b"POST /x HTTP/1.1\r\ncontent-length: 3\r\n\r\nabcGET /y ";
        let (n, out) = parse(raw);
        let ParseOutcome::Complete(req) = out else {
            panic!("expected complete");
        };
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"abc");
        assert_eq!(&raw[n..], b"GET /y ");
    }

    #[test]
    fn pipelined_requests_parse_in_sequence() {
        let raw = b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let (n, out) = parse(raw);
        assert!(matches!(out, ParseOutcome::Complete(_)));
        let (m, out2) = parse(&raw[n..]);
        let ParseOutcome::Complete(req2) = out2 else {
            panic!("expected second request");
        };
        assert_eq!(req2.path, "/b");
        assert_eq!(n + m, raw.len());
    }

    #[test]
    fn bad_version_is_rejected_with_path_tracked() {
        let mut seen = None;
        let (_, out) =
            parse_ready(b"GET /api/v2/x SPDY/9\r\n\r\n", &mut seen);
        assert!(matches!(out, ParseOutcome::Bad(_)));
        assert_eq!(seen.as_deref(), Some("/api/v2/x"));
    }

    #[test]
    fn oversized_header_block_is_rejected() {
        let mut raw = b"GET /x HTTP/1.1\r\n".to_vec();
        raw.extend(std::iter::repeat(b'a').take(MAX_HEADER_BYTES + 2));
        let (_, out) = parse(&raw);
        assert!(matches!(out, ParseOutcome::Bad(_)));
    }

    #[test]
    fn oversized_declared_body_is_rejected_before_buffering() {
        let raw = format!(
            "POST /x HTTP/1.1\r\ncontent-length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        let (_, out) = parse(raw.as_bytes());
        assert!(matches!(out, ParseOutcome::Bad(_)));
    }

    #[test]
    fn bare_lf_line_endings_are_tolerated() {
        let (_, out) = parse(b"GET /x HTTP/1.1\nHost: a\n\n");
        assert!(matches!(out, ParseOutcome::Complete(_)));
    }

    fn test_conn() -> Conn {
        let listener =
            std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stream = std::net::TcpStream::connect(addr).unwrap();
        let _accepted = listener.accept().unwrap();
        Conn::new(stream, Instant::now())
    }

    #[test]
    fn set_state_walks_the_declared_cycle() {
        let mut c = test_conn();
        assert_eq!(c.state, ConnState::ReadHeaders);
        for to in [
            ConnState::ReadBody,
            ConnState::Handle,
            ConnState::Tail,
            ConnState::WriteResponse,
            ConnState::KeepAliveIdle,
            ConnState::ReadHeaders,
        ] {
            c.set_state(to);
            assert_eq!(c.state, to);
        }
        // re-asserting the current state is always a no-op
        c.set_state(ConnState::ReadHeaders);
        assert_eq!(c.state, ConnState::ReadHeaders);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "undeclared conn state transition")]
    fn set_state_rejects_undeclared_transition() {
        let mut c = test_conn();
        c.set_state(ConnState::Handle);
        c.set_state(ConnState::WriteResponse);
        // a drained response can never jump back into a body read
        c.set_state(ConnState::ReadBody);
    }
}
