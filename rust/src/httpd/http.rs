//! HTTP/1.1 request parsing and response serialization (std-only).
//!
//! v2 upgrade: persistent connections. Requests carry their HTTP version
//! so the server can honor HTTP/1.1 keep-alive semantics, responses are
//! always content-length framed, and [`Request::read_next`] distinguishes
//! a cleanly closed idle connection from a malformed request.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without query string.
    pub path: String,
    pub query: BTreeMap<String, String>,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
    /// `HTTP/1.1` or `HTTP/1.0` (keep-alive defaults differ).
    pub version: String,
}

impl Request {
    /// Parse one request from a stream.
    pub fn read_from<R: Read>(stream: R) -> crate::Result<Request> {
        let mut reader = BufReader::new(stream);
        match Self::read_next(&mut reader)? {
            Some(req) => Ok(req),
            None => Err(bad("missing method")),
        }
    }

    /// Parse one request from a buffered reader. Returns `Ok(None)`
    /// when the peer closed the connection before sending anything —
    /// the clean end of a keep-alive session.
    ///
    /// Takes the reader by `&mut` so one `BufReader` can span a whole
    /// keep-alive connection: any read-ahead beyond this request (e.g.
    /// a pipelined next request) stays buffered for the next call
    /// instead of being dropped with a per-request reader.
    pub fn read_next<R: BufRead>(
        reader: &mut R,
    ) -> crate::Result<Option<Request>> {
        Self::read_next_tracked(reader, &mut None)
    }

    /// [`Self::read_next`] that records the request path as soon as the
    /// request line parses, even when the rest of the request errors
    /// out — so transport-layer error responses (400/408) can pick the
    /// envelope matching the API version the client was talking to.
    pub fn read_next_tracked<R: BufRead>(
        reader: &mut R,
        seen_path: &mut Option<String>,
    ) -> crate::Result<Option<Request>> {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Ok(None); // EOF before a request line
        }
        let mut parts = line.trim_end().split(' ');
        let method = parts
            .next()
            .filter(|m| !m.is_empty())
            .ok_or_else(|| bad("missing method"))?
            .to_string();
        let target = parts.next().ok_or_else(|| bad("missing path"))?;
        let version = parts.next().unwrap_or("");
        let (path, query) = match target.split_once('?') {
            Some((p, q)) => (p.to_string(), parse_query(q)),
            None => (target.to_string(), BTreeMap::new()),
        };
        *seen_path = Some(path.clone());
        if !version.starts_with("HTTP/1.") {
            return Err(bad("unsupported HTTP version"));
        }
        let mut headers = BTreeMap::new();
        loop {
            let mut h = String::new();
            reader.read_line(&mut h)?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                headers.insert(
                    k.trim().to_ascii_lowercase(),
                    v.trim().to_string(),
                );
            }
        }
        let len: usize = headers
            .get("content-length")
            .and_then(|v| v.parse().ok())
            .unwrap_or(0);
        if len > 64 * 1024 * 1024 {
            return Err(bad("body too large"));
        }
        let mut body = vec![0u8; len];
        if len > 0 {
            reader.read_exact(&mut body)?;
        }
        Ok(Some(Request {
            method,
            path,
            query,
            headers,
            body,
            version: version.to_string(),
        }))
    }

    /// Bare request for unit tests and benches (no I/O).
    pub fn synthetic(method: &str, path: &str) -> Request {
        let (path, query) = match path.split_once('?') {
            Some((p, q)) => (p.to_string(), parse_query(q)),
            None => (path.to_string(), BTreeMap::new()),
        };
        Request {
            method: method.to_string(),
            path,
            query,
            headers: BTreeMap::new(),
            body: Vec::new(),
            version: "HTTP/1.1".to_string(),
        }
    }

    pub fn json(&self) -> crate::Result<Json> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|_| bad("body is not utf-8"))?;
        Ok(Json::parse(text)?)
    }

    pub fn bearer_token(&self) -> Option<&str> {
        self.headers
            .get("authorization")?
            .strip_prefix("Bearer ")
    }

    /// HTTP/1.1 defaults to keep-alive unless `connection: close`;
    /// HTTP/1.0 defaults to close unless `connection: keep-alive`.
    pub fn wants_keep_alive(&self) -> bool {
        let conn = self
            .headers
            .get("connection")
            .map(|c| c.to_ascii_lowercase());
        if self.version == "HTTP/1.0" {
            conn.as_deref() == Some("keep-alive")
        } else {
            conn.as_deref() != Some("close")
        }
    }
}

fn parse_query(q: &str) -> BTreeMap<String, String> {
    q.split('&')
        .filter_map(|kv| {
            let (k, v) = kv.split_once('=')?;
            Some((url_decode(k), url_decode(v)))
        })
        .collect()
}

fn url_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' if i + 2 < bytes.len() + 1 && i + 2 < bytes.len() => {
                let hex = std::str::from_utf8(&bytes[i + 1..i + 3]).ok();
                match hex.and_then(|h| u8::from_str_radix(h, 16).ok()) {
                    Some(b) => {
                        out.push(b);
                        i += 3;
                    }
                    None => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn bad(msg: &str) -> crate::SubmarineError {
    crate::SubmarineError::InvalidSpec(format!("http: {msg}"))
}

/// Sink handed to a [`StreamProducer`]: each `chunk` call becomes one
/// HTTP/1.1 chunked-transfer frame, flushed immediately so watch
/// clients see events as they happen.
pub struct ChunkSink<'a> {
    w: &'a mut dyn Write,
}

impl ChunkSink<'_> {
    pub fn chunk(&mut self, data: &[u8]) -> std::io::Result<()> {
        if data.is_empty() {
            return Ok(()); // an empty chunk would terminate the stream
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }
}

/// Producer for a chunked-transfer streaming response body (the
/// `?watch=1&stream=1` path). Invoked once with the live socket's
/// chunk sink after the headers are written.
pub type StreamProducer =
    Box<dyn FnOnce(&mut ChunkSink<'_>) -> std::io::Result<()> + Send>;

/// Interior slot for the stream producer so `Response` can keep its
/// by-reference write API (the producer is taken on first write).
pub struct StreamBody(pub std::sync::Mutex<Option<StreamProducer>>);

impl StreamBody {
    pub fn new(producer: StreamProducer) -> StreamBody {
        StreamBody(std::sync::Mutex::new(Some(producer)))
    }
}

/// One advance of a resumable response tail (see [`TailSource`]).
pub enum TailStep {
    /// Nothing to emit yet; park until woken or `deadline()` passes.
    Pending,
    /// Pre-framed chunked bytes to write; the tail stays parked for
    /// more.
    Data(Vec<u8>),
    /// Final bytes (terminal chunk included); the connection closes
    /// after they drain.
    End(Vec<u8>),
    /// A long-poll tail resolved into a complete framed response.
    Respond(Box<Response>),
}

/// A resumable producer for a deferred response tail. Unlike
/// [`StreamProducer`] — which owns the socket until the stream ends —
/// a `TailSource` is *stepped*: each call emits whatever is ready and
/// returns, so the epoll reactor can hold thousands of watch streams
/// as parked entries instead of pinned threads. Blocking callers
/// (dedicated connection threads, benches writing into a `Vec`) drive
/// the same source in a loop via [`Response::write_to_opts`], using
/// `wait` between `Pending` steps.
pub trait TailSource: Send {
    /// Advance the tail. `now` is passed in so deadline checks and the
    /// reactor's sweep clock agree.
    fn step(&mut self, now: std::time::Instant) -> TailStep;
    /// Absolute time at which the tail must finish (bookmark or
    /// timeout response).
    fn deadline(&self) -> std::time::Instant;
    /// Block the calling thread until new data may be available, at
    /// most `max`. Only used by blocking drivers; the reactor relies
    /// on its wakeup pump instead.
    fn wait(&self, max: std::time::Duration);
}

/// Interior slot for a [`TailSource`] so `Response` keeps its
/// by-reference write API (the source is taken once, by whichever
/// driver ends up owning the tail).
pub struct TailBody {
    pub source: std::sync::Mutex<Option<Box<dyn TailSource>>>,
    /// `true`: chunked-transfer stream, connection closes at the end.
    /// `false`: long-poll — the tail resolves into one framed
    /// response and keep-alive is preserved.
    pub chunked: bool,
}

/// Append one HTTP/1.1 chunked-transfer frame for `data` to `out`.
/// Empty chunks are skipped — an empty chunk would terminate the
/// stream early.
pub fn chunk_frame_into(out: &mut Vec<u8>, data: &[u8]) {
    if data.is_empty() {
        return;
    }
    let _ = write!(out, "{:x}\r\n", data.len());
    out.extend_from_slice(data);
    out.extend_from_slice(b"\r\n");
}

/// The terminal chunk that ends a chunked-transfer body.
pub const CHUNK_TERMINAL: &[u8] = b"0\r\n\r\n";

/// An HTTP response.
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra response headers (e.g. `Allow` on 405).
    pub headers: Vec<(String, String)>,
    /// When set, the response body is produced incrementally with
    /// chunked transfer-encoding and the connection closes after the
    /// stream ends; `body` is ignored.
    pub stream: Option<StreamBody>,
    /// When set, the response completes via a resumable [`TailSource`]
    /// (watch streams and long polls); `body` is ignored for chunked
    /// tails and replaced by the resolved response for poll tails.
    pub tail: Option<TailBody>,
    /// Advertised `Content-Length` when the body is intentionally not
    /// materialized (the HEAD fast path over a cached encoded body).
    /// `None` means "length of `body`".
    pub declared_len: Option<usize>,
}

impl std::fmt::Debug for Response {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Response")
            .field("status", &self.status)
            .field("content_type", &self.content_type)
            .field("body_len", &self.body.len())
            .field("headers", &self.headers)
            .field("stream", &self.stream.is_some())
            .field("tail", &self.tail.is_some())
            .finish()
    }
}

impl Response {
    pub fn json(status: u16, body: Json) -> Response {
        let mut buf = Vec::with_capacity(128);
        body.dump_into(&mut buf);
        Self::from_bytes(status, "application/json", buf)
    }

    /// A response over a pre-serialized body (the cached-document fast
    /// path splices stored bytes instead of re-serializing).
    pub fn from_bytes(
        status: u16,
        content_type: &'static str,
        body: Vec<u8>,
    ) -> Response {
        Response {
            status,
            content_type,
            body,
            headers: Vec::new(),
            stream: None,
            tail: None,
            declared_len: None,
        }
    }

    /// A body-less response advertising `Content-Length: len` — HEAD
    /// answered from a cached encoded body without ever materializing
    /// the bytes that would not be sent.
    pub fn head_with_len(
        status: u16,
        content_type: &'static str,
        len: usize,
    ) -> Response {
        Response {
            status,
            content_type,
            body: Vec::new(),
            headers: Vec::new(),
            stream: None,
            tail: None,
            declared_len: Some(len),
        }
    }

    /// A chunked-transfer streaming response (see [`StreamProducer`]).
    pub fn stream(
        status: u16,
        content_type: &'static str,
        producer: StreamProducer,
    ) -> Response {
        Response {
            status,
            content_type,
            body: Vec::new(),
            headers: Vec::new(),
            stream: Some(StreamBody::new(producer)),
            tail: None,
            declared_len: None,
        }
    }

    /// A chunked-transfer streaming response driven by a resumable
    /// [`TailSource`]. The reactor parks these as cheap per-connection
    /// entries; blocking drivers step the source in place.
    pub fn tail_stream(
        status: u16,
        content_type: &'static str,
        source: Box<dyn TailSource>,
    ) -> Response {
        Response {
            status,
            content_type,
            body: Vec::new(),
            headers: Vec::new(),
            stream: None,
            tail: Some(TailBody {
                source: std::sync::Mutex::new(Some(source)),
                chunked: true,
            }),
            declared_len: None,
        }
    }

    /// A deferred framed response (the long-poll watch path): the
    /// source is stepped until it yields [`TailStep::Respond`], whose
    /// response is then written with the normal framing — keep-alive
    /// preserved.
    pub fn tail_poll(source: Box<dyn TailSource>) -> Response {
        Response {
            status: 200,
            content_type: "application/json",
            body: Vec::new(),
            headers: Vec::new(),
            stream: None,
            tail: Some(TailBody {
                source: std::sync::Mutex::new(Some(source)),
                chunked: false,
            }),
            declared_len: None,
        }
    }

    pub fn is_stream(&self) -> bool {
        self.stream.is_some()
    }

    pub fn is_tail(&self) -> bool {
        self.tail.is_some()
    }

    /// Take ownership of the tail source (at most one caller wins).
    /// Returns the source and whether the tail is chunked.
    pub fn take_tail(&self) -> Option<(Box<dyn TailSource>, bool)> {
        let tail = self.tail.as_ref()?;
        let src = tail
            .source
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()?;
        Some((src, tail.chunked))
    }

    /// True when the connection cannot be reused after this response:
    /// chunked bodies (producer streams and chunked tails) always end
    /// with `connection: close`.
    pub fn closes_after(&self) -> bool {
        self.stream.is_some()
            || self.tail.as_ref().map(|t| t.chunked).unwrap_or(false)
    }

    pub fn ok(body: Json) -> Response {
        Self::json(200, body)
    }

    /// Attach an extra header (builder style).
    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }

    /// Submarine-style v1 envelope: `{"status":"OK","result":...}`.
    pub fn ok_result(result: Json) -> Response {
        Self::json(
            200,
            Json::obj()
                .set("status", Json::Str("OK".into()))
                .set("result", result),
        )
    }

    /// v1 error envelope: `{"status":"ERROR","message":...}`.
    pub fn error(status: u16, msg: &str) -> Response {
        Self::json(
            status,
            Json::obj()
                .set("status", Json::Str("ERROR".into()))
                .set("message", Json::Str(msg.to_string())),
        )
    }

    pub fn from_err(e: &crate::SubmarineError) -> Response {
        Self::error(e.http_status(), &e.to_string())
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            401 => "Unauthorized",
            404 => "Not Found",
            405 => "Method Not Allowed",
            408 => "Request Timeout",
            409 => "Conflict",
            410 => "Gone",
            412 => "Precondition Failed",
            429 => "Too Many Requests",
            503 => "Service Unavailable",
            _ => "Internal Server Error",
        }
    }

    /// Serialize with `connection: close` (the v1 single-shot framing).
    pub fn write_to<W: Write>(&self, w: W) -> std::io::Result<()> {
        self.write_to_opts(w, false, false)
    }

    /// Serialize with explicit connection semantics. `head_only` writes
    /// status line and headers (content-length included, per HEAD
    /// semantics) but suppresses the body.
    pub fn write_to_opts<W: Write>(
        &self,
        mut w: W,
        keep_alive: bool,
        head_only: bool,
    ) -> std::io::Result<()> {
        if self.tail.is_some() {
            return self.drive_tail(w, keep_alive, head_only);
        }
        if let Some(stream) = &self.stream {
            // Chunked transfer: the body length is unknown up front
            // (watch events arrive over time). Streams always close
            // the connection when done — the producer may have ended
            // mid-event on error, so the socket can't be trusted for
            // another framed exchange.
            self.write_stream_head(&mut w)?;
            if !head_only {
                // poison recovery: a panicked producer elsewhere must
                // not kill every later streaming response
                if let Some(producer) = stream
                    .0
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .take()
                {
                    let mut sink = ChunkSink { w: &mut w };
                    producer(&mut sink)?;
                }
                w.write_all(CHUNK_TERMINAL)?;
            }
            return w.flush();
        }
        write!(
            w,
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\n",
            self.status,
            self.reason(),
            self.content_type,
            self.declared_len.unwrap_or(self.body.len())
        )?;
        for (k, v) in &self.headers {
            write!(w, "{k}: {v}\r\n")?;
        }
        write!(
            w,
            "connection: {}\r\n\r\n",
            if keep_alive { "keep-alive" } else { "close" }
        )?;
        if !head_only {
            w.write_all(&self.body)?;
        }
        w.flush()
    }

    /// Status line + headers for a chunked-transfer body. Shared by
    /// the blocking stream paths and the reactor (which frames the
    /// head into a connection's write buffer before parking the tail).
    pub fn write_stream_head<W: Write>(
        &self,
        w: &mut W,
    ) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\n\
             transfer-encoding: chunked\r\n",
            self.status,
            self.reason(),
            self.content_type,
        )?;
        for (k, v) in &self.headers {
            write!(w, "{k}: {v}\r\n")?;
        }
        write!(w, "connection: close\r\n\r\n")
    }

    /// Blocking driver for tail responses, so callers that own their
    /// socket (dedicated connection threads, tests, benches writing
    /// into a `Vec`) produce byte-identical output to the reactor's
    /// parked path.
    fn drive_tail<W: Write>(
        &self,
        mut w: W,
        keep_alive: bool,
        head_only: bool,
    ) -> std::io::Result<()> {
        let taken = self.take_tail();
        let Some((mut source, chunked)) = taken else {
            // source already consumed elsewhere; emit a safe fallback
            return Response::error(500, "response tail already taken")
                .write_to_opts(w, false, head_only);
        };
        if chunked {
            self.write_stream_head(&mut w)?;
            if head_only {
                return w.flush();
            }
            loop {
                let now = std::time::Instant::now();
                match source.step(now) {
                    TailStep::Pending => {
                        let max = source
                            .deadline()
                            .saturating_duration_since(now)
                            .min(std::time::Duration::from_millis(250));
                        source.wait(max);
                    }
                    TailStep::Data(bytes) => {
                        w.write_all(&bytes)?;
                        w.flush()?;
                    }
                    TailStep::End(bytes) => {
                        w.write_all(&bytes)?;
                        return w.flush();
                    }
                    TailStep::Respond(_) => {
                        // a poll step misrouted into a chunked tail:
                        // end the stream cleanly rather than corrupt
                        // the framing
                        w.write_all(CHUNK_TERMINAL)?;
                        return w.flush();
                    }
                }
            }
        }
        // Long poll: step until the source resolves into a framed
        // response, then write it with the caller's connection
        // semantics (keep-alive preserved).
        loop {
            let now = std::time::Instant::now();
            match source.step(now) {
                TailStep::Pending => {
                    let max = source
                        .deadline()
                        .saturating_duration_since(now)
                        .min(std::time::Duration::from_millis(250));
                    source.wait(max);
                }
                TailStep::Respond(r) => {
                    return r.write_to_opts(w, keep_alive, head_only);
                }
                TailStep::Data(_) | TailStep::End(_) => {
                    return Response::error(
                        500,
                        "stream step from a long-poll tail",
                    )
                    .write_to_opts(w, keep_alive, head_only);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_get_with_query() {
        let raw = b"GET /api/v1/experiment?limit=5&name=m+x HTTP/1.1\r\nHost: x\r\n\r\n";
        let r = Request::read_from(&raw[..]).unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/api/v1/experiment");
        assert_eq!(r.query["limit"], "5");
        assert_eq!(r.query["name"], "m x");
        assert_eq!(r.version, "HTTP/1.1");
        assert!(r.wants_keep_alive());
    }

    #[test]
    fn parses_post_with_body() {
        let body = r#"{"a":1}"#;
        let raw = format!(
            "POST /x HTTP/1.1\r\ncontent-length: {}\r\nAuthorization: Bearer tok123\r\n\r\n{}",
            body.len(),
            body
        );
        let r = Request::read_from(raw.as_bytes()).unwrap();
        assert_eq!(r.json().unwrap().num_field("a"), Some(1.0));
        assert_eq!(r.bearer_token(), Some("tok123"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Request::read_from(&b""[..]).is_err());
        assert!(Request::read_from(&b"GET /x SPDY/9\r\n\r\n"[..]).is_err());
    }

    #[test]
    fn read_next_signals_clean_eof() {
        assert!(Request::read_next(&mut &b""[..]).unwrap().is_none());
        let raw = b"GET /x HTTP/1.1\r\n\r\n";
        assert!(Request::read_next(&mut &raw[..]).unwrap().is_some());
    }

    #[test]
    fn read_next_preserves_pipelined_requests() {
        let raw = b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let mut reader = &raw[..];
        let a = Request::read_next(&mut reader).unwrap().unwrap();
        let b = Request::read_next(&mut reader).unwrap().unwrap();
        assert_eq!(a.path, "/a");
        assert_eq!(b.path, "/b");
        assert!(Request::read_next(&mut reader).unwrap().is_none());
    }

    #[test]
    fn keep_alive_defaults_by_version() {
        let mut r = Request::synthetic("GET", "/x");
        assert!(r.wants_keep_alive()); // 1.1 default
        r.headers.insert("connection".into(), "close".into());
        assert!(!r.wants_keep_alive());
        let mut r10 = Request::synthetic("GET", "/x");
        r10.version = "HTTP/1.0".into();
        assert!(!r10.wants_keep_alive()); // 1.0 default
        r10.headers
            .insert("connection".into(), "Keep-Alive".into());
        assert!(r10.wants_keep_alive());
    }

    #[test]
    fn response_serializes() {
        let r = Response::ok_result(Json::Str("hi".into()));
        let mut buf = Vec::new();
        r.write_to(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains(r#""status":"OK""#));
        assert!(text.contains("connection: close\r\n"));
    }

    #[test]
    fn keep_alive_and_head_framing() {
        let r = Response::ok(Json::Str("payload".into()))
            .with_header("Allow", "GET, HEAD");
        let mut buf = Vec::new();
        r.write_to_opts(&mut buf, true, true).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("connection: keep-alive\r\n"));
        assert!(text.contains("Allow: GET, HEAD\r\n"));
        assert!(text.contains("content-length: 9\r\n")); // "payload" + quotes
        assert!(text.ends_with("\r\n\r\n")); // no body after headers
    }

    #[test]
    fn tracked_read_records_path_on_partial_requests() {
        // body shorter than content-length: the read errors, but the
        // path was already captured for envelope selection
        let raw =
            b"POST /api/v2/experiment HTTP/1.1\r\ncontent-length: 99\r\n\r\n{}";
        let mut seen = None;
        let mut reader = &raw[..];
        let res = Request::read_next_tracked(&mut reader, &mut seen);
        assert!(res.is_err());
        assert_eq!(seen.as_deref(), Some("/api/v2/experiment"));
        // bad version still yields the path
        let raw = b"GET /api/v2/x SPDY/9\r\n\r\n";
        let mut seen = None;
        let mut reader = &raw[..];
        assert!(
            Request::read_next_tracked(&mut reader, &mut seen).is_err()
        );
        assert_eq!(seen.as_deref(), Some("/api/v2/x"));
    }

    #[test]
    fn stream_response_writes_chunked_frames() {
        let r = Response::stream(
            200,
            "application/x-json-stream",
            Box::new(|sink| {
                sink.chunk(b"hello\n")?;
                sink.chunk(b"world\n")
            }),
        );
        assert!(r.is_stream());
        let mut buf = Vec::new();
        r.write_to_opts(&mut buf, true, false).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("transfer-encoding: chunked\r\n"));
        // streams force connection: close even when keep-alive was asked
        assert!(text.contains("connection: close\r\n"));
        assert!(text.contains("6\r\nhello\n\r\n"));
        assert!(text.contains("6\r\nworld\n\r\n"));
        assert!(text.ends_with("0\r\n\r\n"));
    }

    #[test]
    fn url_decoding() {
        assert_eq!(url_decode("a%20b%2Fc"), "a b/c");
        assert_eq!(url_decode("100%"), "100%"); // tolerate bad escapes
    }

    #[test]
    fn synthetic_splits_query() {
        let r = Request::synthetic("GET", "/api/v2/experiment?limit=3");
        assert_eq!(r.path, "/api/v2/experiment");
        assert_eq!(r.query["limit"], "3");
    }

    #[test]
    fn chunk_framing_helper() {
        let mut out = Vec::new();
        chunk_frame_into(&mut out, b"hello\n");
        chunk_frame_into(&mut out, b""); // skipped, not a terminator
        chunk_frame_into(&mut out, b"world\n");
        assert_eq!(&out, b"6\r\nhello\n\r\n6\r\nworld\n\r\n");
    }

    /// Scripted tail source: emits a fixed sequence of steps.
    struct ScriptTail {
        steps: Vec<TailStep>,
        deadline: std::time::Instant,
    }

    impl TailSource for ScriptTail {
        fn step(&mut self, _now: std::time::Instant) -> TailStep {
            if self.steps.is_empty() {
                TailStep::End(CHUNK_TERMINAL.to_vec())
            } else {
                self.steps.remove(0)
            }
        }
        fn deadline(&self) -> std::time::Instant {
            self.deadline
        }
        fn wait(&self, max: std::time::Duration) {
            std::thread::sleep(max.min(std::time::Duration::from_millis(1)));
        }
    }

    #[test]
    fn chunked_tail_drives_to_completion_blocking() {
        let mut a = Vec::new();
        chunk_frame_into(&mut a, b"ev1\n");
        let mut b = Vec::new();
        chunk_frame_into(&mut b, b"ev2\n");
        b.extend_from_slice(CHUNK_TERMINAL);
        let r = Response::tail_stream(
            200,
            "application/x-json-stream",
            Box::new(ScriptTail {
                steps: vec![
                    TailStep::Pending,
                    TailStep::Data(a),
                    TailStep::End(b),
                ],
                deadline: std::time::Instant::now()
                    + std::time::Duration::from_secs(5),
            }),
        );
        assert!(r.is_tail() && r.closes_after());
        let mut buf = Vec::new();
        r.write_to_opts(&mut buf, true, false).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("transfer-encoding: chunked\r\n"));
        assert!(text.contains("connection: close\r\n"));
        assert!(text.contains("4\r\nev1\n\r\n"));
        assert!(text.contains("4\r\nev2\n\r\n"));
        assert!(text.ends_with("0\r\n\r\n"));
    }

    #[test]
    fn poll_tail_resolves_to_framed_response() {
        let inner = Response::ok_result(Json::Str("resolved".into()));
        let r = Response::tail_poll(Box::new(ScriptTail {
            steps: vec![
                TailStep::Pending,
                TailStep::Respond(Box::new(inner)),
            ],
            deadline: std::time::Instant::now()
                + std::time::Duration::from_secs(5),
        }));
        assert!(r.is_tail() && !r.closes_after());
        let mut buf = Vec::new();
        r.write_to_opts(&mut buf, true, false).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("connection: keep-alive\r\n"));
        assert!(text.contains("resolved"));
    }

    #[test]
    fn take_tail_is_single_shot() {
        let r = Response::tail_poll(Box::new(ScriptTail {
            steps: vec![],
            deadline: std::time::Instant::now(),
        }));
        assert!(r.take_tail().is_some());
        assert!(r.take_tail().is_none());
        // a consumed tail degrades to a 500, not a hang
        let mut buf = Vec::new();
        r.write_to_opts(&mut buf, true, false).unwrap();
        assert!(String::from_utf8(buf).unwrap().contains("500"));
    }
}
