//! HTTP/1.1 request parsing and response serialization (std-only).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without query string.
    pub path: String,
    pub query: BTreeMap<String, String>,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    /// Parse one request from a stream.
    pub fn read_from<R: Read>(stream: R) -> crate::Result<Request> {
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let mut parts = line.trim_end().split(' ');
        let method = parts
            .next()
            .filter(|m| !m.is_empty())
            .ok_or_else(|| bad("missing method"))?
            .to_string();
        let target = parts.next().ok_or_else(|| bad("missing path"))?;
        let version = parts.next().unwrap_or("");
        if !version.starts_with("HTTP/1.") {
            return Err(bad("unsupported HTTP version"));
        }
        let (path, query) = match target.split_once('?') {
            Some((p, q)) => (p.to_string(), parse_query(q)),
            None => (target.to_string(), BTreeMap::new()),
        };
        let mut headers = BTreeMap::new();
        loop {
            let mut h = String::new();
            reader.read_line(&mut h)?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                headers.insert(
                    k.trim().to_ascii_lowercase(),
                    v.trim().to_string(),
                );
            }
        }
        let len: usize = headers
            .get("content-length")
            .and_then(|v| v.parse().ok())
            .unwrap_or(0);
        if len > 64 * 1024 * 1024 {
            return Err(bad("body too large"));
        }
        let mut body = vec![0u8; len];
        if len > 0 {
            reader.read_exact(&mut body)?;
        }
        Ok(Request {
            method,
            path,
            query,
            headers,
            body,
        })
    }

    pub fn json(&self) -> crate::Result<Json> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|_| bad("body is not utf-8"))?;
        Ok(Json::parse(text)?)
    }

    pub fn bearer_token(&self) -> Option<&str> {
        self.headers
            .get("authorization")?
            .strip_prefix("Bearer ")
    }
}

fn parse_query(q: &str) -> BTreeMap<String, String> {
    q.split('&')
        .filter_map(|kv| {
            let (k, v) = kv.split_once('=')?;
            Some((url_decode(k), url_decode(v)))
        })
        .collect()
}

fn url_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' if i + 2 < bytes.len() + 1 && i + 2 < bytes.len() => {
                let hex = std::str::from_utf8(&bytes[i + 1..i + 3]).ok();
                match hex.and_then(|h| u8::from_str_radix(h, 16).ok()) {
                    Some(b) => {
                        out.push(b);
                        i += 3;
                    }
                    None => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn bad(msg: &str) -> crate::SubmarineError {
    crate::SubmarineError::InvalidSpec(format!("http: {msg}"))
}

/// An HTTP response.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.dump().into_bytes(),
        }
    }

    pub fn ok(body: Json) -> Response {
        Self::json(200, body)
    }

    /// Submarine-style envelope: `{"status":"OK","result":...}`.
    pub fn ok_result(result: Json) -> Response {
        Self::json(
            200,
            Json::obj()
                .set("status", Json::Str("OK".into()))
                .set("result", result),
        )
    }

    pub fn error(status: u16, msg: &str) -> Response {
        Self::json(
            status,
            Json::obj()
                .set("status", Json::Str("ERROR".into()))
                .set("message", Json::Str(msg.to_string())),
        )
    }

    pub fn from_err(e: &crate::SubmarineError) -> Response {
        Self::error(e.http_status(), &e.to_string())
    }

    pub fn write_to<W: Write>(&self, mut w: W) -> std::io::Result<()> {
        let reason = match self.status {
            200 => "OK",
            400 => "Bad Request",
            401 => "Unauthorized",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            503 => "Service Unavailable",
            _ => "Internal Server Error",
        };
        write!(
            w,
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
            self.status,
            reason,
            self.content_type,
            self.body.len()
        )?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_get_with_query() {
        let raw = b"GET /api/v1/experiment?limit=5&name=m+x HTTP/1.1\r\nHost: x\r\n\r\n";
        let r = Request::read_from(&raw[..]).unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/api/v1/experiment");
        assert_eq!(r.query["limit"], "5");
        assert_eq!(r.query["name"], "m x");
    }

    #[test]
    fn parses_post_with_body() {
        let body = r#"{"a":1}"#;
        let raw = format!(
            "POST /x HTTP/1.1\r\ncontent-length: {}\r\nAuthorization: Bearer tok123\r\n\r\n{}",
            body.len(),
            body
        );
        let r = Request::read_from(raw.as_bytes()).unwrap();
        assert_eq!(r.json().unwrap().num_field("a"), Some(1.0));
        assert_eq!(r.bearer_token(), Some("tok123"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Request::read_from(&b""[..]).is_err());
        assert!(Request::read_from(&b"GET /x SPDY/9\r\n\r\n"[..]).is_err());
    }

    #[test]
    fn response_serializes() {
        let r = Response::ok_result(Json::Str("hi".into()));
        let mut buf = Vec::new();
        r.write_to(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains(r#""status":"OK""#));
    }

    #[test]
    fn url_decoding() {
        assert_eq!(url_decode("a%20b%2Fc"), "a b/c");
        assert_eq!(url_decode("100%"), "100%"); // tolerate bad escapes
    }
}
