//! REST API service (paper §3.1: "these user interfaces manipulate each
//! component in the model lifecycle via REST API exposed by Submarine
//! server. The REST API service handles HTTP requests and is responsible
//! for authentication.")
//!
//! A std-only HTTP/1.1 server (the offline registry lacks hyper/tokio):
//! thread-pooled accept loop, request parser, router, bearer-token auth,
//! JSON responses.  Routes mirror Apache Submarine's v1 API
//! (`/api/v1/experiment`, `/api/v1/template`, `/api/v1/environment`,
//! `/api/v1/model`, ...).

pub mod http;
pub mod router;
pub mod server;

pub use http::{Request, Response};
pub use router::Router;
pub use server::Server;
