//! REST API service (paper §3.1: "these user interfaces manipulate each
//! component in the model lifecycle via REST API exposed by Submarine
//! server. The REST API service handles HTTP requests and is responsible
//! for authentication.")
//!
//! A std-only HTTP/1.1 server (the offline registry lacks hyper/tokio):
//! an epoll readiness reactor ([`reactor`]) drives per-connection
//! state machines ([`conn`]) with keep-alive and parks watch/stream
//! tails as cheap reactor entries; request parser, compiled
//! segment-trie router ([`trie`]), typed handlers with extractors
//! ([`handler`]), a composable middleware chain ([`middleware`]: auth,
//! logging, per-route metrics, rate limiting), and versioned JSON
//! envelopes ([`router`]).
//!
//! Routes ([`v2`]) serve Apache Submarine's surface under `/api/v2`
//! (typed envelope, pagination, filtering) with `/api/v1` kept as a
//! compat shim (`/api/v1/experiment`, `/api/v1/template`,
//! `/api/v1/environment`, `/api/v1/model`, ...). See `docs/API.md`.

pub mod conn;
pub mod cursor;
pub mod handler;
pub mod http;
pub mod middleware;
pub mod reactor;
pub mod resource;
pub mod router;
pub mod server;
pub mod trie;
pub mod v2;

pub use handler::{typed, Body, Ctx, Handler, Page};
pub use http::{Request, Response};
pub use middleware::Middleware;
pub use resource::{Caps, FilterSpec, ResourceKind};
pub use router::{Envelope, RawHandler, Router};
pub use server::{Server, ServerOptions};
pub use v2::ApiConfig;
