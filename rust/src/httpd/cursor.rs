//! Opaque revision-anchored list cursors (ISSUE 10).
//!
//! Offset paging re-walks everything before the requested window, so
//! draining a namespace is quadratic and a page's contents shift
//! whenever a concurrent write lands before the offset. A cursor
//! instead remembers the **last key** a page delivered; the
//! continuation seeks `BTreeMap::range(Excluded(last_key)..)` in
//! O(log n) and is stable under interleaved writes and deletes — a key
//! inserted before the cursor is simply outside the remaining window,
//! one deleted at the cursor still seeks to its successor.
//!
//! The token also pins:
//!
//! - the **anchor revision** — the store's global revision when page 1
//!   was served. It rides along unchanged so clients (and the relist
//!   protocol) know which bookmark the walk started from; a token whose
//!   anchor is *ahead* of the serving store came from another timeline
//!   (a restarted server) and answers `410 Gone`.
//! - a **query fingerprint** — FNV-1a over the namespace, scope, index
//!   filters, and selector the cursor was minted for. Continuing a walk
//!   with different query parameters would silently skip or duplicate
//!   rows; a fingerprint mismatch answers `410 Gone`, and the client
//!   recovers with the watch protocol's existing relist rule: re-issue
//!   the list without a cursor.
//!
//! Tokens are opaque to clients: `c1.<rev>.<fingerprint>.<hex(key)>`,
//! all hex. The key is hex-encoded so arbitrary key bytes can never
//! collide with the separator. Malformed tokens are a client error
//! (`400`), not `410` — only a *well-formed* token can be stale.

use crate::SubmarineError;

/// Decoded continuation state of one list walk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Cursor {
    /// Global store revision when the walk started (page 1's bookmark).
    pub rev: u64,
    /// Fingerprint of the query shape the token was minted for.
    pub fingerprint: u64,
    /// Last key the previous page delivered; the next page starts
    /// strictly after it.
    pub last_key: String,
}

const PREFIX: &str = "c1";

impl Cursor {
    /// Serialize to the opaque wire token.
    pub fn encode(&self) -> String {
        let mut out = String::with_capacity(
            PREFIX.len() + 2 * self.last_key.len() + 36,
        );
        out.push_str(PREFIX);
        out.push('.');
        push_hex_u64(&mut out, self.rev);
        out.push('.');
        push_hex_u64(&mut out, self.fingerprint);
        out.push('.');
        for b in self.last_key.as_bytes() {
            push_hex_byte(&mut out, *b);
        }
        out
    }

    /// Parse a wire token. Any structural defect is `InvalidSpec`
    /// (400): a malformed token was never minted by this server, so
    /// answering `410` would send clients into relist loops for what
    /// is a caller bug.
    pub fn decode(raw: &str) -> crate::Result<Cursor> {
        let bad = || {
            SubmarineError::InvalidSpec(format!(
                "malformed cursor token {raw:?}"
            ))
        };
        let mut parts = raw.split('.');
        if parts.next() != Some(PREFIX) {
            return Err(bad());
        }
        let rev = parts.next().and_then(parse_hex_u64).ok_or_else(bad)?;
        let fingerprint =
            parts.next().and_then(parse_hex_u64).ok_or_else(bad)?;
        let key_hex = parts.next().ok_or_else(bad)?;
        if parts.next().is_some() || key_hex.is_empty() {
            return Err(bad());
        }
        let bytes = parse_hex_bytes(key_hex).ok_or_else(bad)?;
        let last_key = String::from_utf8(bytes).map_err(|_| bad())?;
        Ok(Cursor {
            rev,
            fingerprint,
            last_key,
        })
    }
}

/// FNV-1a over the ordered query-shape parts (same constants as the
/// store's shard hash). Order matters and each part is terminated, so
/// `["ab","c"]` and `["a","bc"]` fingerprint differently.
pub fn fingerprint<S: AsRef<str>>(parts: &[S]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for p in parts {
        for b in p.as_ref().as_bytes() {
            h ^= u64::from(*b);
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        h ^= 0xff;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

fn push_hex_byte(out: &mut String, b: u8) {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    out.push(HEX[(b >> 4) as usize] as char);
    out.push(HEX[(b & 0xf) as usize] as char);
}

fn push_hex_u64(out: &mut String, mut v: u64) {
    if v == 0 {
        out.push('0');
        return;
    }
    let mut buf = [0u8; 16];
    let mut i = buf.len();
    const HEX: &[u8; 16] = b"0123456789abcdef";
    while v > 0 {
        i -= 1;
        buf[i] = HEX[(v & 0xf) as usize];
        v >>= 4;
    }
    for b in &buf[i..] {
        out.push(*b as char);
    }
}

fn parse_hex_u64(s: &str) -> Option<u64> {
    if s.is_empty() || s.len() > 16 {
        return None;
    }
    let mut v: u64 = 0;
    for c in s.bytes() {
        v = (v << 4) | u64::from(hex_val(c)?);
    }
    Some(v)
}

fn parse_hex_bytes(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len() / 2);
    for pair in b.chunks_exact(2) {
        out.push((hex_val(pair[0])? << 4) | hex_val(pair[1])?);
    }
    Some(out)
}

fn hex_val(c: u8) -> Option<u8> {
    match c {
        b'0'..=b'9' => Some(c - b'0'),
        b'a'..=b'f' => Some(c - b'a' + 10),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_arbitrary_keys() {
        for key in [
            "e1",
            "model@mnist/3",
            "k.with.dots",
            "spaces and ünïcode ✓",
            ".",
        ] {
            let c = Cursor {
                rev: 123_456,
                fingerprint: u64::MAX,
                last_key: key.to_string(),
            };
            let token = c.encode();
            assert_eq!(Cursor::decode(&token).unwrap(), c);
            // tokens are URL-safe as-is: hex + dots only
            assert!(token
                .bytes()
                .all(|b| b.is_ascii_hexdigit() || b == b'.'));
        }
    }

    #[test]
    fn malformed_tokens_are_invalid_spec_not_gone() {
        for raw in [
            "",
            "c1",
            "c1.10.20",          // missing key
            "c1.10.20.",         // empty key
            "c1.10.20.abc",      // odd-length hex
            "c1.10.20.zz",       // not hex
            "c2.10.20.6162",     // unknown version
            "c1.xx.20.6162",     // bad rev
            "c1.10.20.6162.99",  // trailing part
            "c1.10000000000000000.20.6162", // rev overflows u64
        ] {
            let err = Cursor::decode(raw).unwrap_err();
            assert_eq!(err.http_status(), 400, "{raw:?}");
        }
    }

    #[test]
    fn fingerprint_is_order_and_boundary_sensitive() {
        assert_ne!(
            fingerprint(&["a", "b"]),
            fingerprint(&["b", "a"])
        );
        assert_ne!(
            fingerprint(&["ab", "c"]),
            fingerprint(&["a", "bc"])
        );
        assert_eq!(
            fingerprint(&["ns", "scope=x"]),
            fingerprint(&["ns", "scope=x"])
        );
    }
}
