//! The generic declarative resource engine (ISSUE 4 tentpole).
//!
//! The v2 API used to be four hand-rolled copies of the same CRUD
//! shape. Here a kind describes itself once via [`ResourceKind`]
//! (validate, render, lifecycle hooks, which indexed filters it
//! exposes) and [`register_kind`] serves the whole declarative surface
//! for it:
//!
//! - `GET /api/v2/{kind}` — list with pagination (offset, or opaque
//!   revision-anchored `?cursor=` tokens that seek the tree in
//!   O(log n + limit) per page and answer `410 Gone` + relist when the
//!   anchor goes stale), indexed filters (`?status=`, `?stage=`), label
//!   selectors (`?label=k=v,k2=v2` walking the `meta.labels` index),
//!   and a `resource_version` bookmark for starting watches;
//! - `GET /api/v2/{kind}?stream=1` — one-request full-namespace drain:
//!   a chunked stream splicing cached document encodings in bounded
//!   chunks, re-acquiring the shard lock between chunks;
//! - `GET /api/v2/{kind}?watch=1&since=REV` — long-poll (default) or
//!   chunked-stream (`&stream=1`) change feed, `410 Gone` + relist
//!   guidance when `since` has been compacted out of the feed;
//! - `POST` — create (`409` when the name exists);
//! - `GET /{name}` — read with an `ETag` carrying
//!   `meta.resource_version`;
//! - `PUT`/`PATCH /{name}` — replace / RFC 7386 merge-patch, honoring
//!   `If-Match` with `412` on stale revisions (checked atomically under
//!   the storage shard lock: of two racing conditional writers exactly
//!   one wins);
//! - `DELETE /{name}` — conditional delete with kind teardown hooks.
//!
//! Scoped kinds (model versions live under `/model/:name`) plug in via
//! [`ResourceKind::scope_index`].

use super::cursor::{fingerprint, Cursor};
use super::handler::{typed, Ctx, Extract, Page, MAX_LIST_LIMIT};
use super::http::{
    chunk_frame_into, Request, Response, TailSource, TailStep,
    CHUNK_TERMINAL,
};
use super::router::{
    v2_ok_head, v2_ok_raw, wrap_err, wrap_ok, Envelope, Router,
};
use super::server::Services;
use crate::resource::{
    labels_of, merge_patch, resource_version, sanitize_labels,
    stamp_update, strip_meta, strip_volatile, Selector,
};
use crate::storage::{Change, Doc, MetaStore, UpdateRev};
use crate::util::json::{write_json_string, write_json_u64, Json};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default long-poll window for `?watch=1`.
const DEFAULT_WATCH_MS: u64 = 30_000;
/// Hard cap on a single watch request's window.
const MAX_WATCH_MS: u64 = 300_000;
/// Max feed records pulled per wait round.
const WATCH_BATCH: usize = 256;
/// Max documents one streamed-list chunk visits under the shard lock.
const LIST_CHUNK_DOCS: usize = 512;
/// Soft byte budget of one streamed-list chunk; the chunk closes at
/// the first document that crosses it, so the reactor's write buffer
/// holds at most one chunk (plus that document) at a time.
const LIST_CHUNK_BYTES: usize = 64 * 1024;

/// One indexed query filter a kind exposes on its list endpoint.
#[derive(Debug)]
pub struct FilterSpec {
    /// Query parameter name (`status`, `stage`).
    pub query: &'static str,
    /// Secondary-index field backing it.
    pub index_field: &'static str,
}

/// Which of the generic verbs a kind supports.
#[derive(Debug, Clone, Copy)]
pub struct Caps {
    pub create: bool,
    pub update: bool,
    pub delete: bool,
}

/// A resource kind served generically under `/api/v2`. Implementations
/// are ~30-60 lines of validation/rendering/hooks; the HTTP scaffolding
/// (meta stamping, conditional writes, selectors, watches, pagination)
/// lives here once.
pub trait ResourceKind: Send + Sync {
    /// URL segment under `/api/v2` — also the storage namespace.
    fn kind(&self) -> &'static str;

    /// Storage namespace (defaults to [`Self::kind`]).
    fn ns(&self) -> &'static str {
        self.kind()
    }

    /// Scoped collections: `Some(index_field)` puts the collection at
    /// `/api/v2/{kind}/:name` with rows constrained to the scope via
    /// that secondary index (model versions under their model name).
    fn scope_index(&self) -> Option<&'static str> {
        None
    }

    /// Storage-key prefix of a scope's rows (watch filtering).
    fn scope_prefix(&self, scope: &str) -> String {
        format!("{scope}@")
    }

    /// Addressable resource name for a storage key — what `meta.name`
    /// and watch events carry: scoped kinds map the internal key back
    /// to the coordinates the item endpoint accepts (model
    /// `ctr@000003` -> `ctr/3`); unscoped kinds use the key as-is.
    fn display_name(&self, key: &str) -> String {
        key.to_string()
    }

    /// 404 the whole collection when the scope has no rows.
    fn missing_scope_is_404(&self) -> bool {
        false
    }

    fn caps(&self) -> Caps;

    /// Indexed query filters the list endpoint accepts.
    fn filters(&self) -> &'static [FilterSpec] {
        &[]
    }

    /// Storage key of the item addressed by this request.
    fn item_key(&self, ctx: &Ctx<'_>) -> crate::Result<String> {
        Ok(ctx.param("name")?.to_string())
    }

    /// POST: validate the body and perform the create (through the
    /// kind's manager, which stamps `meta`); returns the response
    /// payload.
    fn create(&self, s: &Services, body: &Json) -> crate::Result<Json> {
        let _ = (s, body);
        Err(crate::SubmarineError::InvalidSpec(format!(
            "{} resources cannot be created via the API",
            self.kind()
        )))
    }

    /// List-item rendering.
    fn render_row(&self, s: &Services, key: &str, doc: &Json) -> Json;

    /// Single-document rendering (live status overlays etc.).
    fn render_doc(&self, s: &Services, key: &str, doc: Json) -> Json {
        let _ = (s, key);
        doc
    }

    /// Whether [`Self::render_doc`] is the identity (the default). If
    /// so, item GETs are served straight from the stored document's
    /// revision-keyed encoded-body cache — no render, no serialize —
    /// and HEADs answer `Content-Length` without materializing a body.
    /// **Must** be overridden to `false` by any kind that also
    /// overrides `render_doc`, or GETs will bypass the overlay.
    fn serves_cached_doc(&self) -> bool {
        true
    }

    /// PUT/PATCH: build the full replacement document from the old doc
    /// and the desired client state. `meta` handling is the engine's
    /// job — implementations only deal with kind fields. Runs outside
    /// the storage locks against a snapshot (expensive validation like
    /// the environment dependency solver is fine); the engine commits
    /// only if the document is still exactly that snapshot, retrying
    /// otherwise.
    fn apply_update(
        &self,
        s: &Services,
        key: &str,
        old: &Json,
        desired: &Json,
    ) -> crate::Result<Json>;

    /// Post-commit hook for updates (e.g. demote the previous
    /// Production model version).
    fn post_update(
        &self,
        s: &Services,
        key: &str,
        doc: &Json,
    ) -> crate::Result<()> {
        let _ = (s, key, doc);
        Ok(())
    }

    /// Teardown before the document is removed (kill containers, ...).
    fn pre_delete(
        &self,
        s: &Services,
        key: &str,
        doc: &Json,
    ) -> crate::Result<()> {
        let _ = (s, key, doc);
        Ok(())
    }

    /// Whether [`Self::pre_delete`] has side effects that themselves
    /// bump the document's revision (killing an experiment persists a
    /// status). Teardown-free kinds get a fully atomic
    /// `If-Match`-checked delete; teardown kinds are checked against
    /// the version the client saw before teardown ran.
    fn delete_has_teardown(&self) -> bool {
        false
    }
}

fn invalid(msg: String) -> crate::SubmarineError {
    crate::SubmarineError::InvalidSpec(msg)
}

fn not_found(kind: &dyn ResourceKind, key: &str) -> crate::SubmarineError {
    crate::SubmarineError::NotFound(format!("{} {key}", kind.kind()))
}

fn etag_of(doc: &Json) -> String {
    format!("\"{}\"", resource_version(doc))
}

/// Parsed `If-Match` header.
enum Precondition {
    /// `If-Match: *` — any existing version.
    Any,
    /// `If-Match: "REV"` — exactly this resource_version.
    Rev(u64),
}

fn parse_if_match(req: &Request) -> crate::Result<Option<Precondition>> {
    let Some(raw) = req.headers.get("if-match") else {
        return Ok(None);
    };
    let t = raw.trim();
    if t == "*" {
        return Ok(Some(Precondition::Any));
    }
    let t = t.strip_prefix("W/").unwrap_or(t);
    let t = t.trim_matches('"');
    let rev: u64 = t.parse().map_err(|_| {
        invalid(format!(
            "If-Match must be a resource_version ETag or *, got {raw:?}"
        ))
    })?;
    Ok(Some(Precondition::Rev(rev)))
}

fn check_precondition(
    p: Option<&Precondition>,
    doc: &Json,
) -> crate::Result<()> {
    if let Some(Precondition::Rev(want)) = p {
        let have = resource_version(doc);
        if *want != have {
            return Err(crate::SubmarineError::PreconditionFailed(
                format!(
                    "resource_version mismatch: If-Match {want}, \
                     current {have}"
                ),
            ));
        }
    }
    Ok(())
}

/// Register the full generic surface for one kind.
pub fn register_kind(
    r: &mut Router,
    s: &Arc<Services>,
    kind: &Arc<dyn ResourceKind>,
) {
    let coll = match kind.scope_index() {
        None => format!("/api/v2/{}", kind.kind()),
        Some(_) => format!("/api/v2/{}/:name", kind.kind()),
    };
    let item = match kind.scope_index() {
        None => format!("{coll}/:name"),
        Some(_) => format!("{coll}/:version"),
    };
    let caps = kind.caps();

    {
        // list | watch (raw: watch escapes the enveloped-Json contract)
        let s = Arc::clone(s);
        let k = Arc::clone(kind);
        r.route_raw(
            "GET",
            &coll,
            Arc::new(move |ctx: &Ctx<'_>| -> Response {
                let watching = matches!(
                    ctx.query("watch"),
                    Some("1") | Some("true")
                );
                let streaming = matches!(
                    ctx.query("stream"),
                    Some("1") | Some("true")
                );
                if watching {
                    watch_response(&s, &k, ctx)
                } else if streaming {
                    // `?stream=1` without `watch`: chunked full drain
                    stream_list_response(&s, &k, ctx)
                } else {
                    match list(&s, &k, ctx) {
                        Ok(j) => wrap_ok(Envelope::V2, j),
                        Err(e) => wrap_err(Envelope::V2, &e),
                    }
                }
            }),
        );
    }
    if caps.create {
        let s = Arc::clone(s);
        let k = Arc::clone(kind);
        r.route(
            "POST",
            &coll,
            Envelope::V2,
            typed(move |_: &Ctx<'_>, body: Json| k.create(&s, &body)),
        );
    }
    {
        // Item GET is a raw route: the hot path answers straight from
        // the document's cached encoded body (one splice into the v2
        // envelope), which the enveloped-Json contract can't express.
        let s = Arc::clone(s);
        let k = Arc::clone(kind);
        r.route_raw(
            "GET",
            &item,
            Arc::new(move |ctx: &Ctx<'_>| get_item(&s, &k, ctx)),
        );
    }
    if caps.update {
        for (method, is_patch) in [("PUT", false), ("PATCH", true)] {
            let s = Arc::clone(s);
            let k = Arc::clone(kind);
            r.route(
                method,
                &item,
                Envelope::V2,
                typed(move |ctx: &Ctx<'_>, body: Json| {
                    write_resource(&s, &k, ctx, &body, is_patch)
                }),
            );
        }
    }
    if caps.delete {
        let s = Arc::clone(s);
        let k = Arc::clone(kind);
        r.route(
            "DELETE",
            &item,
            Envelope::V2,
            typed(move |ctx: &Ctx<'_>, _: ()| {
                delete_resource(&s, &k, ctx)
            }),
        );
    }
}

/// Item GET/HEAD. Kinds with identity rendering are served from the
/// revision-keyed body cache: first GET of a revision serializes once,
/// every repeat GET (and every HEAD) after that splices the shared
/// bytes — zero parse, zero render, zero serialize. Kinds with a
/// render overlay (experiment live status) keep the rendered path.
fn get_item(
    s: &Services,
    kind: &Arc<dyn ResourceKind>,
    ctx: &Ctx<'_>,
) -> Response {
    let key = match kind.item_key(ctx) {
        Ok(key) => key,
        Err(e) => return wrap_err(Envelope::V2, &e),
    };
    let Some(doc) = s.store.get(kind.ns(), &key) else {
        return wrap_err(Envelope::V2, &not_found(&**kind, &key));
    };
    let etag = etag_of(&doc);
    let resp = if kind.serves_cached_doc() {
        let body = doc.encoded();
        if ctx.req.method.eq_ignore_ascii_case("HEAD") {
            v2_ok_head(body.len())
        } else {
            v2_ok_raw(&body)
        }
    } else {
        let rendered = kind.render_doc(s, &key, doc.json().clone()); // lint: allow(hot)
        wrap_ok(Envelope::V2, rendered)
    };
    resp.with_header("ETag", &etag)
}

fn intersect(a: Vec<String>, b: Vec<String>) -> Vec<String> {
    let set: std::collections::BTreeSet<&str> =
        b.iter().map(String::as_str).collect();
    a.into_iter().filter(|k| set.contains(k.as_str())).collect()
}

/// Generic list: candidate keys come from the scope / filter / selector
/// indexes (intersected, all key-ordered); only the requested window
/// of documents is ever materialized.
///
/// Two continuation modes share this path:
///
/// - **offset** (`?offset=N&limit=M`, the pre-ISSUE-10 shape): page N
///   re-walks everything before it — kept for compatibility.
/// - **cursor** (`?cursor=<token>`): the token pins the last key the
///   previous page delivered plus a fingerprint of the query shape;
///   the continuation *seeks* (`BTreeMap::range`) so every page costs
///   O(log n + limit) no matter how deep the walk is, and delivered
///   keys are never revisited or skipped under concurrent writes. A
///   full page carries `next_cursor` in its envelope; its absence
///   means the walk is complete. A token minted for a different query
///   shape, or by a different server timeline, answers `410 Gone` —
///   recover by relisting without the cursor (same rule as watch).
fn list(
    s: &Services,
    kind: &Arc<dyn ResourceKind>,
    ctx: &Ctx<'_>,
) -> crate::Result<Json> {
    let page = Page::extract(ctx)?;
    let selector = match ctx.query("label") {
        Some(raw) => Selector::parse(raw)?,
        None => Selector::default(),
    };
    let ns = kind.ns();
    let filters = kind.filters();
    if page.status.is_some()
        && !filters.iter().any(|f| f.query == "status")
    {
        return Err(invalid(format!(
            "{}s have no status; remove the status query param",
            kind.kind()
        )));
    }
    let mut active: Vec<(&FilterSpec, String)> = Vec::new();
    for f in filters {
        let v = if f.query == "status" {
            page.status.clone()
        } else {
            ctx.query(f.query).map(str::to_string)
        };
        if let Some(v) = v {
            active.push((f, v));
        }
    }
    // Bookmark BEFORE reading state: a write racing this list shows up
    // again in a watch started from the bookmark (at-least-once), it
    // can never fall silently between list and watch.
    let bookmark = s.store.current_rev();

    // The query shape this request describes — continuing someone
    // else's walk with different parameters would silently skip or
    // duplicate rows, so the cursor token is fingerprint-checked.
    let scope: Option<&str> = match kind.scope_index() {
        Some(_) => Some(ctx.param("name")?),
        None => None,
    };
    let mut fp_parts: Vec<String> = Vec::with_capacity(4);
    fp_parts.push(ns.to_string());
    if let Some(sc) = scope {
        fp_parts.push(format!("scope={sc}"));
    }
    for (f, v) in &active {
        fp_parts.push(format!("{}={v}", f.query));
    }
    if !selector.is_empty() {
        fp_parts.push(format!("label={}", selector.tokens().join(",")));
    }
    let fp = fingerprint(&fp_parts);
    let cursor = match ctx.query("cursor") {
        None => None,
        Some(raw) => {
            let c = Cursor::decode(raw)?;
            if c.fingerprint != fp {
                return Err(crate::SubmarineError::Gone(
                    "cursor was minted for a different query shape; \
                     relist without it"
                        .into(),
                ));
            }
            if c.rev > bookmark {
                return Err(crate::SubmarineError::Gone(
                    "cursor anchor revision is ahead of this server \
                     (restarted?); relist without it"
                        .into(),
                ));
            }
            if page.offset != 0 {
                return Err(invalid(
                    "cursor and offset are mutually exclusive".into(),
                ));
            }
            Some(c)
        }
    };
    let after: Option<&str> =
        cursor.as_ref().map(|c| c.last_key.as_str());
    // every cursor page is bounded even when the client names no limit
    let eff_limit = page.limit.unwrap_or(MAX_LIST_LIMIT);

    // How many index constraints narrow the candidate set. Exactly one
    // (and no multi-pair selector verification) walks the posting list
    // directly; several intersect materialized key lists as before.
    let n_constraints = usize::from(scope.is_some())
        + active.len()
        + usize::from(!selector.is_empty());
    let single = (n_constraints, selector.pairs.len());

    let (rows, total): (Vec<(String, Arc<Doc>)>, usize) = if n_constraints
        == 0
    {
        // unfiltered: page the primary map inside the store
        match cursor {
            Some(_) => s.store.page_after(ns, after, eff_limit),
            None => s.store.page(ns, page.offset, page.limit),
        }
    } else if matches!(single, (1, 0) | (1, 1)) {
        // one constraint: the posting set pages/seeks itself
        let sel_tokens = selector.tokens();
        let (field, value): (&str, &str) =
            if let Some(scope_field) = kind.scope_index() {
                (scope_field, scope.unwrap_or_default())
            } else if let Some((f, v)) = active.first() {
                (f.index_field, v.as_str())
            } else {
                ("meta.labels", &sel_tokens[0])
            };
        let (win, total) = match cursor {
            Some(_) => s
                .store
                .index_page_after(ns, field, value, after, eff_limit)?,
            None => s.store.index_page(
                ns,
                field,
                value,
                page.offset,
                page.limit,
            )?,
        };
        if total == 0 && scope.is_some() && kind.missing_scope_is_404()
        {
            return Err(crate::SubmarineError::NotFound(format!(
                "{} {}",
                kind.kind(),
                scope.unwrap_or_default()
            )));
        }
        (win, total)
    } else {
        // several constraints: intersect key-ordered index lookups
        let mut candidates: Option<Vec<String>> = None;
        if let Some(scope_field) = kind.scope_index() {
            let sc = scope.unwrap_or_default();
            let keys = s.store.index_lookup(ns, scope_field, sc)?;
            if keys.is_empty() && kind.missing_scope_is_404() {
                return Err(crate::SubmarineError::NotFound(format!(
                    "{} {sc}",
                    kind.kind()
                )));
            }
            candidates = Some(keys);
        }
        for (f, v) in &active {
            let keys = s.store.index_lookup(ns, f.index_field, v)?;
            candidates = Some(match candidates {
                None => keys,
                Some(prev) => intersect(prev, keys),
            });
        }
        if !selector.is_empty() {
            // first pair narrows via the meta.labels index; remaining
            // pairs are verified on the candidate docs below
            let tokens = selector.tokens();
            let keys =
                s.store.index_lookup(ns, "meta.labels", &tokens[0])?;
            candidates = Some(match candidates {
                None => keys,
                Some(prev) => intersect(prev, keys),
            });
        }
        let keys = candidates.unwrap_or_default();
        if selector.pairs.len() > 1 {
            let mut matched: Vec<(String, Arc<Doc>)> = Vec::new();
            for k in keys {
                if let Some(d) = s.store.get(ns, &k) {
                    if selector.matches(&d) {
                        matched.push((k, d));
                    }
                }
            }
            let total = matched.len();
            match after {
                // `matched` is key-ordered, so the continuation is a
                // binary-search seek over the verified rows
                Some(a) => {
                    let start = matched
                        .partition_point(|(k, _)| k.as_str() <= a);
                    let end = (start + eff_limit).min(matched.len());
                    (matched[start..end].to_vec(), total)
                }
                None => page.window(matched.into_iter(), total),
            }
        } else {
            // page the key list; fetch only the window's docs
            let total = keys.len();
            let win: Vec<String> = match after {
                Some(a) => {
                    let start =
                        keys.partition_point(|k| k.as_str() <= a);
                    let end = (start + eff_limit).min(keys.len());
                    keys[start..end].to_vec()
                }
                None => page.window(keys.into_iter(), total).0,
            };
            (
                win.into_iter()
                    .filter_map(|k| {
                        s.store.get(ns, &k).map(|d| (k, d))
                    })
                    .collect(),
                total,
            )
        }
    };
    let items: Vec<Json> = rows
        .iter()
        .map(|(k, d)| kind.render_row(s, k, d))
        .collect();
    let mut out = page
        .envelope(items, total)
        .set("resource_version", Json::Num(bookmark as f64));
    // a full page gets a continuation token; its absence means done.
    // The anchor revision of page 1 rides through every continuation.
    let page_size = match &cursor {
        Some(_) => Some(eff_limit),
        None => page.limit,
    };
    if let (Some(psize), Some((last_key, _))) =
        (page_size, rows.last())
    {
        if rows.len() == psize {
            let token = Cursor {
                rev: cursor.as_ref().map(|c| c.rev).unwrap_or(bookmark),
                fingerprint: fp,
                last_key: last_key.clone(),
            }
            .encode();
            out = out.set("next_cursor", Json::Str(token));
        }
    }
    Ok(out)
}

/// How often a write retries validation when concurrent writers keep
/// changing the document underneath it (single-doc contention is rare;
/// this bound exists so the loop provably terminates).
const WRITE_RETRIES: usize = 16;

fn write_resource(
    s: &Services,
    kind: &Arc<dyn ResourceKind>,
    ctx: &Ctx<'_>,
    body: &Json,
    is_patch: bool,
) -> crate::Result<Json> {
    let key = kind.item_key(ctx)?;
    let expected = parse_if_match(ctx.req)?;
    let ns = kind.ns();
    for _ in 0..WRITE_RETRIES {
        // All potentially expensive work — merge, kind validation
        // (environment updates run the dependency solver), label
        // sanitizing — happens here against a snapshot, OUTSIDE the
        // storage locks, so one slow PUT cannot stall other writers
        // or the change feed.
        let shared = s
            .store
            .get(ns, &key)
            .ok_or_else(|| not_found(&**kind, &key))?;
        let snapshot = shared.json();
        check_precondition(expected.as_ref(), snapshot)?;
        let desired = if is_patch {
            merge_patch(snapshot, body)
        } else {
            body.clone()
        };
        let new_doc = kind.apply_update(s, &key, snapshot, &desired)?;
        // labels: client-specified (meta.labels or top-level labels)
        // or carried over from the stored doc
        let new_labels = match desired
            .at(&["meta", "labels"])
            .or_else(|| desired.get("labels"))
        {
            Some(l) => sanitize_labels(l)?,
            None => labels_of(snapshot),
        };
        let old_meta =
            snapshot.get("meta").cloned().unwrap_or_else(Json::obj);
        let new_doc =
            new_doc.set("meta", old_meta.set("labels", new_labels));
        // no-op writes don't bump resource_version or spam the feed
        let noop = strip_meta(&new_doc) == strip_meta(snapshot)
            && labels_of(&new_doc) == labels_of(snapshot);

        // Commit under the shard lock: the doc must still be exactly
        // the snapshot we validated (this subsumes the If-Match check
        // — of racing conditional writers exactly one wins); if a
        // concurrent writer moved it, loop and revalidate.
        let mut stale = false;
        let mut written: Option<Json> = None;
        let outcome = s.store.update_rev(ns, &key, |old, rev| {
            if old != snapshot {
                stale = true;
                return Ok(None);
            }
            if noop {
                return Ok(None);
            }
            let bump =
                strip_volatile(&new_doc) != strip_volatile(snapshot);
            let stamped = stamp_update(
                new_doc.clone(),
                &kind.display_name(&key),
                rev,
                bump,
            );
            written = Some(stamped.clone());
            Ok(Some(stamped))
        })?;
        if stale {
            continue;
        }
        return match outcome {
            UpdateRev::Missing => Err(not_found(&**kind, &key)),
            UpdateRev::Unchanged => {
                // run the post-commit hook even for no-op writes: a
                // prior attempt may have committed and then failed in
                // the hook (e.g. Production demotion) — the retry
                // must finish the job instead of being swallowed by
                // no-op detection
                kind.post_update(s, &key, snapshot)?;
                ctx.set_resp_header("ETag", &etag_of(snapshot));
                Ok(kind.render_doc(s, &key, snapshot.clone()))
            }
            UpdateRev::Written(rev) => {
                let doc = written.ok_or_else(|| {
                    crate::SubmarineError::Runtime(
                        "update committed but no written doc was \
                         recorded"
                            .to_string(),
                    )
                })?;
                kind.post_update(s, &key, &doc)?;
                ctx.set_resp_header("ETag", &format!("\"{rev}\""));
                Ok(kind.render_doc(s, &key, doc))
            }
        };
    }
    Err(crate::SubmarineError::ResourcesUnavailable(format!(
        "{} {key}: concurrent writers kept invalidating the update; \
         retry",
        kind.kind()
    )))
}

fn delete_resource(
    s: &Services,
    kind: &Arc<dyn ResourceKind>,
    ctx: &Ctx<'_>,
) -> crate::Result<Json> {
    let key = kind.item_key(ctx)?;
    let expected = parse_if_match(ctx.req)?;
    let ns = kind.ns();
    if !kind.delete_has_teardown() {
        // no side effects: check the precondition under the same
        // shard lock as the removal — a racing PUT can never slip in
        // between check and delete
        let removed = s.store.delete_if(ns, &key, |old| {
            check_precondition(expected.as_ref(), old)
        })?;
        if !removed {
            return Err(not_found(&**kind, &key));
        }
        return Ok(Json::Bool(true));
    }
    let doc = s
        .store
        .get(ns, &key)
        .ok_or_else(|| not_found(&**kind, &key))?;
    // Teardown kinds: the If-Match revision is judged against the
    // version the client saw — the teardown itself (killing a live
    // experiment persists a terminal status) bumps the revision, and
    // that self-inflicted bump must not fail the delete.
    check_precondition(expected.as_ref(), &doc)?;
    kind.pre_delete(s, &key, &doc)?;
    let removed = s.store.delete_if(ns, &key, |now| {
        // A conditional client still gets atomicity for everything
        // the teardown does not touch: if a concurrent writer changed
        // the spec or labels during the teardown window, their
        // committed update must not be silently destroyed. Only
        // status churn (the kill's own side effect) is tolerated.
        if expected.is_some()
            && (strip_volatile(now) != strip_volatile(&doc)
                || labels_of(now) != labels_of(&doc))
        {
            return Err(crate::SubmarineError::PreconditionFailed(
                "resource changed while delete teardown was running; \
                 re-read and retry"
                    .into(),
            ));
        }
        Ok(())
    })?;
    if !removed {
        return Err(not_found(&**kind, &key));
    }
    Ok(Json::Bool(true))
}

// ------------------------------------------------------------------ watch

struct WatchParams {
    since: Option<u64>,
    timeout: Duration,
    stream: bool,
}

fn watch_params(ctx: &Ctx<'_>) -> crate::Result<WatchParams> {
    let since = match ctx.query("since") {
        None => None,
        Some(v) => Some(v.parse().map_err(|_| {
            invalid("since must be a non-negative integer".into())
        })?),
    };
    let timeout_ms = match ctx.query("timeout_ms") {
        None => DEFAULT_WATCH_MS,
        Some(v) => v
            .parse::<u64>()
            .map_err(|_| {
                invalid("timeout_ms must be a positive integer".into())
            })?
            .clamp(1, MAX_WATCH_MS),
    };
    Ok(WatchParams {
        since,
        timeout: Duration::from_millis(timeout_ms),
        stream: matches!(ctx.query("stream"), Some("1") | Some("true")),
    })
}

/// One change-feed record in its wire shape (long-poll batches embed
/// it in the response envelope as parsed JSON).
fn change_json(kind: &dyn ResourceKind, c: &Change) -> Json {
    let ty = if c.doc.is_some() { "PUT" } else { "DELETE" };
    let mut j = Json::obj()
        .set("type", Json::Str(ty.to_string()))
        .set("kind", Json::Str(kind.kind().to_string()))
        .set("name", Json::Str(kind.display_name(&c.key)))
        .set("resource_version", Json::Num(c.rev as f64));
    if let Some(d) = &c.doc {
        j = j.set("object", d.json().clone());
    }
    j
}

/// One change-feed record as a ready-to-send stream line: the event
/// shell is written field-by-field and the object payload is spliced
/// in from the document's cached serialization — watch fan-out to N
/// streams serializes each revision at most once, globally. Byte-equal
/// to `change_json(..).dump()` plus the trailing newline.
fn change_line(kind: &dyn ResourceKind, c: &Change) -> Vec<u8> {
    let enc = c.doc.as_ref().map(|d| d.encoded());
    let name = kind.display_name(&c.key);
    let mut line = Vec::with_capacity(
        96 + name.len() + enc.as_ref().map_or(0, |e| e.len()),
    );
    line.extend_from_slice(b"{\"type\":");
    line.extend_from_slice(if c.doc.is_some() {
        b"\"PUT\""
    } else {
        b"\"DELETE\""
    });
    line.extend_from_slice(b",\"kind\":");
    write_json_string(&mut line, kind.kind());
    line.extend_from_slice(b",\"name\":");
    write_json_string(&mut line, &name);
    line.extend_from_slice(b",\"resource_version\":");
    write_json_u64(&mut line, c.rev);
    if let Some(e) = &enc {
        line.extend_from_slice(b",\"object\":");
        line.extend_from_slice(e);
    }
    line.extend_from_slice(b"}\n");
    line
}

/// A watch parked in the reactor: both the long-poll and the chunked
/// stream flavor are [`TailSource`]s stepped on feed publishes (never
/// blocking the reactor), so 10k open watches cost 10k reactor slots,
/// not 10k threads. On a dedicated (tune) connection the blocking
/// driver in `Response::write_to_opts` steps the same source.
struct WatchTail {
    store: Arc<MetaStore>,
    ns: &'static str,
    prefix: Option<String>,
    kind: Arc<dyn ResourceKind>,
    cursor: u64,
    deadline: Instant,
    /// Chunked stream (`&stream=1`) vs. long-poll.
    stream: bool,
    /// Long-poll events accumulated across steps.
    events: Vec<Json>,
}

impl WatchTail {
    fn matches(&self, key: &str) -> bool {
        match &self.prefix {
            Some(p) => key.starts_with(p.as_str()),
            None => true,
        }
    }

    /// Stream mode: one framed JSON line per event as it happens, a
    /// terminal `BOOKMARK` line carrying the resume revision, and an
    /// `ERROR` line (e.g. 410 after feed compaction) if the feed
    /// position is lost mid-stream.
    fn step_stream(&mut self, now: Instant) -> TailStep {
        let mut out: Vec<u8> = Vec::new();
        loop {
            let batch = match self.store.changes_since(
                self.ns,
                self.cursor,
                WATCH_BATCH,
            ) {
                Ok(b) => b,
                Err(e) => {
                    let j = Json::obj()
                        .set("type", Json::Str("ERROR".into()))
                        .set(
                            "code",
                            Json::Num(e.http_status() as f64),
                        )
                        .set("message", Json::Str(e.to_string()));
                    chunk_frame_into(
                        &mut out,
                        format!("{}\n", j.dump()).as_bytes(),
                    );
                    out.extend_from_slice(CHUNK_TERMINAL);
                    return TailStep::End(out);
                }
            };
            if batch.is_empty() {
                break;
            }
            let full = batch.len() == WATCH_BATCH;
            self.cursor =
                batch.last().map(|c| c.rev).unwrap_or(self.cursor);
            for c in &batch {
                if self.matches(&c.key) {
                    chunk_frame_into(
                        &mut out,
                        &change_line(&*self.kind, c),
                    );
                }
            }
            if !full {
                break;
            }
        }
        if now >= self.deadline {
            let bookmark = Json::obj()
                .set("type", Json::Str("BOOKMARK".into()))
                .set(
                    "resource_version",
                    Json::Num(self.cursor as f64),
                );
            chunk_frame_into(
                &mut out,
                format!("{}\n", bookmark.dump()).as_bytes(),
            );
            out.extend_from_slice(CHUNK_TERMINAL);
            return TailStep::End(out);
        }
        if out.is_empty() {
            TailStep::Pending
        } else {
            TailStep::Data(out)
        }
    }

    /// Long-poll mode: resolve into one enveloped batch as soon as at
    /// least one matching event lands past `since` (or the window
    /// closes), with the `resource_version` to resume from.
    fn step_poll(&mut self, now: Instant) -> TailStep {
        loop {
            let batch = match self.store.changes_since(
                self.ns,
                self.cursor,
                WATCH_BATCH,
            ) {
                Ok(b) => b,
                Err(e) => {
                    return TailStep::Respond(Box::new(wrap_err(
                        Envelope::V2,
                        &e,
                    )))
                }
            };
            if batch.is_empty() {
                break;
            }
            let full = batch.len() == WATCH_BATCH;
            self.cursor =
                batch.last().map(|c| c.rev).unwrap_or(self.cursor);
            for c in &batch {
                if self.matches(&c.key) {
                    self.events.push(change_json(&*self.kind, c));
                }
            }
            if !self.events.is_empty() || !full {
                break;
            }
        }
        if !self.events.is_empty() || now >= self.deadline {
            let events = std::mem::take(&mut self.events);
            let result = Json::obj()
                .set("events", Json::Arr(events))
                .set(
                    "resource_version",
                    Json::Num(self.cursor as f64),
                );
            return TailStep::Respond(Box::new(wrap_ok(
                Envelope::V2,
                result,
            )));
        }
        TailStep::Pending
    }
}

impl TailSource for WatchTail {
    fn step(&mut self, now: Instant) -> TailStep {
        if self.stream {
            self.step_stream(now)
        } else {
            self.step_poll(now)
        }
    }

    fn deadline(&self) -> Instant {
        self.deadline
    }

    fn wait(&self, max: Duration) {
        let now = Instant::now();
        let until_deadline =
            self.deadline.saturating_duration_since(now);
        let _ = self
            .store
            .wait_rev_above(self.cursor, max.min(until_deadline));
    }
}

fn watch_response(
    s: &Arc<Services>,
    kind: &Arc<dyn ResourceKind>,
    ctx: &Ctx<'_>,
) -> Response {
    let params = match watch_params(ctx) {
        Ok(p) => p,
        Err(e) => return wrap_err(Envelope::V2, &e),
    };
    let prefix = if kind.scope_index().is_some() {
        match ctx.param("name") {
            Ok(scope) => Some(kind.scope_prefix(scope)),
            Err(e) => return wrap_err(Envelope::V2, &e),
        }
    } else {
        None
    };
    // default: only future events (the client just listed)
    let since = params.since.unwrap_or_else(|| s.store.current_rev());
    let tail = WatchTail {
        store: Arc::clone(&s.store),
        ns: kind.ns(),
        prefix,
        kind: Arc::clone(kind),
        cursor: since,
        deadline: Instant::now() + params.timeout,
        stream: params.stream,
        events: Vec::new(),
    };
    if params.stream {
        Response::tail_stream(
            200,
            "application/x-json-stream",
            Box::new(tail),
        )
    } else {
        Response::tail_poll(Box::new(tail))
    }
}

// ----------------------------------------------------------- stream list

/// A full-namespace drain parked in the reactor (`?stream=1` without
/// `watch`): one chunked JSON line per document, spliced from the
/// revision-keyed encoded-body cache. Each step re-acquires the shard
/// lock for one bounded chunk and resumes from the last emitted key
/// (`MetaStore::scan_chunk`), so a 1M-doc drain never holds a lock
/// longer than one chunk, never re-walks delivered entries, and — with
/// the reactor flushing between chunks — never buffers more than one
/// chunk per connection.
struct ListTail {
    store: Arc<MetaStore>,
    ns: &'static str,
    /// Scoped kinds drain only their scope's key range.
    prefix: Option<String>,
    /// Resume point: last key emitted (or the scope prefix at start).
    after: Option<String>,
    /// Fingerprint + anchor for the resumable cut cursor.
    fingerprint: u64,
    anchor: u64,
    count: usize,
    deadline: Instant,
    done: bool,
}

impl ListTail {
    /// Terminal line of a completed drain. The `resource_version` is
    /// the bookmark captured before the first chunk — start a watch
    /// there for at-least-once continuity with the drained state.
    fn end_line(&self) -> Vec<u8> {
        format!(
            "{{\"done\":true,\"count\":{},\"resource_version\":{}}}\n",
            self.count, self.anchor
        )
        .into_bytes()
    }

    /// Terminal line of a drain cut at its deadline (consumer slower
    /// than the window): carries a cursor token to resume from.
    fn cut_line(&self) -> Vec<u8> {
        let token = match &self.after {
            Some(k) => Cursor {
                rev: self.anchor,
                fingerprint: self.fingerprint,
                last_key: k.clone(),
            }
            .encode(),
            None => String::new(),
        };
        format!(
            "{{\"type\":\"ERROR\",\"code\":408,\"message\":\
             \"drain window closed before completion\",\
             \"cursor\":\"{token}\",\"count\":{}}}\n",
            self.count
        )
        .into_bytes()
    }

    /// One drain step: emit one bounded chunk of
    /// `{"key":K,"object":<cached encoding>}` lines. Hot: the only
    /// per-document work is three shell splices and one
    /// `extend_from_slice` of the document's cached bytes — no
    /// per-document allocation, parse, or render.
    fn step_drain(&mut self, now: Instant) -> TailStep {
        if self.done {
            // defensive: a finished tail re-stepped emits nothing
            return TailStep::End(Vec::with_capacity(0));
        }
        if now >= self.deadline {
            self.done = true;
            let cut = self.cut_line();
            let mut out = Vec::with_capacity(cut.len() + 32);
            chunk_frame_into(&mut out, &cut);
            out.extend_from_slice(CHUNK_TERMINAL);
            return TailStep::End(out);
        }
        let mut body =
            Vec::with_capacity(LIST_CHUNK_BYTES + 4 * 1024);
        let mut emitted = 0usize;
        let mut past_scope = false;
        let prefix = &self.prefix;
        let mut emit = |k: &str, d: &Arc<Doc>| -> bool {
            if let Some(p) = prefix {
                if !k.starts_with(p.as_str()) {
                    past_scope = true;
                    return false;
                }
            }
            body.extend_from_slice(b"{\"key\":");
            write_json_string(&mut body, k);
            body.extend_from_slice(b",\"object\":");
            body.extend_from_slice(&d.encoded());
            body.extend_from_slice(b"}\n");
            emitted += 1;
            body.len() < LIST_CHUNK_BYTES
        };
        let resume = self.store.scan_chunk(
            self.ns,
            self.after.as_deref(),
            LIST_CHUNK_DOCS,
            &mut emit,
        );
        self.count += emitted;
        match resume {
            Some(k) if !past_scope => {
                self.after = Some(k);
                let mut out = Vec::with_capacity(body.len() + 16);
                chunk_frame_into(&mut out, &body);
                TailStep::Data(out)
            }
            _ => {
                self.done = true;
                let end = self.end_line();
                let mut out =
                    Vec::with_capacity(body.len() + end.len() + 48);
                chunk_frame_into(&mut out, &body);
                chunk_frame_into(&mut out, &end);
                out.extend_from_slice(CHUNK_TERMINAL);
                TailStep::End(out)
            }
        }
    }
}

impl TailSource for ListTail {
    fn step(&mut self, now: Instant) -> TailStep {
        self.step_drain(now)
    }

    fn deadline(&self) -> Instant {
        self.deadline
    }

    fn wait(&self, max: Duration) {
        // a drain never reports Pending (there is always either a
        // chunk or the end line), so a blocking driver never actually
        // waits; bound the sleep defensively all the same
        std::thread::sleep(max.min(Duration::from_millis(10)));
    }
}

/// `GET /api/v2/{kind}?stream=1`: drain the collection as a chunked
/// stream. Drains serve bulk export/replication bootstrap, so the
/// narrowing parameters of the paged list (filters, selectors,
/// offset/limit) are rejected — a narrowed walk belongs to the cursor
/// loop. `?cursor=` resumes a previously cut drain.
fn stream_list_response(
    s: &Arc<Services>,
    kind: &Arc<dyn ResourceKind>,
    ctx: &Ctx<'_>,
) -> Response {
    match stream_list_tail(s, kind, ctx) {
        Ok(tail) => Response::tail_stream(
            200,
            "application/x-json-stream",
            Box::new(tail),
        ),
        Err(e) => wrap_err(Envelope::V2, &e),
    }
}

fn stream_list_tail(
    s: &Arc<Services>,
    kind: &Arc<dyn ResourceKind>,
    ctx: &Ctx<'_>,
) -> crate::Result<ListTail> {
    for p in ["label", "limit", "offset", "status"] {
        if ctx.query(p).is_some() {
            return Err(invalid(format!(
                "{p} does not compose with stream=1; use cursor \
                 pagination for narrowed lists"
            )));
        }
    }
    for f in kind.filters() {
        if ctx.query(f.query).is_some() {
            return Err(invalid(format!(
                "{} does not compose with stream=1; use cursor \
                 pagination for narrowed lists",
                f.query
            )));
        }
    }
    let timeout_ms = match ctx.query("timeout_ms") {
        None => MAX_WATCH_MS,
        Some(v) => v
            .parse::<u64>()
            .map_err(|_| {
                invalid("timeout_ms must be a positive integer".into())
            })?
            .clamp(1, MAX_WATCH_MS),
    };
    let ns = kind.ns();
    let mut fp_parts: Vec<String> = Vec::with_capacity(2);
    fp_parts.push(ns.to_string());
    let prefix = match kind.scope_index() {
        Some(_) => {
            let scope = ctx.param("name")?;
            fp_parts.push(format!("scope={scope}"));
            Some(kind.scope_prefix(scope))
        }
        None => None,
    };
    let fp = fingerprint(&fp_parts);
    let bookmark = s.store.current_rev();
    // a scope's keys all sort strictly after the bare prefix, so the
    // prefix itself is the scoped drain's seek origin
    let (after, anchor) = match ctx.query("cursor") {
        None => (prefix.clone(), bookmark),
        Some(raw) => {
            let c = Cursor::decode(raw)?;
            if c.fingerprint != fp {
                return Err(crate::SubmarineError::Gone(
                    "cursor was minted for a different query shape; \
                     restart the drain without it"
                        .into(),
                ));
            }
            if c.rev > bookmark {
                return Err(crate::SubmarineError::Gone(
                    "cursor anchor revision is ahead of this server \
                     (restarted?); restart the drain without it"
                        .into(),
                ));
            }
            (Some(c.last_key), c.rev)
        }
    };
    Ok(ListTail {
        store: Arc::clone(&s.store),
        ns,
        prefix,
        after,
        fingerprint: fp,
        anchor,
        count: 0,
        deadline: Instant::now() + Duration::from_millis(timeout_ms),
        done: false,
    })
}
