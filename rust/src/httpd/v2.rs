//! The REST API surface: every endpoint as a typed handler, registered
//! under `/api/v2` (v2 envelope, pagination, filtering) with `/api/v1`
//! kept as a thin compat shim over the same handlers and managers.
//!
//! See `docs/API.md` for the full route table.

use super::handler::{typed, Body, Ctx, Handler, Page};
use super::middleware::{
    AuthMiddleware, LogMiddleware, MetricsMiddleware, RateLimitMiddleware,
};
use super::router::{Envelope, Router};
use super::server::Services;
use crate::environment::Environment;
use crate::experiment::spec::ExperimentSpec;
use crate::template::Template;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Server-side API configuration (threaded from the CLI flags).
#[derive(Debug, Clone, Default)]
pub struct ApiConfig {
    /// Bearer token required on every request when set.
    pub auth_token: Option<String>,
    /// Global token-bucket limit `(requests_per_sec, burst)` when set.
    pub rate_limit: Option<(f64, f64)>,
}

/// Build the full router: middleware chain + v1 compat + v2 routes.
pub fn build_api(services: Arc<Services>, cfg: &ApiConfig) -> Router {
    let mut r = Router::new();
    // Outermost first: log everything, measure everything (including
    // 401/429 rejections), then authenticate, then rate-limit. Auth
    // sits before the limiter so unauthenticated traffic cannot drain
    // the single global bucket and starve token-holding clients; the
    // auth check itself is a cheap string compare.
    r.add_middleware(Arc::new(LogMiddleware));
    r.add_middleware(Arc::new(MetricsMiddleware::new(Arc::clone(
        &services.metrics,
    ))));
    if let Some(token) = &cfg.auth_token {
        r.add_middleware(Arc::new(AuthMiddleware::new(token)));
    }
    if let Some((rate, burst)) = cfg.rate_limit {
        r.add_middleware(Arc::new(RateLimitMiddleware::new(rate, burst)));
    }
    register_routes(&mut r, services);
    r
}

/// Register one handler under both `/api/v1{tail}` and `/api/v2{tail}`.
fn both(r: &mut Router, method: &str, tail: &str, h: Arc<dyn Handler>) {
    r.route_shared(
        method,
        &format!("/api/v1{tail}"),
        Envelope::V1,
        Arc::clone(&h),
    );
    r.route_shared(method, &format!("/api/v2{tail}"), Envelope::V2, h);
}

fn experiment_item(id: String, status: &str) -> Json {
    Json::obj()
        .set("experimentId", Json::Str(id))
        .set("status", Json::Str(status.to_string()))
}

/// Lists without a status dimension reject `?status=` instead of
/// silently returning unfiltered data.
fn reject_status_filter(page: &Page, what: &str) -> crate::Result<()> {
    if page.status.is_some() {
        return Err(crate::SubmarineError::InvalidSpec(format!(
            "{what} have no status; remove the status query param"
        )));
    }
    Ok(())
}

fn register_routes(r: &mut Router, s: Arc<Services>) {
    // ---- health / version ------------------------------------------
    both(
        r,
        "GET",
        "/cluster",
        Arc::new(typed(|_: &Ctx<'_>, _: ()| {
            Ok(Json::obj()
                .set("version", Json::Str(crate::version().into()))
                .set("status", Json::Str("RUNNING".into())))
        })),
    );

    // ---- experiments -----------------------------------------------
    {
        let s = Arc::clone(&s);
        both(
            r,
            "POST",
            "/experiment",
            Arc::new(typed(
                move |_: &Ctx<'_>, Body(spec): Body<ExperimentSpec>| {
                    let id = s.experiments.submit(&spec)?;
                    Ok(Json::obj().set("experimentId", Json::Str(id)))
                },
            )),
        );
    }
    {
        // v1 list: the seed's bare array (compat shim).
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v1/experiment",
            Envelope::V1,
            typed(move |_: &Ctx<'_>, _: ()| {
                Ok(s.experiments
                    .list()
                    .into_iter()
                    .map(|(id, st)| experiment_item(id, st.as_str()))
                    .collect::<Vec<Json>>())
            }),
        );
    }
    {
        // v2 list: pagination + status filter, served by the storage
        // engine's `status` secondary index instead of scan-and-filter.
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v2/experiment",
            Envelope::V2,
            typed(move |_: &Ctx<'_>, page: Page| {
                let (rows, total) = s.experiments.list_page(
                    page.status.as_deref(),
                    page.offset,
                    page.limit,
                );
                let items = rows
                    .into_iter()
                    .map(|(id, st)| experiment_item(id, st.as_str()))
                    .collect();
                Ok(page.envelope(items, total))
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        both(
            r,
            "GET",
            "/experiment/:id",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                s.experiments.get(ctx.param("id")?)
            })),
        );
    }
    {
        let s = Arc::clone(&s);
        both(
            r,
            "DELETE",
            "/experiment/:id",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                let id = ctx.param("id")?;
                s.experiments.kill(id)?;
                s.experiments.delete(id)?;
                Ok(true)
            })),
        );
    }
    {
        let s = Arc::clone(&s);
        both(
            r,
            "POST",
            "/experiment/:id/kill",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                s.experiments.kill(ctx.param("id")?)?;
                Ok(true)
            })),
        );
    }
    {
        let s = Arc::clone(&s);
        both(
            r,
            "GET",
            "/experiment/:id/metrics",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                let metric = ctx.query("metric").unwrap_or("loss");
                let series =
                    s.metrics.series(ctx.param("id")?, metric);
                Ok(series
                    .iter()
                    .map(|pt| {
                        Json::obj()
                            .set("step", Json::Num(pt.step as f64))
                            .set("value", Json::Num(pt.value))
                    })
                    .collect::<Vec<Json>>())
            })),
        );
    }

    // ---- templates (paper §3.2.3) ----------------------------------
    {
        let s = Arc::clone(&s);
        both(
            r,
            "POST",
            "/template",
            Arc::new(typed(
                move |_: &Ctx<'_>, Body(t): Body<Template>| {
                    s.templates.register(&t)?;
                    Ok(true)
                },
            )),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v1/template",
            Envelope::V1,
            typed(move |_: &Ctx<'_>, _: ()| {
                Ok(s.templates
                    .list()
                    .into_iter()
                    .map(Json::Str)
                    .collect::<Vec<Json>>())
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v2/template",
            Envelope::V2,
            typed(move |_: &Ctx<'_>, page: Page| {
                reject_status_filter(&page, "templates")?;
                let (items, total) =
                    s.templates.list_page(page.offset, page.limit);
                Ok(page.envelope(
                    items.into_iter().map(Json::Str).collect(),
                    total,
                ))
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        both(
            r,
            "GET",
            "/template/:name",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                Ok(s.templates.get(ctx.param("name")?)?.to_json())
            })),
        );
    }
    {
        // "users can run experiments without writing one line of code":
        // POST { "params": {name: value} } -> submitted experiment.
        let s = Arc::clone(&s);
        both(
            r,
            "POST",
            "/template/:name/submit",
            // body is required JSON (seed behavior: empty body is 400);
            // `params` itself may be omitted for all-default templates
            Arc::new(typed(
                move |ctx: &Ctx<'_>, body: Json| {
                    let values: BTreeMap<String, String> = body
                        .get("params")
                        .and_then(Json::as_obj)
                        .map(|o| {
                            o.iter()
                                .map(|(k, v)| {
                                    (
                                        k.clone(),
                                        match v {
                                            Json::Str(s) => s.clone(),
                                            other => other.dump(),
                                        },
                                    )
                                })
                                .collect()
                        })
                        .unwrap_or_default();
                    let spec = s
                        .templates
                        .instantiate(ctx.param("name")?, &values)?;
                    let id = s.experiments.submit(&spec)?;
                    Ok(Json::obj().set("experimentId", Json::Str(id)))
                },
            )),
        );
    }

    // ---- environments (paper §3.2.1) -------------------------------
    {
        let s = Arc::clone(&s);
        both(
            r,
            "POST",
            "/environment",
            Arc::new(typed(
                move |_: &Ctx<'_>, Body(env): Body<Environment>| {
                    s.environments.register(&env)?;
                    Ok(true)
                },
            )),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v1/environment",
            Envelope::V1,
            typed(move |_: &Ctx<'_>, _: ()| {
                Ok(s.environments
                    .list()
                    .into_iter()
                    .map(Json::Str)
                    .collect::<Vec<Json>>())
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v2/environment",
            Envelope::V2,
            typed(move |_: &Ctx<'_>, page: Page| {
                reject_status_filter(&page, "environments")?;
                let (items, total) =
                    s.environments.list_page(page.offset, page.limit);
                Ok(page.envelope(
                    items.into_iter().map(Json::Str).collect(),
                    total,
                ))
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        both(
            r,
            "GET",
            "/environment/:name",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                let name = ctx.param("name")?;
                let env = s.environments.get(name)?;
                let lock = s.environments.lock_of(name).unwrap_or_default();
                Ok(env.to_json().set(
                    "lock",
                    Json::Arr(
                        lock.into_iter().map(Json::Str).collect(),
                    ),
                ))
            })),
        );
    }

    // ---- models (paper §4.2) ---------------------------------------
    {
        // v1: the seed's bare version array.
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v1/model/:name",
            Envelope::V1,
            typed(move |ctx: &Ctx<'_>, _: ()| {
                let name = ctx.param("name")?;
                let versions = s.models.versions(name);
                if versions.is_empty() {
                    return Err(crate::SubmarineError::NotFound(
                        format!("model {name}"),
                    ));
                }
                Ok(versions
                    .iter()
                    .map(model_version_json)
                    .collect::<Vec<Json>>())
            }),
        );
    }
    {
        // v2: pagination + `stage` filter.
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v2/model/:name",
            Envelope::V2,
            typed(move |ctx: &Ctx<'_>, page: Page| {
                // model versions filter on `stage`, not `status`
                reject_status_filter(&page, "model versions")?;
                let name = ctx.param("name")?;
                // existence = one name-index probe; the stage filter
                // walks the stage index (no scan-and-filter, and no
                // materializing versions that the filter discards)
                if !s.models.exists(name) {
                    return Err(crate::SubmarineError::NotFound(
                        format!("model {name}"),
                    ));
                }
                let versions = match ctx.query("stage") {
                    Some(stage) => s.models.versions_by_stage(name, stage),
                    None => s.models.versions(name),
                };
                let (items, total) = page.slice(versions);
                Ok(page.envelope(
                    items.iter().map(model_version_json).collect(),
                    total,
                ))
            }),
        );
    }
}

fn model_version_json(m: &crate::model::ModelVersion) -> Json {
    Json::obj()
        .set("version", Json::Num(m.version as f64))
        .set("stage", Json::Str(m.stage.as_str().into()))
        .set("experimentId", Json::Str(m.experiment_id.clone()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::httpd::http::Request;
    use crate::orchestrator::Submitter;
    use crate::storage::MetaStore;

    struct NullSubmitter;
    impl Submitter for NullSubmitter {
        fn name(&self) -> &'static str {
            "null"
        }
        fn submit(
            &self,
            _: &str,
            _: &ExperimentSpec,
        ) -> crate::Result<()> {
            Ok(())
        }
        fn kill(&self, _: &str) -> crate::Result<()> {
            Ok(())
        }
    }

    fn services() -> Arc<Services> {
        Arc::new(Services::new(
            Arc::new(MetaStore::in_memory()),
            Arc::new(NullSubmitter),
        ))
    }

    fn api() -> Router {
        build_api(services(), &ApiConfig::default())
    }

    fn dispatch(
        router: &Router,
        method: &str,
        path: &str,
        body: &str,
    ) -> (u16, Json) {
        let mut req = Request::synthetic(method, path);
        req.body = body.as_bytes().to_vec();
        let resp = router.dispatch(&req);
        let j = Json::parse(
            std::str::from_utf8(&resp.body).unwrap_or("null"),
        )
        .unwrap_or(Json::Null);
        (resp.status, j)
    }

    const SPEC: &str = r#"{"meta":{"name":"mnist"},
        "spec":{"Worker":{"replicas":1,"resources":"cpu=1"}}}"#;

    #[test]
    fn experiment_crud_over_both_versions() {
        let r = api();
        for base in ["/api/v1", "/api/v2"] {
            let (st, j) =
                dispatch(&r, "POST", &format!("{base}/experiment"), SPEC);
            assert_eq!(st, 200, "{base}: {j:?}");
            let id = j
                .at(&["result", "experimentId"])
                .unwrap()
                .as_str()
                .unwrap()
                .to_string();
            let (st, j) = dispatch(
                &r,
                "GET",
                &format!("{base}/experiment/{id}"),
                "",
            );
            assert_eq!(st, 200);
            assert_eq!(
                j.at(&["result", "status"]).unwrap().as_str(),
                Some("Accepted")
            );
            let (st, _) = dispatch(
                &r,
                "POST",
                &format!("{base}/experiment/{id}/kill"),
                "",
            );
            assert_eq!(st, 200);
            let (st, j) = dispatch(
                &r,
                "DELETE",
                &format!("{base}/experiment/{id}"),
                "",
            );
            assert_eq!(st, 200, "{j:?}");
        }
    }

    #[test]
    fn v2_list_paginates_and_filters() {
        let r = api();
        for _ in 0..5 {
            let (st, _) =
                dispatch(&r, "POST", "/api/v2/experiment", SPEC);
            assert_eq!(st, 200);
        }
        let (st, j) = dispatch(
            &r,
            "GET",
            "/api/v2/experiment?limit=2&offset=1",
            "",
        );
        assert_eq!(st, 200);
        let result = j.get("result").unwrap();
        assert_eq!(result.num_field("total"), Some(5.0));
        assert_eq!(result.num_field("offset"), Some(1.0));
        assert_eq!(
            result.get("items").unwrap().as_arr().unwrap().len(),
            2
        );
        // all seeds are Accepted: filtering by Running yields none
        let (st, j) = dispatch(
            &r,
            "GET",
            "/api/v2/experiment?status=Running",
            "",
        );
        assert_eq!(st, 200);
        assert_eq!(
            j.at(&["result", "total"]).and_then(Json::as_f64),
            Some(0.0)
        );
        let (st, j) = dispatch(
            &r,
            "GET",
            "/api/v2/experiment?status=accepted",
            "",
        );
        assert_eq!(st, 200, "{j:?}");
        assert_eq!(
            j.at(&["result", "total"]).and_then(Json::as_f64),
            Some(5.0)
        );
    }

    #[test]
    fn v1_list_stays_bare_array() {
        let r = api();
        let (st, _) = dispatch(&r, "POST", "/api/v1/experiment", SPEC);
        assert_eq!(st, 200);
        let (st, j) = dispatch(&r, "GET", "/api/v1/experiment", "");
        assert_eq!(st, 200);
        assert_eq!(
            j.get("result").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn bad_spec_is_400_with_v2_error_envelope() {
        let r = api();
        let (st, j) = dispatch(&r, "POST", "/api/v2/experiment", "{}");
        assert_eq!(st, 400);
        assert_eq!(j.str_field("status"), Some("ERROR"));
        assert_eq!(j.num_field("code"), Some(400.0));
        assert!(j.at(&["error", "message"]).is_some());
        let (st, _) =
            dispatch(&r, "POST", "/api/v2/experiment", "not json");
        assert_eq!(st, 400);
        // v1 keeps the flat shape
        let (st, j) = dispatch(&r, "POST", "/api/v1/experiment", "{}");
        assert_eq!(st, 400);
        assert!(j.str_field("message").is_some());
    }

    #[test]
    fn template_register_and_submit() {
        let r = api();
        let tpl = crate::template::tf_mnist_template().to_json().dump();
        let (st, _) = dispatch(&r, "POST", "/api/v2/template", &tpl);
        assert_eq!(st, 200);
        let (st, j) = dispatch(
            &r,
            "POST",
            "/api/v2/template/tf-mnist-template/submit",
            r#"{"params":{"learning_rate":"0.01","batch_size":"64"}}"#,
        );
        assert_eq!(st, 200, "{j:?}");
        assert!(j.at(&["result", "experimentId"]).is_some());
        // v1 shim sees the same registry
        let (st, j) = dispatch(&r, "GET", "/api/v1/template", "");
        assert_eq!(st, 200);
        assert_eq!(
            j.get("result").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn environment_register_and_lock() {
        let r = api();
        let (st, _) = dispatch(
            &r,
            "POST",
            "/api/v2/environment",
            r#"{"name":"tf","image":"submarine:tf",
                "dependencies":["tensorflow>=2.0"]}"#,
        );
        assert_eq!(st, 200);
        let (st, j) =
            dispatch(&r, "GET", "/api/v2/environment/tf", "");
        assert_eq!(st, 200);
        let lock = j.at(&["result", "lock"]).unwrap().as_arr().unwrap();
        assert!(!lock.is_empty());
    }

    #[test]
    fn status_filter_rejected_where_unsupported() {
        let r = api();
        let (st, j) =
            dispatch(&r, "GET", "/api/v2/template?status=x", "");
        assert_eq!(st, 400, "{j:?}");
        let (st, _) =
            dispatch(&r, "GET", "/api/v2/environment?status=x", "");
        assert_eq!(st, 400);
    }

    #[test]
    fn missing_model_is_not_found() {
        let r = api();
        let (st, j) = dispatch(&r, "GET", "/api/v2/model/nope", "");
        assert_eq!(st, 404);
        assert_eq!(
            j.at(&["error", "type"]).and_then(Json::as_str),
            Some("NotFound")
        );
    }

    #[test]
    fn http_metrics_recorded_per_route() {
        let s = services();
        let r = build_api(Arc::clone(&s), &ApiConfig::default());
        for _ in 0..4 {
            dispatch(&r, "GET", "/api/v2/cluster", "");
        }
        let series = s.metrics.series(
            crate::httpd::middleware::HTTP_METRICS_KEY,
            "GET /api/v2/cluster",
        );
        assert_eq!(series.len(), 4);
    }

    #[test]
    fn auth_and_rate_limit_configurable() {
        let cfg = ApiConfig {
            auth_token: Some("tok".into()),
            rate_limit: Some((0.000001, 2.0)),
        };
        let r = build_api(services(), &cfg);
        // no token: 401, and (auth running before the limiter) the
        // anon request must NOT consume rate budget
        let (st, _) = dispatch(&r, "GET", "/api/v2/cluster", "");
        assert_eq!(st, 401);
        let mut req = Request::synthetic("GET", "/api/v2/cluster");
        req.headers
            .insert("authorization".into(), "Bearer tok".into());
        // full burst of 2 available to the authed client...
        assert_eq!(r.dispatch(&req).status, 200);
        assert_eq!(r.dispatch(&req).status, 200);
        // ...and the third authed request is shed with 429
        let shed = r.dispatch(&req);
        assert_eq!(shed.status, 429);
    }
}
