//! The REST API surface: every endpoint as a typed handler, registered
//! under `/api/v2` (v2 envelope, pagination, filtering) with `/api/v1`
//! kept as a thin compat shim over the same handlers and managers.
//!
//! See `docs/API.md` for the full route table.

use super::handler::{typed, Body, Ctx, Handler, Page};
use super::middleware::{
    AuthMiddleware, LogMiddleware, MetricsMiddleware, RateLimitMiddleware,
};
use super::router::{Envelope, Router};
use super::server::Services;
use crate::environment::Environment;
use crate::experiment::spec::ExperimentSpec;
use crate::template::Template;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Server-side API configuration (threaded from the CLI flags).
#[derive(Debug, Clone, Default)]
pub struct ApiConfig {
    /// Bearer token required on every request when set.
    pub auth_token: Option<String>,
    /// Global token-bucket limit `(requests_per_sec, burst)` when set.
    pub rate_limit: Option<(f64, f64)>,
}

/// Build the full router: middleware chain + v1 compat + v2 routes.
pub fn build_api(services: Arc<Services>, cfg: &ApiConfig) -> Router {
    let mut r = Router::new();
    // Outermost first: log everything, measure everything (including
    // 401/429 rejections), then authenticate, then rate-limit. Auth
    // sits before the limiter so unauthenticated traffic cannot drain
    // the single global bucket and starve token-holding clients; the
    // auth check itself is a cheap string compare.
    r.add_middleware(Arc::new(LogMiddleware));
    r.add_middleware(Arc::new(MetricsMiddleware::new(Arc::clone(
        &services.metrics,
    ))));
    if let Some(token) = &cfg.auth_token {
        r.add_middleware(Arc::new(AuthMiddleware::new(token)));
    }
    if let Some((rate, burst)) = cfg.rate_limit {
        r.add_middleware(Arc::new(RateLimitMiddleware::new(rate, burst)));
    }
    register_routes(&mut r, services);
    r
}

/// Register one handler under both `/api/v1{tail}` and `/api/v2{tail}`.
fn both(r: &mut Router, method: &str, tail: &str, h: Arc<dyn Handler>) {
    r.route_shared(
        method,
        &format!("/api/v1{tail}"),
        Envelope::V1,
        Arc::clone(&h),
    );
    r.route_shared(method, &format!("/api/v2{tail}"), Envelope::V2, h);
}

fn experiment_item(id: String, status: &str) -> Json {
    Json::obj()
        .set("experimentId", Json::Str(id))
        .set("status", Json::Str(status.to_string()))
}

/// Lists without a status dimension reject `?status=` instead of
/// silently returning unfiltered data.
fn reject_status_filter(page: &Page, what: &str) -> crate::Result<()> {
    if page.status.is_some() {
        return Err(crate::SubmarineError::InvalidSpec(format!(
            "{what} have no status; remove the status query param"
        )));
    }
    Ok(())
}

fn register_routes(r: &mut Router, s: Arc<Services>) {
    // ---- health / cluster status -----------------------------------
    {
        // health + (when the execution engine is attached) the live
        // cluster picture: nodes, utilization, queue shares, pending
        // jobs, unknown-queue warnings
        let s = Arc::clone(&s);
        both(
            r,
            "GET",
            "/cluster",
            Arc::new(typed(move |_: &Ctx<'_>, _: ()| {
                let mut out = Json::obj()
                    .set(
                        "version",
                        Json::Str(crate::version().into()),
                    )
                    .set("status", Json::Str("RUNNING".into()));
                if let Some(engine) = &s.executor {
                    let status = engine.cluster_status();
                    if let Some(fields) = status.as_obj() {
                        for (k, v) in fields {
                            out = out.set(k, v.clone());
                        }
                    }
                }
                Ok(out)
            })),
        );
    }

    // ---- experiments -----------------------------------------------
    {
        let s = Arc::clone(&s);
        both(
            r,
            "POST",
            "/experiment",
            Arc::new(typed(
                move |_: &Ctx<'_>, Body(spec): Body<ExperimentSpec>| {
                    let id = s.experiments.submit(&spec)?;
                    Ok(Json::obj().set("experimentId", Json::Str(id)))
                },
            )),
        );
    }
    {
        // v1 list: the seed's bare array (compat shim).
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v1/experiment",
            Envelope::V1,
            typed(move |_: &Ctx<'_>, _: ()| {
                Ok(s.experiments
                    .list()
                    .into_iter()
                    .map(|(id, st)| experiment_item(id, st.as_str()))
                    .collect::<Vec<Json>>())
            }),
        );
    }
    {
        // v2 list: pagination + status filter, served by the storage
        // engine's `status` secondary index instead of scan-and-filter.
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v2/experiment",
            Envelope::V2,
            typed(move |_: &Ctx<'_>, page: Page| {
                let (rows, total) = s.experiments.list_page(
                    page.status.as_deref(),
                    page.offset,
                    page.limit,
                );
                let items = rows
                    .into_iter()
                    .map(|(id, st)| experiment_item(id, st.as_str()))
                    .collect();
                Ok(page.envelope(items, total))
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        both(
            r,
            "GET",
            "/experiment/:id",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                s.experiments.get(ctx.param("id")?)
            })),
        );
    }
    {
        let s = Arc::clone(&s);
        both(
            r,
            "DELETE",
            "/experiment/:id",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                let id = ctx.param("id")?;
                s.experiments.kill(id)?;
                s.experiments.delete(id)?;
                Ok(true)
            })),
        );
    }
    {
        let s = Arc::clone(&s);
        both(
            r,
            "POST",
            "/experiment/:id/kill",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                s.experiments.kill(ctx.param("id")?)?;
                Ok(true)
            })),
        );
    }
    {
        // Fig. 4's "records important events": the monitor's per-
        // experiment event log. Volatile — empty after a server restart
        // even though the terminal status survives in the doc.
        let s = Arc::clone(&s);
        both(
            r,
            "GET",
            "/experiment/:id/events",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                let id = ctx.param("id")?;
                s.experiments.get(id)?; // 404 for unknown ids
                Ok(s.monitor
                    .events(id)
                    .iter()
                    .map(|e| e.to_json())
                    .collect::<Vec<Json>>())
            })),
        );
    }
    {
        // AutoML entry point (paper §4.1): each trial is a real child
        // experiment submitted through the same pipeline.
        let s = Arc::clone(&s);
        both(
            r,
            "POST",
            "/experiment/tune",
            Arc::new(typed(move |_: &Ctx<'_>, body: Json| {
                let req = crate::automl::tune::parse_request(&body)?;
                run_tune_over_pipeline(&s, &req)
            })),
        );
    }
    {
        let s = Arc::clone(&s);
        both(
            r,
            "GET",
            "/experiment/:id/metrics",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                let metric = ctx.query("metric").unwrap_or("loss");
                let series =
                    s.metrics.series(ctx.param("id")?, metric);
                Ok(series
                    .iter()
                    .map(|pt| {
                        Json::obj()
                            .set("step", Json::Num(pt.step as f64))
                            .set("value", Json::Num(pt.value))
                    })
                    .collect::<Vec<Json>>())
            })),
        );
    }

    // ---- templates (paper §3.2.3) ----------------------------------
    {
        let s = Arc::clone(&s);
        both(
            r,
            "POST",
            "/template",
            Arc::new(typed(
                move |_: &Ctx<'_>, Body(t): Body<Template>| {
                    s.templates.register(&t)?;
                    Ok(true)
                },
            )),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v1/template",
            Envelope::V1,
            typed(move |_: &Ctx<'_>, _: ()| {
                Ok(s.templates
                    .list()
                    .into_iter()
                    .map(Json::Str)
                    .collect::<Vec<Json>>())
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v2/template",
            Envelope::V2,
            typed(move |_: &Ctx<'_>, page: Page| {
                reject_status_filter(&page, "templates")?;
                let (items, total) =
                    s.templates.list_page(page.offset, page.limit);
                Ok(page.envelope(
                    items.into_iter().map(Json::Str).collect(),
                    total,
                ))
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        both(
            r,
            "GET",
            "/template/:name",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                Ok(s.templates.get(ctx.param("name")?)?.to_json())
            })),
        );
    }
    {
        // "users can run experiments without writing one line of code":
        // POST { "params": {name: value} } -> submitted experiment.
        let s = Arc::clone(&s);
        both(
            r,
            "POST",
            "/template/:name/submit",
            // body is required JSON (seed behavior: empty body is 400);
            // `params` itself may be omitted for all-default templates
            Arc::new(typed(
                move |ctx: &Ctx<'_>, body: Json| {
                    let values: BTreeMap<String, String> = body
                        .get("params")
                        .and_then(Json::as_obj)
                        .map(|o| {
                            o.iter()
                                .map(|(k, v)| {
                                    (
                                        k.clone(),
                                        match v {
                                            Json::Str(s) => s.clone(),
                                            other => other.dump(),
                                        },
                                    )
                                })
                                .collect()
                        })
                        .unwrap_or_default();
                    let spec = s
                        .templates
                        .instantiate(ctx.param("name")?, &values)?;
                    let id = s.experiments.submit(&spec)?;
                    Ok(Json::obj().set("experimentId", Json::Str(id)))
                },
            )),
        );
    }

    // ---- environments (paper §3.2.1) -------------------------------
    {
        let s = Arc::clone(&s);
        both(
            r,
            "POST",
            "/environment",
            Arc::new(typed(
                move |_: &Ctx<'_>, Body(env): Body<Environment>| {
                    s.environments.register(&env)?;
                    Ok(true)
                },
            )),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v1/environment",
            Envelope::V1,
            typed(move |_: &Ctx<'_>, _: ()| {
                Ok(s.environments
                    .list()
                    .into_iter()
                    .map(Json::Str)
                    .collect::<Vec<Json>>())
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v2/environment",
            Envelope::V2,
            typed(move |_: &Ctx<'_>, page: Page| {
                reject_status_filter(&page, "environments")?;
                let (items, total) =
                    s.environments.list_page(page.offset, page.limit);
                Ok(page.envelope(
                    items.into_iter().map(Json::Str).collect(),
                    total,
                ))
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        both(
            r,
            "GET",
            "/environment/:name",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                let name = ctx.param("name")?;
                let env = s.environments.get(name)?;
                let lock = s.environments.lock_of(name).unwrap_or_default();
                Ok(env.to_json().set(
                    "lock",
                    Json::Arr(
                        lock.into_iter().map(Json::Str).collect(),
                    ),
                ))
            })),
        );
    }

    // ---- models (paper §4.2) ---------------------------------------
    {
        // v1: the seed's bare version array.
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v1/model/:name",
            Envelope::V1,
            typed(move |ctx: &Ctx<'_>, _: ()| {
                let name = ctx.param("name")?;
                let versions = s.models.versions(name);
                if versions.is_empty() {
                    return Err(crate::SubmarineError::NotFound(
                        format!("model {name}"),
                    ));
                }
                Ok(versions
                    .iter()
                    .map(model_version_json)
                    .collect::<Vec<Json>>())
            }),
        );
    }
    {
        // v2: pagination + `stage` filter.
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v2/model/:name",
            Envelope::V2,
            typed(move |ctx: &Ctx<'_>, page: Page| {
                // model versions filter on `stage`, not `status`
                reject_status_filter(&page, "model versions")?;
                let name = ctx.param("name")?;
                // existence = one name-index probe; the stage filter
                // walks the stage index (no scan-and-filter, and no
                // materializing versions that the filter discards)
                if !s.models.exists(name) {
                    return Err(crate::SubmarineError::NotFound(
                        format!("model {name}"),
                    ));
                }
                let versions = match ctx.query("stage") {
                    Some(stage) => s.models.versions_by_stage(name, stage),
                    None => s.models.versions(name),
                };
                let (items, total) = page.slice(versions);
                Ok(page.envelope(
                    items.iter().map(model_version_json).collect(),
                    total,
                ))
            }),
        );
    }
}

/// Poll until `id` reaches a terminal status or `timeout_ms` passes; a
/// trial that overruns its budgeted wall time is killed so it frees its
/// queue share and containers.
fn wait_terminal(
    s: &Services,
    id: &str,
    timeout_ms: u64,
) -> crate::experiment::spec::ExperimentStatus {
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_millis(timeout_ms);
    loop {
        let st = s.experiments.status(id);
        if st.is_terminal() {
            return st;
        }
        if std::time::Instant::now() >= deadline {
            crate::warnlog!(
                "tune",
                "trial {id} timed out after {timeout_ms}ms; killing"
            );
            let _ = s.experiments.kill(id);
            return s.experiments.status(id);
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
}

/// Run a tune request where every trial is a child experiment submitted
/// through the manager → scheduler → cluster pipeline. Scores prefer a
/// real logged `loss` metric (negated; local-submitter trials train for
/// real); sim-pipeline trials fall back to the deterministic surrogate.
/// Trials that fail, are killed, or time out score `f64::MIN`.
fn run_tune_over_pipeline(
    s: &Arc<Services>,
    req: &crate::automl::tune::TuneRequest,
) -> crate::Result<Json> {
    use crate::automl::tune;
    // fail fast on an unknown template instead of 64 failed trials
    if let Some(name) = &req.template {
        s.templates.get(name)?;
    }
    let make_spec = |params: &BTreeMap<String, String>,
                     budget: u32|
     -> crate::Result<ExperimentSpec> {
        let mut spec = match (&req.template, &req.base_spec) {
            (Some(name), _) => s.templates.instantiate(name, params)?,
            (None, Some(base)) => {
                let filled =
                    crate::template::substitute(base, params)?;
                ExperimentSpec::from_json(&filled)?
            }
            (None, None) => {
                return Err(crate::SubmarineError::InvalidSpec(
                    "tune request lost its spec source".into(),
                ))
            }
        };
        // the rung budget rides on the child spec as workload steps, so
        // it is visible on the experiment doc (and drives real training
        // time under the local submitter)
        let mut w = spec.workload.clone().unwrap_or_default();
        w.steps = budget;
        spec.workload = Some(w);
        Ok(spec)
    };
    let run_trial = |params: &BTreeMap<String, String>,
                     budget: u32|
     -> tune::TrialRun {
        let submitted = make_spec(params, budget)
            .and_then(|spec| s.experiments.submit(&spec));
        match submitted {
            Ok(id) => {
                let st = wait_terminal(s, &id, req.trial_timeout_ms);
                let score = if st
                    == crate::experiment::spec::ExperimentStatus::Succeeded
                {
                    match s.metrics.last(&id, "loss") {
                        Some(p) => -p.value,
                        None => tune::surrogate_objective(
                            params, budget, req.seed,
                        ),
                    }
                } else {
                    f64::MIN
                };
                s.metrics.log(&id, "objective", budget as u64, score);
                tune::TrialRun {
                    experiment_id: id,
                    params: params.clone(),
                    score,
                    budget,
                    status: st.as_str().to_string(),
                }
            }
            Err(e) => tune::TrialRun {
                experiment_id: String::new(),
                params: params.clone(),
                score: f64::MIN,
                budget,
                status: format!("SubmitFailed: {e}"),
            },
        }
    };
    Ok(tune::run_tune(req, run_trial))
}

fn model_version_json(m: &crate::model::ModelVersion) -> Json {
    Json::obj()
        .set("version", Json::Num(m.version as f64))
        .set("stage", Json::Str(m.stage.as_str().into()))
        .set("experimentId", Json::Str(m.experiment_id.clone()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::httpd::http::Request;
    use crate::orchestrator::Submitter;
    use crate::storage::MetaStore;

    struct NullSubmitter;
    impl Submitter for NullSubmitter {
        fn name(&self) -> &'static str {
            "null"
        }
        fn submit(
            &self,
            _: &str,
            _: &ExperimentSpec,
        ) -> crate::Result<()> {
            Ok(())
        }
        fn kill(&self, _: &str) -> crate::Result<()> {
            Ok(())
        }
    }

    fn services() -> Arc<Services> {
        Arc::new(Services::new(
            Arc::new(MetaStore::in_memory()),
            Arc::new(NullSubmitter),
        ))
    }

    fn api() -> Router {
        build_api(services(), &ApiConfig::default())
    }

    fn dispatch(
        router: &Router,
        method: &str,
        path: &str,
        body: &str,
    ) -> (u16, Json) {
        let mut req = Request::synthetic(method, path);
        req.body = body.as_bytes().to_vec();
        let resp = router.dispatch(&req);
        let j = Json::parse(
            std::str::from_utf8(&resp.body).unwrap_or("null"),
        )
        .unwrap_or(Json::Null);
        (resp.status, j)
    }

    const SPEC: &str = r#"{"meta":{"name":"mnist"},
        "spec":{"Worker":{"replicas":1,"resources":"cpu=1"}}}"#;

    #[test]
    fn experiment_crud_over_both_versions() {
        let r = api();
        for base in ["/api/v1", "/api/v2"] {
            let (st, j) =
                dispatch(&r, "POST", &format!("{base}/experiment"), SPEC);
            assert_eq!(st, 200, "{base}: {j:?}");
            let id = j
                .at(&["result", "experimentId"])
                .unwrap()
                .as_str()
                .unwrap()
                .to_string();
            let (st, j) = dispatch(
                &r,
                "GET",
                &format!("{base}/experiment/{id}"),
                "",
            );
            assert_eq!(st, 200);
            assert_eq!(
                j.at(&["result", "status"]).unwrap().as_str(),
                Some("Accepted")
            );
            let (st, _) = dispatch(
                &r,
                "POST",
                &format!("{base}/experiment/{id}/kill"),
                "",
            );
            assert_eq!(st, 200);
            let (st, j) = dispatch(
                &r,
                "DELETE",
                &format!("{base}/experiment/{id}"),
                "",
            );
            assert_eq!(st, 200, "{j:?}");
        }
    }

    #[test]
    fn v2_list_paginates_and_filters() {
        let r = api();
        for _ in 0..5 {
            let (st, _) =
                dispatch(&r, "POST", "/api/v2/experiment", SPEC);
            assert_eq!(st, 200);
        }
        let (st, j) = dispatch(
            &r,
            "GET",
            "/api/v2/experiment?limit=2&offset=1",
            "",
        );
        assert_eq!(st, 200);
        let result = j.get("result").unwrap();
        assert_eq!(result.num_field("total"), Some(5.0));
        assert_eq!(result.num_field("offset"), Some(1.0));
        assert_eq!(
            result.get("items").unwrap().as_arr().unwrap().len(),
            2
        );
        // all seeds are Accepted: filtering by Running yields none
        let (st, j) = dispatch(
            &r,
            "GET",
            "/api/v2/experiment?status=Running",
            "",
        );
        assert_eq!(st, 200);
        assert_eq!(
            j.at(&["result", "total"]).and_then(Json::as_f64),
            Some(0.0)
        );
        let (st, j) = dispatch(
            &r,
            "GET",
            "/api/v2/experiment?status=accepted",
            "",
        );
        assert_eq!(st, 200, "{j:?}");
        assert_eq!(
            j.at(&["result", "total"]).and_then(Json::as_f64),
            Some(5.0)
        );
    }

    #[test]
    fn v1_list_stays_bare_array() {
        let r = api();
        let (st, _) = dispatch(&r, "POST", "/api/v1/experiment", SPEC);
        assert_eq!(st, 200);
        let (st, j) = dispatch(&r, "GET", "/api/v1/experiment", "");
        assert_eq!(st, 200);
        assert_eq!(
            j.get("result").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn bad_spec_is_400_with_v2_error_envelope() {
        let r = api();
        let (st, j) = dispatch(&r, "POST", "/api/v2/experiment", "{}");
        assert_eq!(st, 400);
        assert_eq!(j.str_field("status"), Some("ERROR"));
        assert_eq!(j.num_field("code"), Some(400.0));
        assert!(j.at(&["error", "message"]).is_some());
        let (st, _) =
            dispatch(&r, "POST", "/api/v2/experiment", "not json");
        assert_eq!(st, 400);
        // v1 keeps the flat shape
        let (st, j) = dispatch(&r, "POST", "/api/v1/experiment", "{}");
        assert_eq!(st, 400);
        assert!(j.str_field("message").is_some());
    }

    #[test]
    fn template_register_and_submit() {
        let r = api();
        let tpl = crate::template::tf_mnist_template().to_json().dump();
        let (st, _) = dispatch(&r, "POST", "/api/v2/template", &tpl);
        assert_eq!(st, 200);
        let (st, j) = dispatch(
            &r,
            "POST",
            "/api/v2/template/tf-mnist-template/submit",
            r#"{"params":{"learning_rate":"0.01","batch_size":"64"}}"#,
        );
        assert_eq!(st, 200, "{j:?}");
        assert!(j.at(&["result", "experimentId"]).is_some());
        // v1 shim sees the same registry
        let (st, j) = dispatch(&r, "GET", "/api/v1/template", "");
        assert_eq!(st, 200);
        assert_eq!(
            j.get("result").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn environment_register_and_lock() {
        let r = api();
        let (st, _) = dispatch(
            &r,
            "POST",
            "/api/v2/environment",
            r#"{"name":"tf","image":"submarine:tf",
                "dependencies":["tensorflow>=2.0"]}"#,
        );
        assert_eq!(st, 200);
        let (st, j) =
            dispatch(&r, "GET", "/api/v2/environment/tf", "");
        assert_eq!(st, 200);
        let lock = j.at(&["result", "lock"]).unwrap().as_arr().unwrap();
        assert!(!lock.is_empty());
    }

    #[test]
    fn status_filter_rejected_where_unsupported() {
        let r = api();
        let (st, j) =
            dispatch(&r, "GET", "/api/v2/template?status=x", "");
        assert_eq!(st, 400, "{j:?}");
        let (st, _) =
            dispatch(&r, "GET", "/api/v2/environment?status=x", "");
        assert_eq!(st, 400);
    }

    #[test]
    fn missing_model_is_not_found() {
        let r = api();
        let (st, j) = dispatch(&r, "GET", "/api/v2/model/nope", "");
        assert_eq!(st, 404);
        assert_eq!(
            j.at(&["error", "type"]).and_then(Json::as_str),
            Some("NotFound")
        );
    }

    #[test]
    fn events_endpoint_serves_monitor_log() {
        let r = api();
        let (st, j) = dispatch(&r, "POST", "/api/v2/experiment", SPEC);
        assert_eq!(st, 200);
        let id = j
            .at(&["result", "experimentId"])
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        let (st, j) = dispatch(
            &r,
            "GET",
            &format!("/api/v2/experiment/{id}/events"),
            "",
        );
        assert_eq!(st, 200);
        let events = j.get("result").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        assert_eq!(
            events[0].at(&["event", "type"]).and_then(Json::as_str),
            Some("Accepted")
        );
        let (st, _) = dispatch(
            &r,
            "GET",
            "/api/v2/experiment/ghost/events",
            "",
        );
        assert_eq!(st, 404);
    }

    #[test]
    fn tune_validates_and_times_out_dead_submitters() {
        let r = api();
        // bad request: no template/spec source
        let (st, _) = dispatch(
            &r,
            "POST",
            "/api/v2/experiment/tune",
            r#"{"space":{"x":{"uniform":[0,1]}}}"#,
        );
        assert_eq!(st, 400);
        // unknown template is a 404 before any trial runs
        let (st, _) = dispatch(
            &r,
            "POST",
            "/api/v2/experiment/tune",
            r#"{"template":"nope",
                "space":{"x":{"uniform":[0,1]}}}"#,
        );
        assert_eq!(st, 404);
        // the NullSubmitter never progresses trials: they hit the
        // per-trial timeout, get killed, and score as failed
        let tpl = crate::template::tf_mnist_template().to_json().dump();
        let (st, _) = dispatch(&r, "POST", "/api/v2/template", &tpl);
        assert_eq!(st, 200);
        let (st, j) = dispatch(
            &r,
            "POST",
            "/api/v2/experiment/tune",
            r#"{"template":"tf-mnist-template","trials":2,
                "budget":10,"trial_timeout_ms":1,
                "space":{"learning_rate":
                    {"log_uniform":[0.0001,1.0]}}}"#,
        );
        assert_eq!(st, 200, "{j:?}");
        let trials =
            j.at(&["result", "trials"]).unwrap().as_arr().unwrap();
        assert_eq!(trials.len(), 2);
        assert!(trials
            .iter()
            .all(|t| t.str_field("status") == Some("Killed")));
    }

    #[test]
    fn http_metrics_recorded_per_route() {
        let s = services();
        let r = build_api(Arc::clone(&s), &ApiConfig::default());
        for _ in 0..4 {
            dispatch(&r, "GET", "/api/v2/cluster", "");
        }
        let series = s.metrics.series(
            crate::httpd::middleware::HTTP_METRICS_KEY,
            "GET /api/v2/cluster",
        );
        assert_eq!(series.len(), 4);
    }

    #[test]
    fn auth_and_rate_limit_configurable() {
        let cfg = ApiConfig {
            auth_token: Some("tok".into()),
            rate_limit: Some((0.000001, 2.0)),
        };
        let r = build_api(services(), &cfg);
        // no token: 401, and (auth running before the limiter) the
        // anon request must NOT consume rate budget
        let (st, _) = dispatch(&r, "GET", "/api/v2/cluster", "");
        assert_eq!(st, 401);
        let mut req = Request::synthetic("GET", "/api/v2/cluster");
        req.headers
            .insert("authorization".into(), "Bearer tok".into());
        // full burst of 2 available to the authed client...
        assert_eq!(r.dispatch(&req).status, 200);
        assert_eq!(r.dispatch(&req).status, 200);
        // ...and the third authed request is shed with 429
        let shed = r.dispatch(&req);
        assert_eq!(shed.status, 429);
    }
}
