//! The REST API surface.
//!
//! `/api/v2` is a *declarative resource API*: the four resource kinds
//! (experiment, template, environment, model version) are described as
//! [`ResourceKind`] implementations — each ~40 lines of validation,
//! rendering, and lifecycle hooks — and registered through the generic
//! engine in [`super::resource`], which serves list/get/create/update/
//! patch/delete, `ETag`/`If-Match` optimistic concurrency, label
//! selectors, and `?watch=1` change streams for all of them from one
//! code path. Non-CRUD verbs (kill, events, metrics, tune, template
//! submit, cluster status) remain explicit routes, and `/api/v1` stays
//! a thin compat shim over the same managers.
//!
//! See `docs/API.md` for the full route table and protocol details.

use super::handler::{typed, Body, Ctx, Handler};
use super::middleware::{
    AuthMiddleware, LogMiddleware, MetricsMiddleware, RateLimitMiddleware,
};
use super::resource::{register_kind, Caps, FilterSpec, ResourceKind};
use super::router::{Envelope, Router};
use super::server::Services;
use crate::environment::Environment;
use crate::experiment::spec::ExperimentSpec;
use crate::model::Stage;
use crate::template::Template;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Server-side API configuration (threaded from the CLI flags).
#[derive(Debug, Clone, Default)]
pub struct ApiConfig {
    /// Bearer token required on every request when set.
    pub auth_token: Option<String>,
    /// Global token-bucket limit `(requests_per_sec, burst)` when set.
    pub rate_limit: Option<(f64, f64)>,
}

/// Build the full router: middleware chain + v1 compat + v2 routes.
pub fn build_api(services: Arc<Services>, cfg: &ApiConfig) -> Router {
    let mut r = Router::new();
    // Outermost first: log everything, measure everything (including
    // 401/429 rejections), then authenticate, then rate-limit. Auth
    // sits before the limiter so unauthenticated traffic cannot drain
    // the single global bucket and starve token-holding clients; the
    // auth check itself is a cheap string compare.
    r.add_middleware(Arc::new(LogMiddleware));
    r.add_middleware(Arc::new(MetricsMiddleware::new(Arc::clone(
        &services.metrics,
    ))));
    if let Some(token) = &cfg.auth_token {
        r.add_middleware(Arc::new(AuthMiddleware::new(token)));
    }
    if let Some((rate, burst)) = cfg.rate_limit {
        r.add_middleware(Arc::new(RateLimitMiddleware::new(rate, burst)));
    }
    register_routes(&mut r, services);
    r
}

/// Register one handler under both `/api/v1{tail}` and `/api/v2{tail}`.
fn both(r: &mut Router, method: &str, tail: &str, h: Arc<dyn Handler>) {
    r.route_shared(
        method,
        &format!("/api/v1{tail}"),
        Envelope::V1,
        Arc::clone(&h),
    );
    r.route_shared(method, &format!("/api/v2{tail}"), Envelope::V2, h);
}

fn experiment_item(id: &str, status: &str, doc: &Json) -> Json {
    let mut item = Json::obj()
        .set("experimentId", Json::Str(id.to_string()))
        .set("status", Json::Str(status.to_string()));
    let labels = crate::resource::labels_of(doc);
    if labels.as_obj().map(|o| !o.is_empty()).unwrap_or(false) {
        item = item.set("labels", labels);
    }
    let rv = crate::resource::resource_version(doc);
    if rv > 0 {
        item = item.set("resource_version", Json::Num(rv as f64));
    }
    item
}

/// Labels riding on a client payload: `meta.labels` (the doc shape) or
/// a top-level `labels` convenience field.
fn labels_in(body: &Json) -> Option<&Json> {
    body.at(&["meta", "labels"]).or_else(|| body.get("labels"))
}

// ---------------------------------------------------------------- kinds

/// Experiments: created through the manager (which submits to the
/// execution pipeline), spec replaceable, teardown kills containers.
struct ExperimentKind;

impl ResourceKind for ExperimentKind {
    fn kind(&self) -> &'static str {
        "experiment"
    }
    fn caps(&self) -> Caps {
        Caps {
            create: true,
            update: true,
            delete: true,
        }
    }
    fn filters(&self) -> &'static [FilterSpec] {
        static F: [FilterSpec; 1] = [FilterSpec {
            query: "status",
            index_field: "status",
        }];
        &F
    }
    fn create(&self, s: &Services, body: &Json) -> crate::Result<Json> {
        let spec = ExperimentSpec::from_json(body)?;
        let id = s.experiments.submit_labeled(&spec, labels_in(body))?;
        Ok(Json::obj().set("experimentId", Json::Str(id)))
    }
    fn render_row(&self, s: &Services, key: &str, doc: &Json) -> Json {
        let st = s.experiments.status_of_doc(key, doc);
        experiment_item(key, st.as_str(), doc)
    }
    fn render_doc(&self, s: &Services, key: &str, doc: Json) -> Json {
        let st = s.experiments.status_of_doc(key, &doc);
        doc.set("status", Json::Str(st.as_str().to_string()))
    }
    /// `render_doc` overlays the live monitor status, so experiment
    /// GETs cannot be served from the stored document's body cache.
    fn serves_cached_doc(&self) -> bool {
        false
    }
    fn apply_update(
        &self,
        _s: &Services,
        _key: &str,
        old: &Json,
        desired: &Json,
    ) -> crate::Result<Json> {
        // only the spec is client-mutable; id/status/submitter/
        // accepted_at are server-managed and carried over
        let spec_json = desired.get("spec").ok_or_else(|| {
            crate::SubmarineError::InvalidSpec(
                "experiment update needs a spec field".into(),
            )
        })?;
        let spec = ExperimentSpec::from_json(spec_json)?;
        Ok(old.clone().set("spec", spec.to_json()))
    }
    fn pre_delete(
        &self,
        s: &Services,
        key: &str,
        doc: &Json,
    ) -> crate::Result<()> {
        // stop containers first; the terminal status lands in the doc
        // (and the change feed) before the tombstone
        if !s.experiments.status_of_doc(key, doc).is_terminal() {
            s.experiments.kill(key)?;
        }
        Ok(())
    }
    fn delete_has_teardown(&self) -> bool {
        true
    }
}

/// Predefined templates (paper §3.2.3): register-once documents whose
/// content may be replaced wholesale.
struct TemplateKind;

impl ResourceKind for TemplateKind {
    fn kind(&self) -> &'static str {
        "template"
    }
    fn caps(&self) -> Caps {
        Caps {
            create: true,
            update: true,
            delete: true,
        }
    }
    fn create(&self, s: &Services, body: &Json) -> crate::Result<Json> {
        let t = Template::from_json(body)?;
        s.templates.register_labeled(&t, labels_in(body))?;
        Ok(Json::Bool(true))
    }
    fn render_row(&self, _s: &Services, key: &str, _doc: &Json) -> Json {
        Json::Str(key.to_string())
    }
    fn apply_update(
        &self,
        _s: &Services,
        key: &str,
        _old: &Json,
        desired: &Json,
    ) -> crate::Result<Json> {
        let t = Template::from_json(desired)?;
        if t.name != key {
            return Err(crate::SubmarineError::InvalidSpec(format!(
                "template name is immutable ({key} != {})",
                t.name
            )));
        }
        Ok(t.to_json())
    }
}

/// Environments (paper §3.2.1): the dependency lock is re-resolved when
/// the constraint set changes, so an update can never leave a stale
/// lock behind.
struct EnvironmentKind;

impl ResourceKind for EnvironmentKind {
    fn kind(&self) -> &'static str {
        "environment"
    }
    fn caps(&self) -> Caps {
        Caps {
            create: true,
            update: true,
            delete: true,
        }
    }
    fn create(&self, s: &Services, body: &Json) -> crate::Result<Json> {
        let env = Environment::from_json(body)?;
        s.environments.register_labeled(&env, labels_in(body))?;
        Ok(Json::Bool(true))
    }
    fn render_row(&self, _s: &Services, key: &str, _doc: &Json) -> Json {
        Json::Str(key.to_string())
    }
    fn apply_update(
        &self,
        s: &Services,
        key: &str,
        old: &Json,
        desired: &Json,
    ) -> crate::Result<Json> {
        let env = Environment::from_json(desired)?;
        if env.name != key {
            return Err(crate::SubmarineError::InvalidSpec(format!(
                "environment name is immutable ({key} != {})",
                env.name
            )));
        }
        let mut doc = env.to_json();
        let deps_changed =
            old.get("dependencies") != doc.get("dependencies");
        if deps_changed {
            let lock: Vec<Json> = s
                .environments
                .resolve_lock(&env)?
                .into_iter()
                .map(Json::Str)
                .collect();
            doc = doc.set("lock", Json::Arr(lock));
        } else {
            doc = doc.set(
                "lock",
                old.get("lock")
                    .cloned()
                    .unwrap_or_else(|| Json::Arr(Vec::new())),
            );
        }
        Ok(doc)
    }
}

/// Model versions (paper §4.2): registered by the training pipeline,
/// scoped under their model name, mutable only in stage (checked
/// transitions) and labels.
struct ModelKind;

impl ResourceKind for ModelKind {
    fn kind(&self) -> &'static str {
        "model"
    }
    fn scope_index(&self) -> Option<&'static str> {
        Some("name")
    }
    fn missing_scope_is_404(&self) -> bool {
        true
    }
    fn caps(&self) -> Caps {
        Caps {
            create: false,
            update: true,
            delete: false,
        }
    }
    fn filters(&self) -> &'static [FilterSpec] {
        static F: [FilterSpec; 1] = [FilterSpec {
            query: "stage",
            index_field: "stage",
        }];
        &F
    }
    fn item_key(&self, ctx: &Ctx<'_>) -> crate::Result<String> {
        let name = ctx.param("name")?;
        let version: u32 =
            ctx.param("version")?.parse().map_err(|_| {
                crate::SubmarineError::InvalidSpec(
                    "model version must be a number".into(),
                )
            })?;
        Ok(crate::model::ModelRegistry::doc_key(name, version))
    }
    fn display_name(&self, key: &str) -> String {
        crate::model::ModelRegistry::display_name(key)
    }
    fn render_row(&self, _s: &Services, _key: &str, doc: &Json) -> Json {
        model_version_json_from_doc(doc)
    }
    fn apply_update(
        &self,
        _s: &Services,
        _key: &str,
        old: &Json,
        desired: &Json,
    ) -> crate::Result<Json> {
        // only `stage` (checked transition) and labels are mutable
        let from = old
            .str_field("stage")
            .and_then(Stage::parse)
            .unwrap_or(Stage::None);
        let to = match desired.str_field("stage") {
            None => from,
            Some(raw) => Stage::parse(raw).ok_or_else(|| {
                crate::SubmarineError::InvalidSpec(format!(
                    "unknown stage {raw:?}"
                ))
            })?,
        };
        if to != from && !from.can_transition(to) {
            return Err(crate::SubmarineError::InvalidSpec(format!(
                "illegal stage transition {} -> {}",
                from.as_str(),
                to.as_str()
            )));
        }
        Ok(old
            .clone()
            .set("stage", Json::Str(to.as_str().to_string())))
    }
    fn post_update(
        &self,
        s: &Services,
        key: &str,
        doc: &Json,
    ) -> crate::Result<()> {
        // only one Production version per model; racing promotions
        // resolve to the one with the higher resource_version
        if doc.str_field("stage") == Some(Stage::Production.as_str()) {
            if let Some(name) = doc.str_field("name") {
                s.models.demote_other_production(
                    name,
                    key,
                    crate::resource::resource_version(doc),
                )?;
            }
        }
        // any stage change can alter what the serving tier should run
        // (promote = hot-swap, archive = unload); rebuild its route
        // snapshot. In-flight batches drain against the old snapshot.
        if let Some(name) = doc.str_field("name") {
            s.serving.refresh(name);
        }
        Ok(())
    }
}

fn kinds() -> Vec<Arc<dyn ResourceKind>> {
    vec![
        Arc::new(ExperimentKind),
        Arc::new(TemplateKind),
        Arc::new(EnvironmentKind),
        Arc::new(ModelKind),
    ]
}

// ---------------------------------------------------------------- routes

fn register_routes(r: &mut Router, s: Arc<Services>) {
    // ---- the declarative v2 resource surface -----------------------
    for kind in kinds() {
        register_kind(r, &s, &kind);
    }

    // ---- health / cluster status -----------------------------------
    {
        // health + (when the execution engine is attached) the live
        // cluster picture: nodes, utilization, queue shares, pending
        // jobs, unknown-queue warnings
        let s = Arc::clone(&s);
        both(
            r,
            "GET",
            "/cluster",
            Arc::new(typed(move |_: &Ctx<'_>, _: ()| {
                let mut out = Json::obj()
                    .set(
                        "version",
                        Json::Str(crate::version().into()),
                    )
                    .set("status", Json::Str("RUNNING".into()));
                if let Some(engine) = &s.executor {
                    let status = engine.cluster_status();
                    if let Some(fields) = status.as_obj() {
                        for (k, v) in fields {
                            out = out.set(k, v.clone());
                        }
                    }
                }
                Ok(out)
            })),
        );
    }

    // ---- experiment verbs beyond CRUD ------------------------------
    {
        let s = Arc::clone(&s);
        both(
            r,
            "POST",
            "/experiment/:name/kill",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                s.experiments.kill(ctx.param("name")?)?;
                Ok(true)
            })),
        );
    }
    {
        // Fig. 4's "records important events": the monitor's per-
        // experiment event log. Volatile — empty after a server restart
        // even though the terminal status survives in the doc.
        let s = Arc::clone(&s);
        both(
            r,
            "GET",
            "/experiment/:name/events",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                let id = ctx.param("name")?;
                s.experiments.get(id)?; // 404 for unknown ids
                Ok(s.monitor
                    .events(id)
                    .iter()
                    .map(|e| e.to_json())
                    .collect::<Vec<Json>>())
            })),
        );
    }
    {
        // AutoML entry point (paper §4.1): each trial is a real child
        // experiment submitted through the same pipeline.
        let s = Arc::clone(&s);
        both(
            r,
            "POST",
            "/experiment/tune",
            Arc::new(typed(move |_: &Ctx<'_>, body: Json| {
                let req = crate::automl::tune::parse_request(&body)?;
                run_tune_over_pipeline(&s, &req)
            })),
        );
    }
    {
        let s = Arc::clone(&s);
        both(
            r,
            "GET",
            "/experiment/:name/metrics",
            Arc::new(typed(move |ctx: &Ctx<'_>, _: ()| {
                let metric = ctx.query("metric").unwrap_or("loss");
                let series =
                    s.metrics.series(ctx.param("name")?, metric);
                Ok(series
                    .iter()
                    .map(|pt| {
                        Json::obj()
                            .set("step", Json::Num(pt.step as f64))
                            .set("value", Json::Num(pt.value))
                    })
                    .collect::<Vec<Json>>())
            })),
        );
    }
    {
        // "users can run experiments without writing one line of code":
        // POST { "params": {name: value} } -> submitted experiment.
        let s = Arc::clone(&s);
        both(
            r,
            "POST",
            "/template/:name/submit",
            // body is required JSON (seed behavior: empty body is 400);
            // `params` itself may be omitted for all-default templates
            Arc::new(typed(
                move |ctx: &Ctx<'_>, body: Json| {
                    let values: BTreeMap<String, String> = body
                        .get("params")
                        .and_then(Json::as_obj)
                        .map(|o| {
                            o.iter()
                                .map(|(k, v)| {
                                    (
                                        k.clone(),
                                        match v {
                                            Json::Str(s) => s.clone(),
                                            other => other.dump(),
                                        },
                                    )
                                })
                                .collect()
                        })
                        .unwrap_or_default();
                    let spec = s
                        .templates
                        .instantiate(ctx.param("name")?, &values)?;
                    let id = s.experiments.submit(&spec)?;
                    Ok(Json::obj().set("experimentId", Json::Str(id)))
                },
            )),
        );
    }

    // ---- online inference serving (ISSUE 9) ------------------------
    // v2-only: the serving tier speaks the v2 envelope and rides the
    // reactor's tail mechanism for micro-batching, so the predict
    // route bypasses the typed-handler layer entirely (a typed handler
    // must produce its Json before returning; a parked tail must not).
    {
        let s = Arc::clone(&s);
        r.route_raw(
            "POST",
            "/api/v2/serve/:model",
            Arc::new(move |ctx: &Ctx<'_>| s.serving.predict(ctx)),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v2/serve/:model",
            Envelope::V2,
            typed(move |ctx: &Ctx<'_>, _: ()| {
                s.serving.status(ctx.param("model")?)
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "PATCH",
            "/api/v2/serve/:model",
            Envelope::V2,
            typed(move |ctx: &Ctx<'_>, body: Json| {
                s.serving.patch_config(ctx.param("model")?, &body)
            }),
        );
    }

    // ---- /api/v1 compat shim ---------------------------------------
    register_v1_shim(r, s);
}

/// The seed-era `/api/v1` surface: bare arrays, flat envelopes, no
/// concurrency control. Kept as a thin layer over the same managers.
fn register_v1_shim(r: &mut Router, s: Arc<Services>) {
    {
        let s = Arc::clone(&s);
        r.route(
            "POST",
            "/api/v1/experiment",
            Envelope::V1,
            typed(move |_: &Ctx<'_>, Body(spec): Body<ExperimentSpec>| {
                let id = s.experiments.submit(&spec)?;
                Ok(Json::obj().set("experimentId", Json::Str(id)))
            }),
        );
    }
    {
        // v1 list: the seed's bare array.
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v1/experiment",
            Envelope::V1,
            typed(move |_: &Ctx<'_>, _: ()| {
                Ok(s.experiments
                    .list()
                    .into_iter()
                    .map(|(id, st)| {
                        Json::obj()
                            .set("experimentId", Json::Str(id))
                            .set(
                                "status",
                                Json::Str(st.as_str().to_string()),
                            )
                    })
                    .collect::<Vec<Json>>())
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v1/experiment/:name",
            Envelope::V1,
            typed(move |ctx: &Ctx<'_>, _: ()| {
                s.experiments.get(ctx.param("name")?)
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "DELETE",
            "/api/v1/experiment/:name",
            Envelope::V1,
            typed(move |ctx: &Ctx<'_>, _: ()| {
                let id = ctx.param("name")?;
                s.experiments.kill(id)?;
                s.experiments.delete(id)?;
                Ok(true)
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "POST",
            "/api/v1/template",
            Envelope::V1,
            typed(move |_: &Ctx<'_>, Body(t): Body<Template>| {
                s.templates.register(&t)?;
                Ok(true)
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v1/template",
            Envelope::V1,
            typed(move |_: &Ctx<'_>, _: ()| {
                Ok(s.templates
                    .list()
                    .into_iter()
                    .map(Json::Str)
                    .collect::<Vec<Json>>())
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v1/template/:name",
            Envelope::V1,
            typed(move |ctx: &Ctx<'_>, _: ()| {
                Ok(s.templates.get(ctx.param("name")?)?.to_json())
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "POST",
            "/api/v1/environment",
            Envelope::V1,
            typed(move |_: &Ctx<'_>, Body(env): Body<Environment>| {
                s.environments.register(&env)?;
                Ok(true)
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v1/environment",
            Envelope::V1,
            typed(move |_: &Ctx<'_>, _: ()| {
                Ok(s.environments
                    .list()
                    .into_iter()
                    .map(Json::Str)
                    .collect::<Vec<Json>>())
            }),
        );
    }
    {
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v1/environment/:name",
            Envelope::V1,
            typed(move |ctx: &Ctx<'_>, _: ()| {
                let name = ctx.param("name")?;
                let env = s.environments.get(name)?;
                let lock = s.environments.lock_of(name).unwrap_or_default();
                Ok(env.to_json().set(
                    "lock",
                    Json::Arr(
                        lock.into_iter().map(Json::Str).collect(),
                    ),
                ))
            }),
        );
    }
    {
        // v1 model: the seed's bare version array.
        let s = Arc::clone(&s);
        r.route(
            "GET",
            "/api/v1/model/:name",
            Envelope::V1,
            typed(move |ctx: &Ctx<'_>, _: ()| {
                let name = ctx.param("name")?;
                let (versions, total) =
                    s.models.versions_page(name, None, 0, None);
                if total == 0 {
                    return Err(crate::SubmarineError::NotFound(
                        format!("model {name}"),
                    ));
                }
                Ok(versions
                    .iter()
                    .map(model_version_json)
                    .collect::<Vec<Json>>())
            }),
        );
    }
}

/// Poll until `id` reaches a terminal status or `timeout_ms` passes; a
/// trial that overruns its budgeted wall time is killed so it frees its
/// queue share and containers.
fn wait_terminal(
    s: &Services,
    id: &str,
    timeout_ms: u64,
) -> crate::experiment::spec::ExperimentStatus {
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_millis(timeout_ms);
    loop {
        let st = s.experiments.status(id);
        if st.is_terminal() {
            return st;
        }
        if std::time::Instant::now() >= deadline {
            crate::warnlog!(
                "tune",
                "trial {id} timed out after {timeout_ms}ms; killing"
            );
            let _ = s.experiments.kill(id);
            return s.experiments.status(id);
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
}

/// Run a tune request where every trial is a child experiment submitted
/// through the manager → scheduler → cluster pipeline. Scores prefer a
/// real logged `loss` metric (negated; local-submitter trials train for
/// real); sim-pipeline trials fall back to the deterministic surrogate.
/// Trials that fail, are killed, or time out score `f64::MIN`.
fn run_tune_over_pipeline(
    s: &Arc<Services>,
    req: &crate::automl::tune::TuneRequest,
) -> crate::Result<Json> {
    use crate::automl::tune;
    // fail fast on an unknown template instead of 64 failed trials
    if let Some(name) = &req.template {
        s.templates.get(name)?;
    }
    let make_spec = |params: &BTreeMap<String, String>,
                     budget: u32|
     -> crate::Result<ExperimentSpec> {
        let mut spec = match (&req.template, &req.base_spec) {
            (Some(name), _) => s.templates.instantiate(name, params)?,
            (None, Some(base)) => {
                let filled =
                    crate::template::substitute(base, params)?;
                ExperimentSpec::from_json(&filled)?
            }
            (None, None) => {
                return Err(crate::SubmarineError::InvalidSpec(
                    "tune request lost its spec source".into(),
                ))
            }
        };
        // the rung budget rides on the child spec as workload steps, so
        // it is visible on the experiment doc (and drives real training
        // time under the local submitter)
        let mut w = spec.workload.clone().unwrap_or_default();
        w.steps = budget;
        spec.workload = Some(w);
        Ok(spec)
    };
    let run_trial = |params: &BTreeMap<String, String>,
                     budget: u32|
     -> tune::TrialRun {
        let submitted = make_spec(params, budget)
            .and_then(|spec| s.experiments.submit(&spec));
        match submitted {
            Ok(id) => {
                let st = wait_terminal(s, &id, req.trial_timeout_ms);
                let score = if st
                    == crate::experiment::spec::ExperimentStatus::Succeeded
                {
                    match s.metrics.last(&id, "loss") {
                        Some(p) => -p.value,
                        None => tune::surrogate_objective(
                            params, budget, req.seed,
                        ),
                    }
                } else {
                    f64::MIN
                };
                s.metrics.log(&id, "objective", budget as u64, score);
                tune::TrialRun {
                    experiment_id: id,
                    params: params.clone(),
                    score,
                    budget,
                    status: st.as_str().to_string(),
                }
            }
            Err(e) => tune::TrialRun {
                experiment_id: String::new(),
                params: params.clone(),
                score: f64::MIN,
                budget,
                status: format!("SubmitFailed: {e}"),
            },
        }
    };
    Ok(tune::run_tune(req, run_trial))
}

fn model_version_json(m: &crate::model::ModelVersion) -> Json {
    Json::obj()
        .set("version", Json::Num(m.version as f64))
        .set("stage", Json::Str(m.stage.as_str().into()))
        .set("experimentId", Json::Str(m.experiment_id.clone()))
}

/// The v2 list-row shape of a model-version document (the doc itself is
/// the source of truth; no re-materialization through the registry).
fn model_version_json_from_doc(doc: &Json) -> Json {
    let mut item = Json::obj()
        .set(
            "version",
            Json::Num(doc.num_field("version").unwrap_or(0.0)),
        )
        .set(
            "stage",
            Json::Str(doc.str_field("stage").unwrap_or("None").into()),
        )
        .set(
            "experimentId",
            Json::Str(
                doc.str_field("experiment_id").unwrap_or("").into(),
            ),
        );
    let labels = crate::resource::labels_of(doc);
    if labels.as_obj().map(|o| !o.is_empty()).unwrap_or(false) {
        item = item.set("labels", labels);
    }
    item
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::httpd::http::Request;
    use crate::orchestrator::Submitter;
    use crate::storage::MetaStore;

    struct NullSubmitter;
    impl Submitter for NullSubmitter {
        fn name(&self) -> &'static str {
            "null"
        }
        fn submit(
            &self,
            _: &str,
            _: &ExperimentSpec,
        ) -> crate::Result<()> {
            Ok(())
        }
        fn kill(&self, _: &str) -> crate::Result<()> {
            Ok(())
        }
    }

    fn services() -> Arc<Services> {
        Arc::new(Services::new(
            Arc::new(MetaStore::in_memory()),
            Arc::new(NullSubmitter),
        ))
    }

    fn api() -> Router {
        build_api(services(), &ApiConfig::default())
    }

    fn dispatch(
        router: &Router,
        method: &str,
        path: &str,
        body: &str,
    ) -> (u16, Json) {
        let mut req = Request::synthetic(method, path);
        req.body = body.as_bytes().to_vec();
        let resp = router.dispatch(&req);
        let j = Json::parse(
            std::str::from_utf8(&resp.body).unwrap_or("null"),
        )
        .unwrap_or(Json::Null);
        (resp.status, j)
    }

    const SPEC: &str = r#"{"meta":{"name":"mnist"},
        "spec":{"Worker":{"replicas":1,"resources":"cpu=1"}}}"#;

    #[test]
    fn experiment_crud_over_both_versions() {
        let r = api();
        for base in ["/api/v1", "/api/v2"] {
            let (st, j) =
                dispatch(&r, "POST", &format!("{base}/experiment"), SPEC);
            assert_eq!(st, 200, "{base}: {j:?}");
            let id = j
                .at(&["result", "experimentId"])
                .unwrap()
                .as_str()
                .unwrap()
                .to_string();
            let (st, j) = dispatch(
                &r,
                "GET",
                &format!("{base}/experiment/{id}"),
                "",
            );
            assert_eq!(st, 200);
            assert_eq!(
                j.at(&["result", "status"]).unwrap().as_str(),
                Some("Accepted")
            );
            let (st, _) = dispatch(
                &r,
                "POST",
                &format!("{base}/experiment/{id}/kill"),
                "",
            );
            assert_eq!(st, 200);
            let (st, j) = dispatch(
                &r,
                "DELETE",
                &format!("{base}/experiment/{id}"),
                "",
            );
            assert_eq!(st, 200, "{j:?}");
        }
    }

    #[test]
    fn v2_list_paginates_and_filters() {
        let r = api();
        for _ in 0..5 {
            let (st, _) =
                dispatch(&r, "POST", "/api/v2/experiment", SPEC);
            assert_eq!(st, 200);
        }
        let (st, j) = dispatch(
            &r,
            "GET",
            "/api/v2/experiment?limit=2&offset=1",
            "",
        );
        assert_eq!(st, 200);
        let result = j.get("result").unwrap();
        assert_eq!(result.num_field("total"), Some(5.0));
        assert_eq!(result.num_field("offset"), Some(1.0));
        assert_eq!(
            result.get("items").unwrap().as_arr().unwrap().len(),
            2
        );
        // lists carry the watch bookmark
        assert!(result.num_field("resource_version").is_some());
        // all seeds are Accepted: filtering by Running yields none
        let (st, j) = dispatch(
            &r,
            "GET",
            "/api/v2/experiment?status=Running",
            "",
        );
        assert_eq!(st, 200);
        assert_eq!(
            j.at(&["result", "total"]).and_then(Json::as_f64),
            Some(0.0)
        );
        let (st, j) = dispatch(
            &r,
            "GET",
            "/api/v2/experiment?status=accepted",
            "",
        );
        assert_eq!(st, 200, "{j:?}");
        assert_eq!(
            j.at(&["result", "total"]).and_then(Json::as_f64),
            Some(5.0)
        );
    }

    #[test]
    fn v1_list_stays_bare_array() {
        let r = api();
        let (st, _) = dispatch(&r, "POST", "/api/v1/experiment", SPEC);
        assert_eq!(st, 200);
        let (st, j) = dispatch(&r, "GET", "/api/v1/experiment", "");
        assert_eq!(st, 200);
        assert_eq!(
            j.get("result").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn bad_spec_is_400_with_v2_error_envelope() {
        let r = api();
        let (st, j) = dispatch(&r, "POST", "/api/v2/experiment", "{}");
        assert_eq!(st, 400);
        assert_eq!(j.str_field("status"), Some("ERROR"));
        assert_eq!(j.num_field("code"), Some(400.0));
        assert!(j.at(&["error", "message"]).is_some());
        let (st, _) =
            dispatch(&r, "POST", "/api/v2/experiment", "not json");
        assert_eq!(st, 400);
        // v1 keeps the flat shape
        let (st, j) = dispatch(&r, "POST", "/api/v1/experiment", "{}");
        assert_eq!(st, 400);
        assert!(j.str_field("message").is_some());
    }

    #[test]
    fn template_register_and_submit() {
        let r = api();
        let tpl = crate::template::tf_mnist_template().to_json().dump();
        let (st, _) = dispatch(&r, "POST", "/api/v2/template", &tpl);
        assert_eq!(st, 200);
        let (st, j) = dispatch(
            &r,
            "POST",
            "/api/v2/template/tf-mnist-template/submit",
            r#"{"params":{"learning_rate":"0.01","batch_size":"64"}}"#,
        );
        assert_eq!(st, 200, "{j:?}");
        assert!(j.at(&["result", "experimentId"]).is_some());
        // v1 shim sees the same registry
        let (st, j) = dispatch(&r, "GET", "/api/v1/template", "");
        assert_eq!(st, 200);
        assert_eq!(
            j.get("result").unwrap().as_arr().unwrap().len(),
            1
        );
        // duplicate registration is a 409 Conflict
        let (st, j) = dispatch(&r, "POST", "/api/v2/template", &tpl);
        assert_eq!(st, 409, "{j:?}");
        assert_eq!(
            j.at(&["error", "type"]).and_then(Json::as_str),
            Some("AlreadyExists")
        );
    }

    #[test]
    fn environment_register_and_lock() {
        let r = api();
        let (st, _) = dispatch(
            &r,
            "POST",
            "/api/v2/environment",
            r#"{"name":"tf","image":"submarine:tf",
                "dependencies":["tensorflow>=2.0"]}"#,
        );
        assert_eq!(st, 200);
        let (st, j) =
            dispatch(&r, "GET", "/api/v2/environment/tf", "");
        assert_eq!(st, 200);
        let lock = j.at(&["result", "lock"]).unwrap().as_arr().unwrap();
        assert!(!lock.is_empty());
        // documents carry the unified meta block
        assert!(j.at(&["result", "meta", "resource_version"]).is_some());
        assert_eq!(
            j.at(&["result", "meta", "name"]).and_then(Json::as_str),
            Some("tf")
        );
    }

    #[test]
    fn status_filter_rejected_where_unsupported() {
        let r = api();
        let (st, j) =
            dispatch(&r, "GET", "/api/v2/template?status=x", "");
        assert_eq!(st, 400, "{j:?}");
        let (st, _) =
            dispatch(&r, "GET", "/api/v2/environment?status=x", "");
        assert_eq!(st, 400);
    }

    #[test]
    fn missing_model_is_not_found() {
        let r = api();
        let (st, j) = dispatch(&r, "GET", "/api/v2/model/nope", "");
        assert_eq!(st, 404);
        assert_eq!(
            j.at(&["error", "type"]).and_then(Json::as_str),
            Some("NotFound")
        );
    }

    #[test]
    fn events_endpoint_serves_monitor_log() {
        let r = api();
        let (st, j) = dispatch(&r, "POST", "/api/v2/experiment", SPEC);
        assert_eq!(st, 200);
        let id = j
            .at(&["result", "experimentId"])
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        let (st, j) = dispatch(
            &r,
            "GET",
            &format!("/api/v2/experiment/{id}/events"),
            "",
        );
        assert_eq!(st, 200);
        let events = j.get("result").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        assert_eq!(
            events[0].at(&["event", "type"]).and_then(Json::as_str),
            Some("Accepted")
        );
        let (st, _) = dispatch(
            &r,
            "GET",
            "/api/v2/experiment/ghost/events",
            "",
        );
        assert_eq!(st, 404);
    }

    #[test]
    fn tune_validates_and_times_out_dead_submitters() {
        let r = api();
        // bad request: no template/spec source
        let (st, _) = dispatch(
            &r,
            "POST",
            "/api/v2/experiment/tune",
            r#"{"space":{"x":{"uniform":[0,1]}}}"#,
        );
        assert_eq!(st, 400);
        // unknown template is a 404 before any trial runs
        let (st, _) = dispatch(
            &r,
            "POST",
            "/api/v2/experiment/tune",
            r#"{"template":"nope",
                "space":{"x":{"uniform":[0,1]}}}"#,
        );
        assert_eq!(st, 404);
        // the NullSubmitter never progresses trials: they hit the
        // per-trial timeout, get killed, and score as failed
        let tpl = crate::template::tf_mnist_template().to_json().dump();
        let (st, _) = dispatch(&r, "POST", "/api/v2/template", &tpl);
        assert_eq!(st, 200);
        let (st, j) = dispatch(
            &r,
            "POST",
            "/api/v2/experiment/tune",
            r#"{"template":"tf-mnist-template","trials":2,
                "budget":10,"trial_timeout_ms":1,
                "space":{"learning_rate":
                    {"log_uniform":[0.0001,1.0]}}}"#,
        );
        assert_eq!(st, 200, "{j:?}");
        let trials =
            j.at(&["result", "trials"]).unwrap().as_arr().unwrap();
        assert_eq!(trials.len(), 2);
        assert!(trials
            .iter()
            .all(|t| t.str_field("status") == Some("Killed")));
    }

    #[test]
    fn http_metrics_recorded_per_route() {
        let s = services();
        let r = build_api(Arc::clone(&s), &ApiConfig::default());
        for _ in 0..4 {
            dispatch(&r, "GET", "/api/v2/cluster", "");
        }
        let series = s.metrics.series(
            crate::httpd::middleware::HTTP_METRICS_KEY,
            "GET /api/v2/cluster",
        );
        assert_eq!(series.len(), 4);
    }

    #[test]
    fn auth_and_rate_limit_configurable() {
        let cfg = ApiConfig {
            auth_token: Some("tok".into()),
            rate_limit: Some((0.000001, 2.0)),
        };
        let r = build_api(services(), &cfg);
        // no token: 401, and (auth running before the limiter) the
        // anon request must NOT consume rate budget
        let (st, _) = dispatch(&r, "GET", "/api/v2/cluster", "");
        assert_eq!(st, 401);
        let mut req = Request::synthetic("GET", "/api/v2/cluster");
        req.headers
            .insert("authorization".into(), "Bearer tok".into());
        // full burst of 2 available to the authed client...
        assert_eq!(r.dispatch(&req).status, 200);
        assert_eq!(r.dispatch(&req).status, 200);
        // ...and the third authed request is shed with 429
        let shed = r.dispatch(&req);
        assert_eq!(shed.status, 429);
    }

    #[test]
    fn created_docs_carry_meta_and_etag() {
        let r = api();
        let body = r#"{"meta":{"name":"mnist",
            "labels":{"team":"vision"}},
            "spec":{"Worker":{"replicas":1,"resources":"cpu=1"}}}"#;
        let (st, j) = dispatch(&r, "POST", "/api/v2/experiment", body);
        assert_eq!(st, 200, "{j:?}");
        let id = j
            .at(&["result", "experimentId"])
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        let mut req = Request::synthetic(
            "GET",
            &format!("/api/v2/experiment/{id}"),
        );
        req.body = Vec::new();
        let resp = r.dispatch(&req);
        assert_eq!(resp.status, 200);
        let etag = resp
            .headers
            .iter()
            .find(|(k, _)| k == "ETag")
            .map(|(_, v)| v.clone());
        assert!(etag.is_some(), "GET must carry an ETag");
        let j = Json::parse(
            std::str::from_utf8(&resp.body).unwrap(),
        )
        .unwrap();
        let meta = j.at(&["result", "meta"]).unwrap();
        assert_eq!(meta.str_field("name"), Some(id.as_str()));
        assert_eq!(
            meta.at(&["labels", "team"]).and_then(Json::as_str),
            Some("vision")
        );
        let rv = meta.num_field("resource_version").unwrap();
        assert_eq!(etag.unwrap(), format!("\"{rv}\""));
        // label selector list finds it
        let (st, j) = dispatch(
            &r,
            "GET",
            "/api/v2/experiment?label=team=vision",
            "",
        );
        assert_eq!(st, 200);
        assert_eq!(
            j.at(&["result", "total"]).and_then(Json::as_f64),
            Some(1.0)
        );
        let (st, j) = dispatch(
            &r,
            "GET",
            "/api/v2/experiment?label=team=nlp",
            "",
        );
        assert_eq!(st, 200, "{j:?}");
        assert_eq!(
            j.at(&["result", "total"]).and_then(Json::as_f64),
            Some(0.0)
        );
    }

    #[test]
    fn stale_if_match_put_is_412() {
        let r = api();
        let (st, j) = dispatch(&r, "POST", "/api/v2/experiment", SPEC);
        assert_eq!(st, 200);
        let id = j
            .at(&["result", "experimentId"])
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        let (_, j) = dispatch(
            &r,
            "GET",
            &format!("/api/v2/experiment/{id}"),
            "",
        );
        let rv = j
            .at(&["result", "meta", "resource_version"])
            .and_then(Json::as_u64)
            .unwrap();
        let put_body = format!(
            r#"{{"spec":{{"meta":{{"name":"mnist"}},
                "spec":{{"Worker":{{"replicas":2,"resources":"cpu=2"}}}}}}}}"#
        );
        let put = |if_match: Option<String>| -> (u16, Json) {
            let mut req = Request::synthetic(
                "PUT",
                &format!("/api/v2/experiment/{id}"),
            );
            req.body = put_body.as_bytes().to_vec();
            if let Some(m) = if_match {
                req.headers.insert("if-match".into(), m);
            }
            let resp = r.dispatch(&req);
            let j = Json::parse(
                std::str::from_utf8(&resp.body).unwrap_or("null"),
            )
            .unwrap_or(Json::Null);
            (resp.status, j)
        };
        // fresh If-Match wins and bumps the version + generation
        let (st, j) = put(Some(format!("\"{rv}\"")));
        assert_eq!(st, 200, "{j:?}");
        let new_rv = j
            .at(&["result", "meta", "resource_version"])
            .and_then(Json::as_u64)
            .unwrap();
        assert!(new_rv > rv);
        assert_eq!(
            j.at(&["result", "meta", "generation"])
                .and_then(Json::as_u64),
            Some(2)
        );
        // the old version is now stale: 412 with the typed error
        let (st, j) = put(Some(format!("\"{rv}\"")));
        assert_eq!(st, 412, "{j:?}");
        assert_eq!(
            j.at(&["error", "type"]).and_then(Json::as_str),
            Some("PreconditionFailed")
        );
        // If-Match: * only requires existence
        let (st, _) = put(Some("*".into()));
        assert_eq!(st, 200);
        // garbage If-Match is a 400, not a silent overwrite
        let (st, _) = put(Some("not-a-rev".into()));
        assert_eq!(st, 400);
    }

    #[test]
    fn patch_merges_labels() {
        let r = api();
        let (st, j) = dispatch(&r, "POST", "/api/v2/experiment", SPEC);
        assert_eq!(st, 200);
        let id = j
            .at(&["result", "experimentId"])
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        let (st, j) = dispatch(
            &r,
            "PATCH",
            &format!("/api/v2/experiment/{id}"),
            r#"{"meta":{"labels":{"team":"vision","tier":"dev"}}}"#,
        );
        assert_eq!(st, 200, "{j:?}");
        assert_eq!(
            j.at(&["result", "meta", "labels", "team"])
                .and_then(Json::as_str),
            Some("vision")
        );
        // labels-only patch must NOT bump generation
        assert_eq!(
            j.at(&["result", "meta", "generation"])
                .and_then(Json::as_u64),
            Some(1)
        );
        // merge-patch null removes one label, keeps the other
        let (st, j) = dispatch(
            &r,
            "PATCH",
            &format!("/api/v2/experiment/{id}"),
            r#"{"meta":{"labels":{"tier":null}}}"#,
        );
        assert_eq!(st, 200, "{j:?}");
        let labels = j.at(&["result", "meta", "labels"]).unwrap();
        assert_eq!(labels.str_field("team"), Some("vision"));
        assert!(labels.get("tier").is_none());
    }

    #[test]
    fn model_versions_served_generically() {
        let s = services();
        let r = build_api(Arc::clone(&s), &ApiConfig::default());
        let params = vec![vec![1.0f32]];
        let v1 = s.models.register("ctr", "e-1", &params, &[]).unwrap();
        let v2 = s.models.register("ctr", "e-2", &params, &[]).unwrap();
        let (st, j) = dispatch(&r, "GET", "/api/v2/model/ctr", "");
        assert_eq!(st, 200, "{j:?}");
        assert_eq!(
            j.at(&["result", "total"]).and_then(Json::as_f64),
            Some(2.0)
        );
        // single version GET with meta
        let (st, j) = dispatch(
            &r,
            "GET",
            &format!("/api/v2/model/ctr/{v1}"),
            "",
        );
        assert_eq!(st, 200, "{j:?}");
        assert!(j.at(&["result", "meta", "resource_version"]).is_some());
        // stage transition via PUT: None -> Staging -> Production
        for (v, stage) in
            [(v1, "Staging"), (v1, "Production"), (v2, "Staging")]
        {
            let (st, j) = dispatch(
                &r,
                "PUT",
                &format!("/api/v2/model/ctr/{v}"),
                &format!(r#"{{"stage":"{stage}"}}"#),
            );
            assert_eq!(st, 200, "{stage}: {j:?}");
        }
        // illegal transition rejected
        let (st, _) = dispatch(
            &r,
            "PUT",
            &format!("/api/v2/model/ctr/{v2}"),
            r#"{"stage":"Archived"}"#,
        );
        assert_eq!(st, 200); // Staging -> Archived is legal
        let (st, _) = dispatch(
            &r,
            "PUT",
            &format!("/api/v2/model/ctr/{v2}"),
            r#"{"stage":"Production"}"#,
        );
        assert_eq!(st, 400); // Archived -> Production is not
        // stage filter still walks the index
        let (st, j) = dispatch(
            &r,
            "GET",
            "/api/v2/model/ctr?stage=production",
            "",
        );
        assert_eq!(st, 200);
        assert_eq!(
            j.at(&["result", "total"]).and_then(Json::as_f64),
            Some(1.0)
        );
    }
}
