//! Epoll readiness reactor (ISSUE 7 tentpole).
//!
//! One reactor thread owns every connection: a nonblocking listener
//! plus each accepted socket are registered with a raw epoll instance
//! (`epoll_create1`/`epoll_ctl`/`epoll_wait` via direct FFI — the
//! project keeps its zero-dependency property, so there is no libc or
//! mio here). Readiness events drive the per-connection state machine
//! in [`super::conn`]; complete requests are handed to a small worker
//! pool over [`JobQueue`], responses come back over [`DoneQueue`] with
//! an eventfd nudge, and watch/stream responses park as cheap
//! [`TailState`] entries stepped by feed wakeups — 10k concurrent
//! watchers cost 10k sockets and buffers, not 10k threads.
//!
//! Wakeup paths into the epoll wait:
//! - socket readiness (the normal request/response flow),
//! - the eventfd, written by workers on completion and by the feed
//!   pump when the store publishes a revision (parked watch tails get
//!   stepped),
//! - a 25ms sweep tick for idle reaping, mid-request 408s, and tail
//!   deadlines.
//!
//! The only dedicated-thread escape hatch left is the long synchronous
//! `POST .../experiment/tune` handler (minutes of wall time that must
//! not pin a pool worker), plus a safety hatch for legacy
//! `Response::stream` producers, which own their socket until done.

use super::conn::{
    Conn, ConnState, ParseOutcome, ReadOutcome, WriteOutcome,
    MAX_HEADER_BYTES,
};
use super::http::{Request, Response, TailSource, TailStep};
use super::router::{envelope_of_path, error_json, Router};
use super::server::{
    shed_connection, ConnGuard, MAX_KEEPALIVE_REQUESTS,
};
use crate::analysis::lock_order::LockRank;
use crate::analysis::tracker;
use crate::storage::{MetaStore, MetricStore};
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::{
    AtomicBool, AtomicU64, AtomicUsize, Ordering,
};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

// ------------------------------------------------------- raw syscalls

/// Minimal FFI surface for the reactor. Declared privately instead of
/// pulling in libc: these signatures are the stable Linux kernel ABI.
mod sys {
    /// `struct epoll_event`. x86_64 declares it packed; other Linux
    /// targets use natural alignment.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLL_CLOEXEC: i32 = 0x80000;
    pub const EFD_NONBLOCK: i32 = 0x800;
    pub const RLIMIT_NOFILE: i32 = 7;
    pub const SOL_SOCKET: i32 = 1;
    pub const SO_RCVBUF: i32 = 8;

    #[repr(C)]
    pub struct Rlimit {
        pub rlim_cur: u64,
        pub rlim_max: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(
            epfd: i32,
            op: i32,
            fd: i32,
            event: *mut EpollEvent,
        ) -> i32;
        pub fn epoll_wait(
            epfd: i32,
            events: *mut EpollEvent,
            maxevents: i32,
            timeout_ms: i32,
        ) -> i32;
        pub fn eventfd(initval: u32, flags: i32) -> i32;
        pub fn close(fd: i32) -> i32;
        pub fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        pub fn write(fd: i32, buf: *const u8, count: usize) -> isize;
        pub fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
        pub fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
        pub fn setsockopt(
            fd: i32,
            level: i32,
            optname: i32,
            optval: *const u8,
            optlen: u32,
        ) -> i32;
    }
}

/// Raise the process `RLIMIT_NOFILE` soft limit toward `want` (capped
/// by the hard limit) and return the resulting soft limit. The 10k+
/// watcher fan-out test calls this before opening its sockets.
pub fn raise_nofile_limit(want: u64) -> u64 {
    let mut rl = sys::Rlimit {
        rlim_cur: 0,
        rlim_max: 0,
    };
    // SAFETY: `rl` is a live, properly-aligned `Rlimit` whose #[repr(C)]
    // layout matches the kernel's struct rlimit (two u64s); the kernel
    // writes at most that many bytes. Return value is checked.
    if unsafe { sys::getrlimit(sys::RLIMIT_NOFILE, &mut rl) } != 0 {
        return 1024;
    }
    if rl.rlim_cur >= want {
        return rl.rlim_cur;
    }
    let target = want.min(rl.rlim_max);
    let bumped = sys::Rlimit {
        rlim_cur: target,
        rlim_max: rl.rlim_max,
    };
    // SAFETY: `bumped` is a valid #[repr(C)] Rlimit read (not written)
    // by the kernel; rlim_cur <= rlim_max holds by construction above.
    // Return value is checked — on failure the old limit is reported.
    if unsafe { sys::setrlimit(sys::RLIMIT_NOFILE, &bumped) } == 0 {
        target
    } else {
        rl.rlim_cur
    }
}

/// Shrink a socket's kernel receive buffer (`SO_RCVBUF`). Tests use it
/// to force mid-response `EAGAIN` on the server's write path with a
/// realistically small amount of data.
pub fn set_recv_buffer(stream: &TcpStream, bytes: usize) {
    let v = bytes as i32;
    // SAFETY: the fd is live for the duration of the call (borrowed
    // from `stream`); `optval` points at a stack i32 and `optlen` is
    // exactly its 4-byte size. Best-effort test knob — the contract
    // registry marks setsockopt as not-must-check, so the discarded
    // return is deliberate.
    let _ = unsafe {
        sys::setsockopt(
            stream.as_raw_fd(),
            sys::SOL_SOCKET,
            sys::SO_RCVBUF,
            (&v as *const i32).cast(),
            4,
        )
    };
}

/// Owned epoll instance.
struct Epoll {
    fd: RawFd,
}

impl Epoll {
    fn new() -> std::io::Result<Epoll> {
        // SAFETY: no pointers cross the boundary; the returned fd is
        // checked and, when valid, owned by the new Epoll until Drop
        // closes it.
        let fd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(Epoll { fd })
    }

    fn ctl(
        &self,
        op: i32,
        fd: RawFd,
        events: u32,
        token: u64,
    ) -> std::io::Result<()> {
        let mut ev = sys::EpollEvent {
            events,
            data: token,
        };
        // SAFETY: `self.fd` is the epoll fd this struct owns; `ev` is
        // a live EpollEvent whose repr matches the kernel ABI (packed
        // on x86_64), only read by the kernel. Return value checked.
        let rc = unsafe { sys::epoll_ctl(self.fd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(())
    }

    fn add(
        &self,
        fd: RawFd,
        events: u32,
        token: u64,
    ) -> std::io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, events, token)
    }

    fn modify(
        &self,
        fd: RawFd,
        events: u32,
        token: u64,
    ) -> std::io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, events, token)
    }

    fn del(&self, fd: RawFd) -> std::io::Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait for readiness events. `EINTR` (and any other error) is
    /// reported as zero events — the caller's loop just re-enters.
    fn wait(
        &self,
        events: &mut [sys::EpollEvent],
        timeout_ms: i32,
    ) -> usize {
        // SAFETY: `events` is a live mutable slice of ABI-compatible
        // EpollEvent structs and `maxevents` is exactly its length, so
        // the kernel never writes past it. `self.fd` is owned by this
        // struct. rc is checked: negative (EINTR included) maps to
        // zero events and the caller's loop re-enters the wait.
        let rc = unsafe {
            sys::epoll_wait(
                self.fd,
                events.as_mut_ptr(),
                events.len() as i32,
                timeout_ms,
            )
        };
        if rc < 0 {
            0
        } else {
            rc as usize
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        // SAFETY: `self.fd` was returned by epoll_create1 and is
        // closed exactly once, here. close is fire-and-forget: POSIX
        // leaves the fd state unspecified after EINTR, so retrying
        // could close an fd another thread just received.
        unsafe {
            sys::close(self.fd);
        }
    }
}

/// Nonblocking eventfd: the reactor's cross-thread doorbell. Workers
/// and the feed pump `wake` it; the reactor `drain`s it on readiness.
struct EventFd {
    fd: RawFd,
    /// Persistent `wake` failures (anything but success / EINTR /
    /// EAGAIN). A lost doorbell write stalls completions, so the
    /// reactor sweep publishes this into the metrics store instead of
    /// letting the signal vanish silently.
    failures: AtomicU64,
}

impl EventFd {
    fn new() -> std::io::Result<EventFd> {
        // SAFETY: no pointers cross the boundary; the returned fd is
        // checked and, when valid, owned by the new EventFd until
        // Drop closes it.
        let fd = unsafe { sys::eventfd(0, sys::EFD_NONBLOCK) };
        if fd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(EventFd {
            fd,
            failures: AtomicU64::new(0),
        })
    }

    fn raw(&self) -> RawFd {
        self.fd
    }

    /// Ring the doorbell. `EINTR` is retried; `EAGAIN` means the
    /// 64-bit counter is saturated, i.e. a wakeup is already pending,
    /// so the signal cannot be lost. Any other failure is counted for
    /// the sweep to publish.
    fn wake(&self) {
        let one: u64 = 1;
        loop {
            // SAFETY: `self.fd` is a live eventfd owned by this struct
            // until Drop; the buffer is a stack u64 valid for exactly
            // the 8 bytes the kernel reads. Return value is checked
            // below (short writes cannot happen on an eventfd: the
            // kernel accepts exactly 8 bytes or fails).
            let rc = unsafe {
                sys::write(self.fd, (&one as *const u64).cast(), 8)
            };
            if rc == 8 {
                return;
            }
            match std::io::Error::last_os_error().kind() {
                std::io::ErrorKind::Interrupted => continue,
                // counter saturated — a wakeup is already pending
                std::io::ErrorKind::WouldBlock => return,
                _ => {
                    self.failures.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            }
        }
    }

    fn drain(&self) {
        let mut buf = [0u8; 8];
        // SAFETY: `self.fd` is the owned eventfd; `buf` is a live
        // 8-byte stack buffer matching `count`. The return value is
        // the loop condition: the eventfd is level-drained until it
        // reports anything but a full 8-byte counter read (EAGAIN on
        // empty; EINTR just means this wake is picked up by the next
        // readiness event — the counter still holds the value).
        while unsafe { sys::read(self.fd, buf.as_mut_ptr(), 8) } == 8 {}
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        // SAFETY: `self.fd` was returned by eventfd and is closed
        // exactly once, here. Fire-and-forget for the same POSIX
        // EINTR reason as `Epoll`'s Drop.
        unsafe {
            sys::close(self.fd);
        }
    }
}

// --------------------------------------------------- reactor <-> pool

/// A parsed request in flight to the worker pool.
struct Job {
    token: u64,
    req: Box<Request>,
}

/// Reactor → workers hand-off. Same rank as the old connection queue
/// it replaces ([`LockRank::ConnQueue`]).
struct JobQueue {
    q: Mutex<VecDeque<Job>>,
    cv: Condvar,
    closed: AtomicBool,
}

impl JobQueue {
    fn new() -> JobQueue {
        JobQueue {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
        }
    }

    fn push(&self, job: Job) {
        let _held = tracker::acquired(LockRank::ConnQueue, 0);
        let mut q = self.q.lock().unwrap_or_else(|e| e.into_inner());
        q.push_back(job);
        drop(q);
        self.cv.notify_one();
    }

    fn pop(&self) -> Option<Job> {
        let _held = tracker::acquired(LockRank::ConnQueue, 0);
        let mut q = self.q.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(j) = q.pop_front() {
                return Some(j);
            }
            if self.closed.load(Ordering::Acquire) {
                return None;
            }
            q = self.cv.wait(q).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.cv.notify_all();
    }
}

/// One finished handler invocation.
struct Done {
    token: u64,
    resp: Box<Response>,
    /// The request asked to keep the connection alive.
    keep: bool,
    /// The request was `HEAD` — suppress the body.
    head: bool,
}

/// Workers → reactor completion queue ([`LockRank::ReactorDone`]).
struct DoneQueue {
    completions: Mutex<Vec<Done>>,
}

impl DoneQueue {
    fn new() -> DoneQueue {
        DoneQueue {
            completions: Mutex::new(Vec::new()),
        }
    }

    fn push(&self, d: Done) {
        let _held = tracker::acquired(LockRank::ReactorDone, 0);
        let mut completions = self
            .completions
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        completions.push(d);
    }

    /// Swap the accumulated completions into `into` (which must be
    /// empty) without holding the lock while they are processed.
    fn drain(&self, into: &mut Vec<Done>) {
        let _held = tracker::acquired(LockRank::ReactorDone, 0);
        let mut completions = self
            .completions
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        std::mem::swap(into, &mut *completions);
    }
}

fn worker_loop(
    jobs: &Arc<JobQueue>,
    done: &Arc<DoneQueue>,
    router: &Arc<Router>,
    wake: &Arc<EventFd>,
) {
    while let Some(job) = jobs.pop() {
        let head = job.req.method.eq_ignore_ascii_case("HEAD");
        let keep = job.req.wants_keep_alive();
        let resp = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| router.dispatch(&job.req)),
        )
        .unwrap_or_else(|_| {
            Response::error(500, "handler panicked")
        });
        done.push(Done {
            token: job.token,
            resp: Box::new(resp),
            keep,
            head,
        });
        wake.wake();
    }
}

/// Wakes the reactor whenever the store publishes a revision, so
/// parked watch tails are stepped promptly without one blocked thread
/// per watcher.
fn feed_pump(
    store: &Arc<MetaStore>,
    flag: &Arc<AtomicBool>,
    wake: &Arc<EventFd>,
    stop: &Arc<AtomicBool>,
) {
    let mut last = store.current_rev();
    while !stop.load(Ordering::Acquire) {
        let rev =
            store.wait_rev_above(last, Duration::from_millis(250));
        if rev > last {
            last = rev;
            flag.store(true, Ordering::Release);
            wake.wake();
        }
    }
}

// ------------------------------------------------------------ reactor

/// Token values reserved for non-connection fds.
const TOKEN_LISTENER: u64 = u64::MAX;
const TOKEN_WAKE: u64 = u64::MAX - 1;

/// Sweep cadence: idle reaping, mid-request 408s, tail deadlines.
const SWEEP_MS: i32 = 25;

/// A parked watch/stream tail.
struct TailState {
    source: Box<dyn TailSource>,
    chunked: bool,
    head: bool,
    /// The originating request's keep-alive wish (long polls resume
    /// keep-alive after resolving).
    keep: bool,
    /// Chunked tail has queued its terminal bytes; close once drained.
    finished: bool,
}

/// Slab entry: connection + generation (stale-token insurance) + any
/// parked tail. Dropping the slot closes the socket and releases the
/// live-connection count via `_guard`.
struct Slot {
    conn: Conn,
    gen: u32,
    tail: Option<TailState>,
    _guard: ConnGuard,
}

fn token_of(gen: u32, idx: usize) -> u64 {
    ((gen as u64) << 32) | idx as u64
}

pub(crate) struct Reactor {
    epoll: Epoll,
    wake: Arc<EventFd>,
    listener: TcpListener,
    router: Arc<Router>,
    store: Arc<MetaStore>,
    jobs: Arc<JobQueue>,
    done: Arc<DoneQueue>,
    feed_flag: Arc<AtomicBool>,
    slots: Vec<Option<Slot>>,
    free: Vec<usize>,
    live: usize,
    next_gen: u32,
    active: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
    workers: usize,
    max_connections: usize,
    idle_timeout: Duration,
    wbuf_cap: usize,
    done_batch: Vec<Done>,
    metrics: Arc<MetricStore>,
    /// Doorbell failures already published to `metrics`; the sweep
    /// only logs when the counter moves past this watermark.
    wake_failures_seen: u64,
}

/// Deferred per-slot decision computed under an immutable borrow.
enum SweepAction {
    Close,
    Timeout408,
    StepTail,
}

impl Reactor {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        listener: TcpListener,
        router: Arc<Router>,
        store: Arc<MetaStore>,
        metrics: Arc<MetricStore>,
        serving: Arc<crate::serving::ServingLayer>,
        active: Arc<AtomicUsize>,
        stop: Arc<AtomicBool>,
        workers: usize,
        max_connections: usize,
        idle_timeout: Duration,
        wbuf_cap: usize,
    ) -> std::io::Result<Reactor> {
        let epoll = Epoll::new()?;
        let wake = Arc::new(EventFd::new()?);
        listener.set_nonblocking(true)?;
        epoll.add(
            listener.as_raw_fd(),
            sys::EPOLLIN,
            TOKEN_LISTENER,
        )?;
        epoll.add(wake.raw(), sys::EPOLLIN, TOKEN_WAKE)?;
        let feed_flag = Arc::new(AtomicBool::new(false));
        // Serving doorbell: a batch fan-out behaves like a feed
        // publish — set the step-tails flag and ring the eventfd so
        // freshly filled predict slots are stepped on this wakeup, not
        // at the next 25ms sweep.
        {
            let flag = Arc::clone(&feed_flag);
            let bell = Arc::clone(&wake);
            serving.set_waker(Arc::new(move || {
                flag.store(true, Ordering::Release);
                bell.wake();
            }));
        }
        Ok(Reactor {
            epoll,
            wake,
            listener,
            router,
            store,
            jobs: Arc::new(JobQueue::new()),
            done: Arc::new(DoneQueue::new()),
            feed_flag,
            slots: Vec::new(),
            free: Vec::new(),
            live: 0,
            next_gen: 0,
            active,
            stop,
            workers,
            max_connections,
            idle_timeout,
            wbuf_cap,
            done_batch: Vec::new(),
            metrics,
            wake_failures_seen: 0,
        })
    }

    /// Run the event loop until the stop flag is set (and a dummy
    /// connection or any event wakes the wait).
    pub(crate) fn run(mut self) -> crate::Result<()> {
        let mut pool = Vec::with_capacity(self.workers);
        for i in 0..self.workers {
            let jobs = Arc::clone(&self.jobs);
            let done = Arc::clone(&self.done);
            let router = Arc::clone(&self.router);
            let wake = Arc::clone(&self.wake);
            let spawned = std::thread::Builder::new()
                .name(format!("submarine-worker-{i}"))
                .spawn(move || {
                    worker_loop(&jobs, &done, &router, &wake)
                });
            match spawned {
                Ok(h) => pool.push(h),
                Err(e) => {
                    self.jobs.close();
                    for h in pool {
                        let _ = h.join();
                    }
                    return Err(crate::SubmarineError::Runtime(
                        format!("spawning request worker {i}: {e}"),
                    ));
                }
            }
        }
        let pump = {
            let store = Arc::clone(&self.store);
            let flag = Arc::clone(&self.feed_flag);
            let wake = Arc::clone(&self.wake);
            let stop = Arc::clone(&self.stop);
            std::thread::Builder::new()
                .name("submarine-feed-pump".into())
                .spawn(move || feed_pump(&store, &flag, &wake, &stop))
        };
        let mut events =
            vec![sys::EpollEvent { events: 0, data: 0 }; 1024];
        let mut last_sweep = Instant::now();
        loop {
            if self.stop.load(Ordering::Acquire) {
                break;
            }
            let timeout = if self.live > 0 { SWEEP_MS } else { 250 };
            let n = self.epoll.wait(&mut events, timeout);
            if self.stop.load(Ordering::Acquire) {
                break;
            }
            let now = Instant::now();
            self.dispatch_events(&events[..n], now);
            self.drain_completions(now);
            if self.feed_flag.swap(false, Ordering::AcqRel) {
                self.step_tails(now);
            }
            if now.duration_since(last_sweep)
                >= Duration::from_millis(SWEEP_MS as u64)
            {
                last_sweep = now;
                self.sweep(now);
            }
        }
        self.jobs.close();
        for h in pool {
            let _ = h.join();
        }
        if let Ok(h) = pump {
            let _ = h.join();
        }
        Ok(())
    }

    // ------------------------------------------------ event dispatch

    /// Fan readiness events out to their owners. Hot: runs once per
    /// wakeup over the whole batch.
    fn dispatch_events(
        &mut self,
        events: &[sys::EpollEvent],
        now: Instant,
    ) {
        for ev in events {
            let token = ev.data;
            let bits = ev.events;
            if token == TOKEN_LISTENER {
                self.accept_ready(now);
            } else if token == TOKEN_WAKE {
                self.wake.drain();
            } else {
                self.conn_event(token, bits, now);
            }
        }
    }

    fn conn_event(&mut self, token: u64, bits: u32, now: Instant) {
        let idx = (token & 0xffff_ffff) as usize;
        let gen = (token >> 32) as u32;
        match self.slots.get(idx).and_then(|s| s.as_ref()) {
            Some(slot) if slot.gen == gen => {}
            _ => return, // stale event for a recycled slot
        }
        if bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0 {
            self.close_conn(idx);
            return;
        }
        if bits & sys::EPOLLOUT != 0 && self.on_writable(idx, now) {
            self.close_conn(idx);
            return;
        }
        if bits & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0
            && self.on_readable(idx, now)
        {
            self.close_conn(idx);
            return;
        }
        self.rearm(idx);
    }

    fn accept_ready(&mut self, now: Instant) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => self.admit(stream, now),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    return;
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    crate::warnlog!("httpd", "accept error: {e}");
                    return;
                }
            }
        }
    }

    fn admit(&mut self, stream: TcpStream, now: Instant) {
        if self.active.load(Ordering::Relaxed) >= self.max_connections
        {
            // Shed instead of queueing: a prompt 503 beats an
            // unbounded backlog. The lingering close runs on its own
            // short-lived thread so a slow peer cannot stall the
            // reactor at exactly the moment the server is overloaded.
            let _ = std::thread::Builder::new()
                .name("submarine-shed".into())
                .spawn(move || shed_connection(stream));
            return;
        }
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let _ = stream.set_nodelay(true);
        self.active.fetch_add(1, Ordering::Relaxed);
        let guard = ConnGuard {
            active: Arc::clone(&self.active),
        };
        let mut conn = Conn::new(stream, now);
        conn.interest = sys::EPOLLIN | sys::EPOLLRDHUP;
        let fd = conn.stream.as_raw_fd();
        let (idx, token) = self.alloc_slot(conn, guard);
        let interest = sys::EPOLLIN | sys::EPOLLRDHUP;
        if self.epoll.add(fd, interest, token).is_err() {
            self.remove_slot(idx);
        }
    }

    fn alloc_slot(
        &mut self,
        conn: Conn,
        guard: ConnGuard,
    ) -> (usize, u64) {
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                self.slots.len() - 1
            }
        };
        self.next_gen = self.next_gen.wrapping_add(1);
        let gen = self.next_gen;
        self.slots[idx] = Some(Slot {
            conn,
            gen,
            tail: None,
            _guard: guard,
        });
        self.live += 1;
        (idx, token_of(gen, idx))
    }

    /// Drop a slot without touching epoll (used when registration
    /// itself failed, and by the migration paths after `del`).
    fn remove_slot(&mut self, idx: usize) -> Option<Slot> {
        let slot = self.slots.get_mut(idx).and_then(|s| s.take())?;
        self.free.push(idx);
        self.live -= 1;
        Some(slot)
    }

    fn close_conn(&mut self, idx: usize) {
        if let Some(slot) = self.remove_slot(idx) {
            let _ =
                self.epoll.del(slot.conn.stream.as_raw_fd());
            // socket closes when the slot drops; the guard releases
            // the live-connection count
        }
    }

    // ---------------------------------------------------- readiness

    fn on_readable(&mut self, idx: usize, now: Instant) -> bool {
        let mut saw_eof = false;
        loop {
            let Some(slot) =
                self.slots.get_mut(idx).and_then(|s| s.as_mut())
            else {
                return false;
            };
            // bound buffering while a request is already in flight
            if slot.conn.state == ConnState::Handle
                && slot.conn.rbuf.len() - slot.conn.rpos
                    > MAX_HEADER_BYTES
            {
                break;
            }
            match slot.conn.read_some() {
                ReadOutcome::Progress => {
                    if slot.conn.state == ConnState::Tail {
                        // watch clients have nothing more to say;
                        // discard so a chatty peer can't grow rbuf
                        slot.conn.rbuf.clear();
                        slot.conn.rpos = 0;
                    }
                }
                ReadOutcome::WouldBlock => break,
                ReadOutcome::Eof => {
                    saw_eof = true;
                    break;
                }
                ReadOutcome::Err => return true,
            }
        }
        let Some(slot) =
            self.slots.get_mut(idx).and_then(|s| s.as_mut())
        else {
            return false;
        };
        let state = slot.conn.state;
        if saw_eof {
            slot.conn.eof = true;
            slot.conn.keep = false;
            if state == ConnState::Tail {
                return true; // peer gone; unpark and drop
            }
        }
        match state {
            ConnState::ReadHeaders
            | ConnState::ReadBody
            | ConnState::KeepAliveIdle => {
                self.pump_requests(idx, now)
            }
            ConnState::Handle
            | ConnState::WriteResponse
            | ConnState::Tail => false,
        }
    }

    /// Try to parse and dispatch the next buffered request. Returns
    /// `true` when the connection should close.
    fn pump_requests(&mut self, idx: usize, now: Instant) -> bool {
        let _ = now;
        let Some(slot) =
            self.slots.get_mut(idx).and_then(|s| s.as_mut())
        else {
            return false;
        };
        match slot.conn.state {
            ConnState::ReadHeaders
            | ConnState::ReadBody
            | ConnState::KeepAliveIdle => {}
            ConnState::Handle
            | ConnState::WriteResponse
            | ConnState::Tail => return false,
        }
        if slot.conn.state == ConnState::KeepAliveIdle
            && slot.conn.pending_in()
        {
            slot.conn.set_state(ConnState::ReadHeaders);
        }
        match slot.conn.try_parse() {
            ParseOutcome::Partial { .. } => slot.conn.eof,
            ParseOutcome::Complete(req) => {
                slot.conn.set_state(ConnState::Handle);
                let token = token_of(slot.gen, idx);
                if is_tune(&req) {
                    self.migrate_tune(idx, req);
                    return false;
                }
                self.jobs.push(Job { token, req });
                false
            }
            ParseOutcome::Bad(e) => {
                let envelope = envelope_of_path(
                    slot.conn.seen_path.as_deref().unwrap_or(""),
                );
                let resp = error_json(
                    envelope,
                    400,
                    "InvalidSpec",
                    &e.to_string(),
                );
                slot.conn.keep = false;
                let _ = resp.write_to_opts(
                    &mut slot.conn.wbuf,
                    false,
                    false,
                );
                slot.conn.set_state(ConnState::WriteResponse);
                match slot.conn.flush_out() {
                    WriteOutcome::Done | WriteOutcome::Err => true,
                    WriteOutcome::Blocked => false,
                }
            }
        }
    }

    fn on_writable(&mut self, idx: usize, now: Instant) -> bool {
        let Some(slot) =
            self.slots.get_mut(idx).and_then(|s| s.as_mut())
        else {
            return false;
        };
        match slot.conn.flush_out() {
            WriteOutcome::Blocked => false,
            WriteOutcome::Err => true,
            WriteOutcome::Done => {
                let state = slot.conn.state;
                let tail_finished = slot
                    .tail
                    .as_ref()
                    .map(|t| t.finished)
                    .unwrap_or(false);
                match state {
                    ConnState::WriteResponse => {
                        self.after_response_drained(idx, now);
                        false
                    }
                    ConnState::Tail => {
                        if tail_finished {
                            true
                        } else {
                            // the buffer drained but the tail is not
                            // done: an eager source (list drain) has
                            // the next chunk ready now — step it
                            // instead of waiting for a publish/sweep
                            self.step_tail(idx, now);
                            false
                        }
                    }
                    ConnState::ReadHeaders
                    | ConnState::ReadBody
                    | ConnState::Handle
                    | ConnState::KeepAliveIdle => false,
                }
            }
        }
    }

    /// A framed response fully hit the socket: either close, or reset
    /// for the next keep-alive request (serving a pipelined one
    /// immediately if it is already buffered).
    fn after_response_drained(&mut self, idx: usize, now: Instant) {
        let keep = match self.slots.get(idx).and_then(|s| s.as_ref())
        {
            Some(slot) => slot.conn.keep,
            None => return,
        };
        if !keep {
            self.close_conn(idx);
            return;
        }
        if let Some(slot) =
            self.slots.get_mut(idx).and_then(|s| s.as_mut())
        {
            slot.conn.await_next_request(now);
        }
        if self.pump_requests(idx, now) {
            self.close_conn(idx);
        } else {
            self.rearm(idx);
        }
    }

    /// Re-register epoll interest when the desired mask changed. Hot:
    /// called after every state transition; the cached-mask check
    /// keeps `epoll_ctl` off the per-event fast path.
    fn rearm(&mut self, idx: usize) {
        let Some(slot) =
            self.slots.get_mut(idx).and_then(|s| s.as_mut())
        else {
            return;
        };
        let mut want = sys::EPOLLRDHUP;
        match slot.conn.state {
            ConnState::ReadHeaders
            | ConnState::ReadBody
            | ConnState::KeepAliveIdle => want |= sys::EPOLLIN,
            ConnState::Handle => {}
            ConnState::WriteResponse => want |= sys::EPOLLOUT,
            ConnState::Tail => {
                want |= sys::EPOLLIN;
                if slot.conn.pending_out() > 0 {
                    want |= sys::EPOLLOUT;
                }
            }
        }
        if slot.conn.interest == want {
            return;
        }
        slot.conn.interest = want;
        let fd = slot.conn.stream.as_raw_fd();
        let token = token_of(slot.gen, idx);
        let _ = self.epoll.modify(fd, want, token);
    }

    // -------------------------------------------------- completions

    fn drain_completions(&mut self, now: Instant) {
        let mut batch = std::mem::take(&mut self.done_batch);
        self.done.drain(&mut batch);
        for d in batch.drain(..) {
            self.complete(d, now);
        }
        self.done_batch = batch;
    }

    fn complete(&mut self, d: Done, now: Instant) {
        let idx = (d.token & 0xffff_ffff) as usize;
        let gen = (d.token >> 32) as u32;
        match self.slots.get(idx).and_then(|s| s.as_ref()) {
            Some(slot)
                if slot.gen == gen
                    && slot.conn.state == ConnState::Handle => {}
            _ => return, // connection died while the handler ran
        }
        if d.resp.is_stream() {
            // legacy producer stream: owns its socket until done
            self.migrate_stream(idx, d);
            return;
        }
        if let Some((source, chunked)) = d.resp.take_tail() {
            self.park_tail(idx, d, source, chunked, now);
            return;
        }
        self.finish_framed(idx, d, now);
    }

    fn finish_framed(&mut self, idx: usize, d: Done, now: Instant) {
        let Some(slot) =
            self.slots.get_mut(idx).and_then(|s| s.as_mut())
        else {
            return;
        };
        slot.conn.served += 1;
        let keep = d.keep
            && !slot.conn.eof
            && (slot.conn.served as usize) < MAX_KEEPALIVE_REQUESTS
            && !d.resp.closes_after();
        slot.conn.keep = keep;
        let _ =
            d.resp.write_to_opts(&mut slot.conn.wbuf, keep, d.head);
        slot.conn.set_state(ConnState::WriteResponse);
        match slot.conn.flush_out() {
            WriteOutcome::Done => {
                self.after_response_drained(idx, now)
            }
            WriteOutcome::Blocked => self.rearm(idx),
            WriteOutcome::Err => self.close_conn(idx),
        }
    }

    /// Park a tail response: queue the chunked head (or resolve HEAD
    /// immediately), then hold the connection as a cheap reactor entry
    /// stepped on feed wakeups and sweeps.
    fn park_tail(
        &mut self,
        idx: usize,
        d: Done,
        source: Box<dyn TailSource>,
        chunked: bool,
        now: Instant,
    ) {
        let Some(slot) =
            self.slots.get_mut(idx).and_then(|s| s.as_mut())
        else {
            return;
        };
        if chunked {
            let _ = d.resp.write_stream_head(&mut slot.conn.wbuf);
            if d.head {
                // HEAD of a stream: headers only, then close
                slot.conn.keep = false;
                slot.conn.served += 1;
                slot.conn.set_state(ConnState::WriteResponse);
                match slot.conn.flush_out() {
                    WriteOutcome::Done | WriteOutcome::Err => {
                        self.close_conn(idx)
                    }
                    WriteOutcome::Blocked => self.rearm(idx),
                }
                return;
            }
        }
        slot.tail = Some(TailState {
            source,
            chunked,
            head: d.head,
            keep: d.keep,
            finished: false,
        });
        slot.conn.set_state(ConnState::Tail);
        self.step_tail(idx, now);
        self.rearm(idx);
    }

    fn step_tails(&mut self, now: Instant) {
        for idx in 0..self.slots.len() {
            let is_tail = matches!(
                self.slots.get(idx).and_then(|s| s.as_ref()),
                Some(slot) if slot.conn.state == ConnState::Tail
            );
            if is_tail {
                self.step_tail(idx, now);
                self.rearm(idx);
            }
        }
    }

    /// Advance one parked tail: emit whatever its source has ready
    /// into the connection's write buffer and drain it. Hot: runs for
    /// every parked watcher on every feed publish.
    fn step_tail(&mut self, idx: usize, now: Instant) {
        loop {
            let Some(slot) =
                self.slots.get_mut(idx).and_then(|s| s.as_mut())
            else {
                return;
            };
            if slot.conn.state != ConnState::Tail {
                break;
            }
            if slot.conn.pending_out() > self.wbuf_cap {
                // slow consumer: its kernel buffer and ours are both
                // full — evict rather than buffer without bound
                self.close_conn(idx);
                return;
            }
            let Some(tail) = slot.tail.as_mut() else {
                break;
            };
            if tail.finished {
                break;
            }
            match tail.source.step(now) {
                TailStep::Pending => break,
                TailStep::Data(bytes) => {
                    slot.conn.wbuf.extend_from_slice(&bytes);
                    // flush between data steps: an eager source (a
                    // list drain emitting chunk after chunk) must be
                    // paced by the socket, not accumulated — the
                    // buffer never holds more than one chunk beyond
                    // what the kernel already accepted
                    match slot.conn.flush_out() {
                        WriteOutcome::Done => {}
                        WriteOutcome::Blocked => {
                            self.rearm(idx);
                            return;
                        }
                        WriteOutcome::Err => {
                            self.close_conn(idx);
                            return;
                        }
                    }
                }
                TailStep::End(bytes) => {
                    slot.conn.wbuf.extend_from_slice(&bytes);
                    tail.finished = true;
                    break;
                }
                TailStep::Respond(r) => {
                    let keep = tail.keep
                        && !slot.conn.eof
                        && (slot.conn.served as usize) + 1
                            < MAX_KEEPALIVE_REQUESTS
                        && !r.closes_after();
                    let head = tail.head;
                    slot.conn.keep = keep;
                    slot.conn.served += 1;
                    let _ = r.write_to_opts(
                        &mut slot.conn.wbuf,
                        keep,
                        head,
                    );
                    slot.tail = None;
                    slot.conn.set_state(ConnState::WriteResponse);
                    break;
                }
            }
        }
        let Some(slot) =
            self.slots.get_mut(idx).and_then(|s| s.as_mut())
        else {
            return;
        };
        let state = slot.conn.state;
        let finished = slot
            .tail
            .as_ref()
            .map(|t| t.finished)
            .unwrap_or(false);
        match slot.conn.flush_out() {
            WriteOutcome::Done => match state {
                ConnState::Tail => {
                    if finished {
                        self.close_conn(idx);
                    }
                }
                ConnState::WriteResponse => {
                    self.after_response_drained(idx, now)
                }
                ConnState::ReadHeaders
                | ConnState::ReadBody
                | ConnState::Handle
                | ConnState::KeepAliveIdle => {}
            },
            WriteOutcome::Blocked => self.rearm(idx),
            WriteOutcome::Err => self.close_conn(idx),
        }
    }

    // ------------------------------------------------------- sweeps

    /// Periodic housekeeping: reap idle keep-alive connections, 408
    /// requests that stalled mid-arrival (slow loris), and push tail
    /// deadlines over the line.
    fn sweep(&mut self, now: Instant) {
        // surface doorbell write failures: a dead eventfd stalls
        // completions, so persistent failures land in the shared
        // metrics series instead of disappearing
        let fails = self.wake.failures.load(Ordering::Relaxed);
        if fails > self.wake_failures_seen {
            self.wake_failures_seen = fails;
            self.metrics.log_bounded(
                super::middleware::HTTP_METRICS_KEY,
                "eventfd_wake_failures",
                fails,
                fails as f64,
                super::middleware::HTTP_METRICS_CAP,
            );
        }
        for idx in 0..self.slots.len() {
            let action = {
                let Some(slot) =
                    self.slots.get(idx).and_then(|s| s.as_ref())
                else {
                    continue;
                };
                match slot.conn.state {
                    ConnState::ReadHeaders
                    | ConnState::ReadBody
                    | ConnState::KeepAliveIdle => {
                        if let Some(start) = slot.conn.req_start {
                            if now.duration_since(start)
                                >= self.idle_timeout
                            {
                                Some(SweepAction::Timeout408)
                            } else {
                                None
                            }
                        } else if now
                            .duration_since(slot.conn.idle_since)
                            >= self.idle_timeout
                        {
                            // routine keep-alive expiry: close
                            // silently
                            Some(SweepAction::Close)
                        } else {
                            None
                        }
                    }
                    ConnState::Tail => {
                        let over_cap = slot.conn.pending_out()
                            > self.wbuf_cap;
                        let due = slot
                            .tail
                            .as_ref()
                            .map(|t| now >= t.source.deadline())
                            .unwrap_or(false);
                        if over_cap {
                            Some(SweepAction::Close)
                        } else if due {
                            Some(SweepAction::StepTail)
                        } else {
                            None
                        }
                    }
                    ConnState::Handle
                    | ConnState::WriteResponse => None,
                }
            };
            match action {
                None => {}
                Some(SweepAction::Close) => self.close_conn(idx),
                Some(SweepAction::Timeout408) => {
                    self.answer_408(idx)
                }
                Some(SweepAction::StepTail) => {
                    self.step_tail(idx, now);
                    self.rearm(idx);
                }
            }
        }
    }

    /// A request started arriving but stalled past the idle window:
    /// answer 408 in the envelope the request line revealed, then
    /// close.
    fn answer_408(&mut self, idx: usize) {
        let Some(slot) =
            self.slots.get_mut(idx).and_then(|s| s.as_mut())
        else {
            return;
        };
        let envelope = envelope_of_path(
            slot.conn.seen_path.as_deref().unwrap_or(""),
        );
        let resp =
            error_json(envelope, 408, "Timeout", "request incomplete");
        slot.conn.keep = false;
        let _ = resp.write_to_opts(&mut slot.conn.wbuf, false, false);
        slot.conn.set_state(ConnState::WriteResponse);
        match slot.conn.flush_out() {
            WriteOutcome::Done | WriteOutcome::Err => {
                self.close_conn(idx)
            }
            WriteOutcome::Blocked => self.rearm(idx),
        }
    }

    // ---------------------------------------------------- migration

    /// Hand a tune request's connection to a dedicated blocking
    /// thread — the one request shape whose handler legitimately runs
    /// for minutes and must neither pin a pool worker nor sit in the
    /// reactor.
    fn migrate_tune(&mut self, idx: usize, first: Box<Request>) {
        let Some(slot) = self.remove_slot(idx) else { return };
        let _ = self.epoll.del(slot.conn.stream.as_raw_fd());
        let router = Arc::clone(&self.router);
        let idle = self.idle_timeout;
        let Slot {
            conn, _guard: guard, ..
        } = slot;
        let spawned = std::thread::Builder::new()
            .name("submarine-tune".into())
            .spawn(move || {
                run_dedicated(conn, first, &router, guard, idle)
            });
        if spawned.is_err() {
            // the closure never ran, so conn and guard are gone —
            // the connection closed with them
            crate::warnlog!(
                "httpd",
                "failed to spawn tune thread; dropping connection"
            );
        }
    }

    /// Safety hatch for legacy `Response::stream` producers, which
    /// drive the socket themselves until the stream ends: give them a
    /// blocking thread and let the connection close behind them.
    fn migrate_stream(&mut self, idx: usize, d: Done) {
        let Some(slot) = self.remove_slot(idx) else { return };
        let _ = self.epoll.del(slot.conn.stream.as_raw_fd());
        let Slot {
            conn, _guard: guard, ..
        } = slot;
        let spawned = std::thread::Builder::new()
            .name("submarine-stream".into())
            .spawn(move || {
                let _ = conn.stream.set_nonblocking(false);
                let _ =
                    d.resp.write_to_opts(&conn.stream, false, d.head);
                let _ = conn
                    .stream
                    .shutdown(std::net::Shutdown::Both);
                drop(guard);
            });
        if spawned.is_err() {
            crate::warnlog!(
                "httpd",
                "failed to spawn stream thread; dropping connection"
            );
        }
    }
}

/// Request shape that still gets a dedicated thread (see module docs).
fn is_tune(req: &Request) -> bool {
    req.method.eq_ignore_ascii_case("POST")
        && req.path.ends_with("/experiment/tune")
}

/// Blocking serve loop for a migrated tune connection: dispatch the
/// already-parsed first request, then keep serving whatever else
/// arrives on the connection in place (including watches — the
/// blocking tail driver in `Response::write_to_opts` handles them).
fn run_dedicated(
    conn: Conn,
    first: Box<Request>,
    router: &Arc<Router>,
    guard: ConnGuard,
    idle: Duration,
) {
    let _ = conn.stream.set_nonblocking(false);
    let _ = conn.stream.set_read_timeout(Some(idle));
    let leftover = conn.rbuf[conn.rpos..].to_vec();
    let write_half = match conn.stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            crate::warnlog!(
                "httpd",
                "tune hand-off failed to clone socket: {e}"
            );
            return; // conn + guard drop; the socket closes
        }
    };
    let mut reader = BufReader::new(
        std::io::Cursor::new(leftover).chain(conn.stream),
    );
    let mut served: usize = conn.served as usize;
    let mut pending = Some(first);
    loop {
        let req = match pending.take() {
            Some(r) => *r,
            None => {
                let mut seen_path: Option<String> = None;
                match Request::read_next_tracked(
                    &mut reader,
                    &mut seen_path,
                ) {
                    Ok(Some(r)) => r,
                    Ok(None) => break, // clean EOF
                    Err(e) => {
                        let timed_out = matches!(
                            &e,
                            crate::SubmarineError::Io(io) if matches!(
                                io.kind(),
                                std::io::ErrorKind::WouldBlock
                                    | std::io::ErrorKind::TimedOut
                            )
                        );
                        if timed_out && seen_path.is_none() {
                            break; // idle expiry: close silently
                        }
                        let envelope = envelope_of_path(
                            seen_path.as_deref().unwrap_or(""),
                        );
                        let resp = if timed_out {
                            error_json(
                                envelope,
                                408,
                                "Timeout",
                                "request incomplete",
                            )
                        } else {
                            error_json(
                                envelope,
                                400,
                                "InvalidSpec",
                                &e.to_string(),
                            )
                        };
                        let _ = resp.write_to_opts(
                            &write_half,
                            false,
                            false,
                        );
                        break;
                    }
                }
            }
        };
        served += 1;
        let head = req.method.eq_ignore_ascii_case("HEAD");
        let resp = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| router.dispatch(&req)),
        )
        .unwrap_or_else(|_| Response::error(500, "handler panicked"));
        let keep = req.wants_keep_alive()
            && served < MAX_KEEPALIVE_REQUESTS
            && !resp.closes_after()
            && !resp.is_stream();
        if resp.write_to_opts(&write_half, keep, head).is_err() {
            break;
        }
        if !keep {
            break;
        }
    }
    let _ = write_half.shutdown(std::net::Shutdown::Both);
    drop(guard);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eventfd_wakes_epoll_with_its_token() {
        let ep = Epoll::new().unwrap();
        let ev = EventFd::new().unwrap();
        ep.add(ev.raw(), sys::EPOLLIN, 7).unwrap();
        let mut events =
            vec![sys::EpollEvent { events: 0, data: 0 }; 8];
        assert_eq!(ep.wait(&mut events, 0), 0);
        ev.wake();
        ev.wake(); // coalesces into one readiness event
        let n = ep.wait(&mut events, 1000);
        assert_eq!(n, 1);
        let token = events[0].data; // by-value read (packed struct)
        assert_eq!(token, 7);
        ev.drain();
        assert_eq!(ep.wait(&mut events, 0), 0);
    }

    #[test]
    fn epoll_mod_and_del_work() {
        let ep = Epoll::new().unwrap();
        let ev = EventFd::new().unwrap();
        ep.add(ev.raw(), 0, 1).unwrap(); // no interest
        ev.wake();
        let mut events =
            vec![sys::EpollEvent { events: 0, data: 0 }; 8];
        assert_eq!(ep.wait(&mut events, 0), 0);
        ep.modify(ev.raw(), sys::EPOLLIN, 2).unwrap();
        assert_eq!(ep.wait(&mut events, 1000), 1);
        let token = events[0].data;
        assert_eq!(token, 2);
        ep.del(ev.raw()).unwrap();
        assert_eq!(ep.wait(&mut events, 0), 0);
    }

    #[test]
    fn nofile_limit_is_queryable_and_monotonic() {
        let cur = raise_nofile_limit(64);
        assert!(cur >= 64);
        // asking again for less never lowers the limit
        assert!(raise_nofile_limit(1) >= cur.min(64));
    }

    #[test]
    fn tune_detection_is_method_and_suffix() {
        let post =
            Request::synthetic("POST", "/api/v2/experiment/tune");
        assert!(is_tune(&post));
        let get =
            Request::synthetic("GET", "/api/v2/experiment/tune");
        assert!(!is_tune(&get));
        let other = Request::synthetic("POST", "/api/v2/experiment");
        assert!(!is_tune(&other));
    }

    #[test]
    fn tokens_round_trip_gen_and_index() {
        let t = token_of(0xABCD_1234, 77);
        assert_eq!((t & 0xffff_ffff) as usize, 77);
        assert_eq!((t >> 32) as u32, 0xABCD_1234);
        assert!(t < TOKEN_WAKE);
    }
}
