//! Trie router with typed handlers, envelopes, and a middleware chain.
//!
//! Dispatch = one trie walk (O(path segments)) → middleware chain →
//! handler → envelope. The route table is compiled at registration into
//! a segment trie ([`super::trie`]); handlers are [`Handler`] trait
//! objects returning `Result<Json>`, so success/error serialization
//! lives here in exactly one place:
//!
//! - v1 envelope (compat): `{"status":"OK","result":...}` /
//!   `{"status":"ERROR","message":...}`
//! - v2 envelope: `{"status":"OK","code":200,"result":...}` /
//!   `{"status":"ERROR","code":C,"error":{"type":T,"message":M}}`
//!
//! 405 responses carry an `Allow` header; `HEAD` is answered by the
//! matching `GET` route (the server suppresses the body).

use super::handler::{Ctx, Handler};
use super::http::{Request, Response};
use super::middleware::{run_chain, Middleware};
use super::trie::PathTrie;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Which response envelope a route uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Envelope {
    V1,
    V2,
}

/// Envelope implied by a request path (used for errors produced before
/// a route is known: 404, auth, rate limiting, parse failures).
pub fn envelope_of_path(path: &str) -> Envelope {
    if path.starts_with("/api/v2") {
        Envelope::V2
    } else {
        Envelope::V1
    }
}

/// Success wrapping for a handler's output.
pub fn wrap_ok(envelope: Envelope, result: Json) -> Response {
    match envelope {
        Envelope::V1 => Response::ok_result(result),
        Envelope::V2 => Response::json(
            200,
            Json::obj()
                .set("status", Json::Str("OK".into()))
                .set("code", Json::Num(200.0))
                .set("result", result),
        ),
    }
}

/// The exact byte prefix `wrap_ok(Envelope::V2, ..)` serializes to —
/// kept in lockstep by `v2_raw_envelope_matches_wrap_ok` so the
/// cached-body fast path below stays byte-compatible.
const V2_OK_PREFIX: &[u8] = b"{\"status\":\"OK\",\"code\":200,\"result\":";

/// v2 success response spliced around a pre-serialized result — the
/// repeat-GET fast path writes a stored document's cached bytes
/// without re-serializing (or even re-parsing) anything.
pub fn v2_ok_raw(result: &[u8]) -> Response {
    let mut body =
        Vec::with_capacity(V2_OK_PREFIX.len() + result.len() + 1);
    body.extend_from_slice(V2_OK_PREFIX);
    body.extend_from_slice(result);
    body.push(b'}');
    Response::from_bytes(200, "application/json", body)
}

/// v2 success HEAD response for a result whose encoded length is
/// already known — advertises the GET body's `Content-Length` without
/// materializing a body that will not be sent.
pub fn v2_ok_head(result_len: usize) -> Response {
    Response::head_with_len(
        200,
        "application/json",
        V2_OK_PREFIX.len() + result_len + 1,
    )
}

/// Error wrapping with an explicit machine-readable kind.
pub fn error_json(
    envelope: Envelope,
    code: u16,
    kind: &str,
    msg: &str,
) -> Response {
    match envelope {
        Envelope::V1 => Response::error(code, msg),
        Envelope::V2 => Response::json(
            code,
            Json::obj()
                .set("status", Json::Str("ERROR".into()))
                .set("code", Json::Num(code as f64))
                .set(
                    "error",
                    Json::obj()
                        .set("type", Json::Str(kind.to_string()))
                        .set("message", Json::Str(msg.to_string())),
                ),
        ),
    }
}

/// Error wrapping for a [`crate::SubmarineError`].
pub fn wrap_err(envelope: Envelope, e: &crate::SubmarineError) -> Response {
    error_json(envelope, e.http_status(), e.kind(), &e.to_string())
}

/// Envelope-correct error response for a raw path (middleware, parse
/// failures — anywhere the matched route is not in hand).
pub fn error_response(path: &str, e: &crate::SubmarineError) -> Response {
    wrap_err(envelope_of_path(path), e)
}

/// A handler that owns its full [`Response`] — no envelope wrapping.
/// The watch endpoints use this: a long-poll batch or a chunked stream
/// doesn't fit the enveloped-`Json` contract. Closures
/// `Fn(&Ctx) -> Response` qualify.
pub trait RawHandler: Send + Sync {
    fn handle(&self, ctx: &Ctx<'_>) -> Response;
}

impl<F> RawHandler for F
where
    F: Fn(&Ctx<'_>) -> Response + Send + Sync,
{
    fn handle(&self, ctx: &Ctx<'_>) -> Response {
        self(ctx)
    }
}

enum RouteEntry {
    Json {
        handler: Arc<dyn Handler>,
        envelope: Envelope,
    },
    Raw(Arc<dyn RawHandler>),
}

type MethodMap = BTreeMap<String, RouteEntry>;

/// Routes requests to handlers; supports `/api/v2/experiment/:id` style
/// patterns.
#[derive(Default)]
pub struct Router {
    trie: PathTrie<MethodMap>,
    middlewares: Vec<Arc<dyn Middleware>>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Append a middleware (outermost first).
    pub fn add_middleware(&mut self, m: Arc<dyn Middleware>) {
        self.middlewares.push(m);
    }

    /// Register a handler for `method pattern` under `envelope`.
    pub fn route<H>(
        &mut self,
        method: &str,
        pattern: &str,
        envelope: Envelope,
        handler: H,
    ) where
        H: Handler + 'static,
    {
        self.route_shared(method, pattern, envelope, Arc::new(handler));
    }

    /// Register a shared handler (one endpoint served under both the v1
    /// shim and v2 paths).
    pub fn route_shared(
        &mut self,
        method: &str,
        pattern: &str,
        envelope: Envelope,
        handler: Arc<dyn Handler>,
    ) {
        let slot = self
            .trie
            .entry(pattern)
            .get_or_insert_with(MethodMap::new);
        slot.insert(
            method.to_uppercase(),
            RouteEntry::Json { handler, envelope },
        );
    }

    /// Register a raw handler that builds its own [`Response`]
    /// (streaming/watch endpoints; middleware still applies).
    pub fn route_raw(
        &mut self,
        method: &str,
        pattern: &str,
        handler: Arc<dyn RawHandler>,
    ) {
        let slot = self
            .trie
            .entry(pattern)
            .get_or_insert_with(MethodMap::new);
        slot.insert(method.to_uppercase(), RouteEntry::Raw(handler));
    }

    pub fn dispatch(&self, req: &Request) -> Response {
        let hit = self.trie.lookup(&req.path);
        let label: Option<&str> = hit.as_ref().map(|(_, pat, _)| *pat);
        let terminal = |r: &Request| -> Response {
            match &hit {
                None => error_json(
                    envelope_of_path(&r.path),
                    404,
                    "NotFound",
                    &format!("no route for {}", r.path),
                ),
                Some((methods, _pat, params)) => {
                    dispatch_method(methods, params, r)
                }
            }
        };
        run_chain(&self.middlewares, req, label, &terminal)
    }
}

fn dispatch_method(
    methods: &MethodMap,
    params: &BTreeMap<String, String>,
    req: &Request,
) -> Response {
    let method = req.method.to_uppercase();
    // HEAD is answered by the GET route; the server suppresses the body
    // while keeping content-length (RFC 9110 §9.3.2).
    let entry = methods.get(&method).or_else(|| {
        (method == "HEAD").then(|| methods.get("GET")).flatten()
    });
    match entry {
        Some(RouteEntry::Json { handler, envelope }) => {
            let ctx = Ctx::new(req, params);
            match handler.handle(&ctx) {
                Ok(result) => {
                    let mut resp = wrap_ok(*envelope, result);
                    for (k, v) in ctx.take_resp_headers() {
                        resp = resp.with_header(&k, &v);
                    }
                    resp
                }
                Err(err) => wrap_err(*envelope, &err),
            }
        }
        Some(RouteEntry::Raw(handler)) => {
            handler.handle(&Ctx::new(req, params))
        }
        None => {
            let mut allow: Vec<String> =
                methods.keys().cloned().collect();
            if methods.contains_key("GET")
                && !methods.contains_key("HEAD")
            {
                allow.push("HEAD".to_string());
            }
            allow.sort();
            error_json(
                envelope_of_path(&req.path),
                405,
                "MethodNotAllowed",
                &format!("method {method} not allowed"),
            )
            .with_header("Allow", &allow.join(", "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::httpd::middleware::AuthMiddleware;

    fn req(method: &str, path: &str) -> Request {
        Request::synthetic(method, path)
    }

    fn ok_handler(
        text: &'static str,
    ) -> impl Handler + 'static {
        move |_: &Ctx<'_>| -> crate::Result<Json> {
            Ok(Json::Str(text.to_string()))
        }
    }

    fn router() -> Router {
        let mut r = Router::new();
        r.route(
            "GET",
            "/api/v1/experiment",
            Envelope::V1,
            ok_handler("list"),
        );
        r.route(
            "GET",
            "/api/v1/experiment/:id",
            Envelope::V1,
            |ctx: &Ctx<'_>| -> crate::Result<Json> {
                Ok(Json::Str(format!("get {}", ctx.param("id")?)))
            },
        );
        r.route(
            "POST",
            "/api/v1/experiment",
            Envelope::V1,
            ok_handler("created"),
        );
        r.route(
            "GET",
            "/api/v2/experiment",
            Envelope::V2,
            ok_handler("list2"),
        );
        r
    }

    fn body_text(resp: &Response) -> String {
        String::from_utf8(resp.body.clone()).unwrap()
    }

    #[test]
    fn literal_and_param_routes() {
        let r = router();
        let resp = r.dispatch(&req("GET", "/api/v1/experiment"));
        assert_eq!(resp.status, 200);
        assert!(body_text(&resp).contains(r#""result":"list""#));
        let resp = r.dispatch(&req("GET", "/api/v1/experiment/e-42"));
        assert!(body_text(&resp).contains("get e-42"));
    }

    #[test]
    fn not_found_and_method_not_allowed() {
        let r = router();
        assert_eq!(r.dispatch(&req("GET", "/nope")).status, 404);
        let resp = r.dispatch(&req("DELETE", "/api/v1/experiment"));
        assert_eq!(resp.status, 405);
        let allow = resp
            .headers
            .iter()
            .find(|(k, _)| k == "Allow")
            .map(|(_, v)| v.as_str());
        assert_eq!(allow, Some("GET, HEAD, POST"));
    }

    #[test]
    fn head_answered_by_get_route() {
        let r = router();
        let resp = r.dispatch(&req("HEAD", "/api/v1/experiment"));
        assert_eq!(resp.status, 200);
        assert!(body_text(&resp).contains("list"));
    }

    #[test]
    fn envelopes_differ_by_version() {
        let r = router();
        let v1 = r.dispatch(&req("GET", "/api/v1/experiment"));
        let j1 = Json::parse(&body_text(&v1)).unwrap();
        assert!(j1.get("code").is_none());
        assert_eq!(j1.str_field("status"), Some("OK"));
        let v2 = r.dispatch(&req("GET", "/api/v2/experiment"));
        let j2 = Json::parse(&body_text(&v2)).unwrap();
        assert_eq!(j2.num_field("code"), Some(200.0));
        // v2 errors carry the typed error object
        let e2 = r.dispatch(&req("GET", "/api/v2/zzz"));
        let j = Json::parse(&body_text(&e2)).unwrap();
        assert_eq!(
            j.at(&["error", "type"]).and_then(Json::as_str),
            Some("NotFound")
        );
        // v1 errors keep the flat message field
        let e1 = r.dispatch(&req("GET", "/api/v1/zzz"));
        let j = Json::parse(&body_text(&e1)).unwrap();
        assert!(j.str_field("message").is_some());
    }

    #[test]
    fn handler_errors_map_through_envelope() {
        let mut r = router();
        r.route(
            "GET",
            "/api/v2/boom",
            Envelope::V2,
            |_: &Ctx<'_>| -> crate::Result<Json> {
                Err(crate::SubmarineError::NotFound("thing".into()))
            },
        );
        let resp = r.dispatch(&req("GET", "/api/v2/boom"));
        assert_eq!(resp.status, 404);
        let j = Json::parse(&body_text(&resp)).unwrap();
        assert_eq!(j.num_field("code"), Some(404.0));
        assert_eq!(
            j.at(&["error", "type"]).and_then(Json::as_str),
            Some("NotFound")
        );
    }

    #[test]
    fn auth_enforced_when_configured() {
        let mut r = router();
        r.add_middleware(Arc::new(AuthMiddleware::new("secret")));
        assert_eq!(
            r.dispatch(&req("GET", "/api/v1/experiment")).status,
            401
        );
        let mut authed = req("GET", "/api/v1/experiment");
        authed
            .headers
            .insert("authorization".into(), "Bearer secret".into());
        assert_eq!(r.dispatch(&authed).status, 200);
    }

    #[test]
    fn v2_raw_envelope_matches_wrap_ok() {
        let result = Json::parse(r#"{"a":[1,2],"b":"x"}"#).unwrap();
        let enveloped = wrap_ok(Envelope::V2, result.clone());
        let raw = v2_ok_raw(&result.dump().into_bytes());
        assert_eq!(enveloped.body, raw.body);
        let head = v2_ok_head(result.dump().len());
        assert_eq!(head.declared_len, Some(raw.body.len()));
        assert!(head.body.is_empty());
    }

    #[test]
    fn trailing_slash_tolerated() {
        let r = router();
        assert_eq!(
            r.dispatch(&req("GET", "/api/v1/experiment/")).status,
            200
        );
    }
}
