//! Method + path-pattern router with `:param` captures.

use super::http::{Request, Response};
use std::collections::BTreeMap;
use std::sync::Arc;

type Handler = dyn Fn(&Request, &BTreeMap<String, String>) -> Response
    + Send
    + Sync;

struct Route {
    method: String,
    segments: Vec<Seg>,
    handler: Arc<Handler>,
}

enum Seg {
    Lit(String),
    Param(String),
}

/// Routes requests to handlers; supports `/api/v1/experiment/:id` style
/// patterns.
#[derive(Default)]
pub struct Router {
    routes: Vec<Route>,
    /// Optional bearer token required on every request (§3.1 auth).
    pub auth_token: Option<String>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    pub fn with_auth(mut self, token: &str) -> Router {
        self.auth_token = Some(token.to_string());
        self
    }

    pub fn add<F>(&mut self, method: &str, pattern: &str, handler: F)
    where
        F: Fn(&Request, &BTreeMap<String, String>) -> Response
            + Send
            + Sync
            + 'static,
    {
        let segments = pattern
            .trim_matches('/')
            .split('/')
            .filter(|s| !s.is_empty())
            .map(|s| {
                if let Some(p) = s.strip_prefix(':') {
                    Seg::Param(p.to_string())
                } else {
                    Seg::Lit(s.to_string())
                }
            })
            .collect();
        self.routes.push(Route {
            method: method.to_uppercase(),
            segments,
            handler: Arc::new(handler),
        });
    }

    pub fn dispatch(&self, req: &Request) -> Response {
        if let Some(expect) = &self.auth_token {
            if req.bearer_token() != Some(expect.as_str()) {
                return Response::error(401, "missing or bad token");
            }
        }
        let parts: Vec<&str> = req
            .path
            .trim_matches('/')
            .split('/')
            .filter(|s| !s.is_empty())
            .collect();
        let mut saw_path = false;
        for route in &self.routes {
            if route.segments.len() != parts.len() {
                continue;
            }
            let mut params = BTreeMap::new();
            let matches =
                route.segments.iter().zip(&parts).all(|(seg, part)| {
                    match seg {
                        Seg::Lit(l) => l == part,
                        Seg::Param(name) => {
                            params.insert(
                                name.clone(),
                                part.to_string(),
                            );
                            true
                        }
                    }
                });
            if !matches {
                continue;
            }
            saw_path = true;
            if route.method == req.method {
                return (route.handler)(req, &params);
            }
        }
        if saw_path {
            Response::error(405, "method not allowed")
        } else {
            Response::error(404, &format!("no route for {}", req.path))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn req(method: &str, path: &str) -> Request {
        Request {
            method: method.into(),
            path: path.into(),
            query: BTreeMap::new(),
            headers: BTreeMap::new(),
            body: Vec::new(),
        }
    }

    fn router() -> Router {
        let mut r = Router::new();
        r.add("GET", "/api/v1/experiment", |_, _| {
            Response::ok(Json::Str("list".into()))
        });
        r.add("GET", "/api/v1/experiment/:id", |_, p| {
            Response::ok(Json::Str(format!("get {}", p["id"])))
        });
        r.add("POST", "/api/v1/experiment", |_, _| {
            Response::ok(Json::Str("created".into()))
        });
        r
    }

    #[test]
    fn literal_and_param_routes() {
        let r = router();
        assert_eq!(
            r.dispatch(&req("GET", "/api/v1/experiment")).body,
            Json::Str("list".into()).dump().into_bytes()
        );
        let resp = r.dispatch(&req("GET", "/api/v1/experiment/e-42"));
        assert!(String::from_utf8(resp.body).unwrap().contains("get e-42"));
    }

    #[test]
    fn not_found_and_method_not_allowed() {
        let r = router();
        assert_eq!(r.dispatch(&req("GET", "/nope")).status, 404);
        assert_eq!(
            r.dispatch(&req("DELETE", "/api/v1/experiment")).status,
            405
        );
    }

    #[test]
    fn auth_enforced_when_configured() {
        let r = router().with_auth("secret");
        assert_eq!(
            r.dispatch(&req("GET", "/api/v1/experiment")).status,
            401
        );
        let mut authed = req("GET", "/api/v1/experiment");
        authed.headers.insert(
            "authorization".into(),
            "Bearer secret".into(),
        );
        assert_eq!(r.dispatch(&authed).status, 200);
    }

    #[test]
    fn trailing_slash_tolerated() {
        let r = router();
        assert_eq!(
            r.dispatch(&req("GET", "/api/v1/experiment/")).status,
            200
        );
    }
}
