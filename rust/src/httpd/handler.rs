//! Typed request handlers.
//!
//! Every v2 endpoint is `fn(&Ctx, Input) -> crate::Result<Output>`:
//! extraction (path params, query, JSON body parsed into spec types),
//! serialization, and error→status mapping live here and in the router's
//! envelope, not in each endpoint. Handlers return domain values; the
//! router wraps them in the API envelope.

use super::http::Request;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::marker::PhantomData;

/// Per-request context handed to handlers: the parsed request plus the
/// path parameters captured by the trie router, and a side channel for
/// response headers (`ETag` on resource reads).
pub struct Ctx<'a> {
    pub req: &'a Request,
    pub params: &'a BTreeMap<String, String>,
    resp_headers: std::cell::RefCell<Vec<(String, String)>>,
}

fn invalid(msg: String) -> crate::SubmarineError {
    crate::SubmarineError::InvalidSpec(msg)
}

impl<'a> Ctx<'a> {
    pub fn new(
        req: &'a Request,
        params: &'a BTreeMap<String, String>,
    ) -> Ctx<'a> {
        Ctx {
            req,
            params,
            resp_headers: std::cell::RefCell::new(Vec::new()),
        }
    }

    /// Attach a header to the (successful) response.
    pub fn set_resp_header(&self, name: &str, value: &str) {
        self.resp_headers
            .borrow_mut()
            .push((name.to_string(), value.to_string()));
    }

    /// Drain the headers handlers attached (called by the router after
    /// a successful dispatch).
    pub fn take_resp_headers(&self) -> Vec<(String, String)> {
        std::mem::take(&mut *self.resp_headers.borrow_mut())
    }

    /// Required path parameter (`:name` capture).
    pub fn param(&self, name: &str) -> crate::Result<&str> {
        self.params
            .get(name)
            .map(String::as_str)
            .ok_or_else(|| invalid(format!("missing path param {name}")))
    }

    /// Optional query-string value.
    pub fn query(&self, name: &str) -> Option<&str> {
        self.req.query.get(name).map(String::as_str)
    }

    /// Optional numeric query-string value; non-numeric input is a 400.
    pub fn query_usize(&self, name: &str) -> crate::Result<Option<usize>> {
        match self.query(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                invalid(format!("query param {name} must be a number"))
            }),
        }
    }

    /// Parsed JSON request body (error if missing or malformed).
    pub fn json_body(&self) -> crate::Result<Json> {
        self.req.json()
    }

    /// JSON body parsed into a spec type.
    pub fn body_as<T: FromBody>(&self) -> crate::Result<T> {
        T::from_body(&self.json_body()?)
    }
}

/// Types constructible from a JSON request body.
pub trait FromBody: Sized {
    fn from_body(j: &Json) -> crate::Result<Self>;
}

impl FromBody for crate::experiment::spec::ExperimentSpec {
    fn from_body(j: &Json) -> crate::Result<Self> {
        crate::experiment::spec::ExperimentSpec::from_json(j)
    }
}

impl FromBody for crate::template::Template {
    fn from_body(j: &Json) -> crate::Result<Self> {
        crate::template::Template::from_json(j)
    }
}

impl FromBody for crate::environment::Environment {
    fn from_body(j: &Json) -> crate::Result<Self> {
        crate::environment::Environment::from_json(j)
    }
}

/// A routed endpoint. Closures `Fn(&Ctx) -> Result<Json>` qualify; use
/// [`typed`] for the extractor-based `fn(&Ctx, Input) -> Result<Output>`
/// form.
pub trait Handler: Send + Sync {
    fn handle(&self, ctx: &Ctx<'_>) -> crate::Result<Json>;
}

impl<F> Handler for F
where
    F: Fn(&Ctx<'_>) -> crate::Result<Json> + Send + Sync,
{
    fn handle(&self, ctx: &Ctx<'_>) -> crate::Result<Json> {
        self(ctx)
    }
}

/// Inputs the harness can pull out of a request before the handler runs.
pub trait Extract: Sized {
    fn extract(ctx: &Ctx<'_>) -> crate::Result<Self>;
}

impl Extract for () {
    fn extract(_: &Ctx<'_>) -> crate::Result<()> {
        Ok(())
    }
}

/// Raw JSON body.
impl Extract for Json {
    fn extract(ctx: &Ctx<'_>) -> crate::Result<Json> {
        ctx.json_body()
    }
}

/// Optional raw JSON body (`None` when the body is empty).
impl Extract for Option<Json> {
    fn extract(ctx: &Ctx<'_>) -> crate::Result<Option<Json>> {
        if ctx.req.body.is_empty() {
            Ok(None)
        } else {
            ctx.json_body().map(Some)
        }
    }
}

/// JSON body parsed into a spec type (`Body(ExperimentSpec)` etc.).
pub struct Body<T>(pub T);

impl<T: FromBody> Extract for Body<T> {
    fn extract(ctx: &Ctx<'_>) -> crate::Result<Body<T>> {
        ctx.body_as().map(Body)
    }
}

/// Largest page any v2 list endpoint hands out for an explicit
/// `?limit=` (larger asks are clamped, not rejected — the clamp is
/// visible in the echoed `limit` field). Full drains belong to the
/// cursor loop or `?stream=1`, not to one giant page.
pub const MAX_LIST_LIMIT: usize = 1000;

/// Pagination + status filter, from `limit` / `offset` / `status` query
/// params (v2 list endpoints).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Page {
    pub limit: Option<usize>,
    pub offset: usize,
    pub status: Option<String>,
}

impl Page {
    /// Apply offset/limit to `items`; returns the page and the
    /// pre-pagination total. Prefer [`Page::window`] when the caller
    /// has an iterator and a known total — this eager form forces the
    /// full result vector to exist first.
    pub fn slice<T>(&self, items: Vec<T>) -> (Vec<T>, usize) {
        let total = items.len();
        let (page, _) = self.window(items.into_iter(), total);
        (page, total)
    }

    /// Iterator-based paging: materializes only the requested window,
    /// so `?limit=10` over a 10k-key namespace clones 10 rows, not 10k.
    pub fn window<T>(
        &self,
        items: impl Iterator<Item = T>,
        total: usize,
    ) -> (Vec<T>, usize) {
        let page = items
            .skip(self.offset)
            .take(self.limit.unwrap_or(usize::MAX))
            .collect();
        (page, total)
    }

    /// The v2 list payload: `{items, total, limit, offset}`.
    pub fn envelope(&self, items: Vec<Json>, total: usize) -> Json {
        let mut out = Json::obj()
            .set("items", Json::Arr(items))
            .set("total", Json::Num(total as f64))
            .set("offset", Json::Num(self.offset as f64));
        if let Some(l) = self.limit {
            out = out.set("limit", Json::Num(l as f64));
        }
        out
    }
}

impl Extract for Page {
    fn extract(ctx: &Ctx<'_>) -> crate::Result<Page> {
        let limit = match ctx.query_usize("limit")? {
            // `limit=0` used to silently mean "no limit" through the
            // `unwrap_or(usize::MAX)` windows below; an explicit empty
            // page is never what a caller wants, so it is now loud
            Some(0) => {
                return Err(crate::SubmarineError::InvalidSpec(
                    "limit must be at least 1".into(),
                ))
            }
            Some(l) => Some(l.min(MAX_LIST_LIMIT)),
            None => None,
        };
        Ok(Page {
            limit,
            offset: ctx.query_usize("offset")?.unwrap_or(0),
            status: ctx.query("status").map(str::to_string),
        })
    }
}

/// Handler outputs the harness knows how to serialize.
pub trait IntoOutput {
    fn into_output(self) -> Json;
}

impl IntoOutput for Json {
    fn into_output(self) -> Json {
        self
    }
}

impl IntoOutput for bool {
    fn into_output(self) -> Json {
        Json::Bool(self)
    }
}

impl IntoOutput for String {
    fn into_output(self) -> Json {
        Json::Str(self)
    }
}

impl IntoOutput for Vec<Json> {
    fn into_output(self) -> Json {
        Json::Arr(self)
    }
}

/// Adapter turning `fn(&Ctx, I) -> Result<O>` into a [`Handler`].
pub struct Typed<F, I, O> {
    f: F,
    _marker: PhantomData<fn(I) -> O>,
}

/// Wrap a typed endpoint function: input extraction and output
/// serialization happen in one place.
pub fn typed<F, I, O>(f: F) -> Typed<F, I, O>
where
    F: Fn(&Ctx<'_>, I) -> crate::Result<O> + Send + Sync,
    I: Extract,
    O: IntoOutput,
{
    Typed {
        f,
        _marker: PhantomData,
    }
}

impl<F, I, O> Handler for Typed<F, I, O>
where
    F: Fn(&Ctx<'_>, I) -> crate::Result<O> + Send + Sync,
    I: Extract,
    O: IntoOutput,
{
    fn handle(&self, ctx: &Ctx<'_>) -> crate::Result<Json> {
        let input = I::extract(ctx)?;
        (self.f)(ctx, input).map(IntoOutput::into_output)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_of<'a>(
        req: &'a Request,
        params: &'a BTreeMap<String, String>,
    ) -> Ctx<'a> {
        Ctx::new(req, params)
    }

    #[test]
    fn page_extraction_and_slice() {
        let req = Request::synthetic(
            "GET",
            "/e?limit=2&offset=1&status=Running",
        );
        let params = BTreeMap::new();
        let page = Page::extract(&ctx_of(&req, &params)).unwrap();
        assert_eq!(page.limit, Some(2));
        assert_eq!(page.offset, 1);
        assert_eq!(page.status.as_deref(), Some("Running"));
        let (items, total) = page.slice(vec![1, 2, 3, 4, 5]);
        assert_eq!(items, vec![2, 3]);
        assert_eq!(total, 5);
    }

    #[test]
    fn bad_limit_is_invalid_spec() {
        let req = Request::synthetic("GET", "/e?limit=abc");
        let params = BTreeMap::new();
        let err = Page::extract(&ctx_of(&req, &params)).unwrap_err();
        assert_eq!(err.http_status(), 400);
    }

    #[test]
    fn zero_limit_is_invalid_spec() {
        let req = Request::synthetic("GET", "/e?limit=0");
        let params = BTreeMap::new();
        let err = Page::extract(&ctx_of(&req, &params)).unwrap_err();
        assert_eq!(err.http_status(), 400);
    }

    #[test]
    fn oversized_limit_is_clamped_to_max() {
        let req = Request::synthetic("GET", "/e?limit=999999");
        let params = BTreeMap::new();
        let page = Page::extract(&ctx_of(&req, &params)).unwrap();
        assert_eq!(page.limit, Some(MAX_LIST_LIMIT));
        // no limit still means unlimited (compat)
        let req = Request::synthetic("GET", "/e");
        let page = Page::extract(&ctx_of(&req, &params)).unwrap();
        assert_eq!(page.limit, None);
    }

    #[test]
    fn typed_handler_runs_extraction() {
        let h = typed(|_ctx: &Ctx<'_>, page: Page| {
            Ok(Json::Num(page.offset as f64))
        });
        let req = Request::synthetic("GET", "/e?offset=7");
        let params = BTreeMap::new();
        let out = h.handle(&ctx_of(&req, &params)).unwrap();
        assert_eq!(out, Json::Num(7.0));
    }

    #[test]
    fn body_extractor_parses_spec_types() {
        let mut req = Request::synthetic("POST", "/e");
        req.body = br#"{"meta":{"name":"m"},
            "spec":{"Worker":{"replicas":1,"resources":"cpu=1"}}}"#
            .to_vec();
        let params = BTreeMap::new();
        let Body(spec) =
            Body::<crate::experiment::spec::ExperimentSpec>::extract(
                &ctx_of(&req, &params),
            )
            .unwrap();
        assert_eq!(spec.meta.name, "m");
    }

    #[test]
    fn optional_body_none_when_empty() {
        let req = Request::synthetic("POST", "/e");
        let params = BTreeMap::new();
        let v = Option::<Json>::extract(&ctx_of(&req, &params)).unwrap();
        assert!(v.is_none());
    }

    #[test]
    fn param_lookup_errors_when_missing() {
        let req = Request::synthetic("GET", "/e");
        let params = BTreeMap::new();
        let c = ctx_of(&req, &params);
        assert!(c.param("id").is_err());
    }
}
