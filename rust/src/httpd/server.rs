//! The Submarine server (paper Fig. 1 control plane): wires every core
//! service behind the REST API and runs a thread-per-connection accept
//! loop capped at [`MAX_CONNECTIONS`] (beyond the cap, connections are
//! shed with 503 rather than queued).
//!
//! Connections are HTTP/1.1 keep-alive: each connection thread loops
//! read-request → dispatch → write content-length-framed response on the
//! same socket until the client closes, asks for `connection: close`, or
//! the per-connection request cap / idle timeout is hit.

use super::http::{Request, Response};
use super::router::{envelope_of_path, error_json, Router};
use super::v2::{build_api, ApiConfig};
use crate::environment::EnvironmentManager;
use crate::experiment::manager::ExperimentManager;
use crate::experiment::monitor::ExperimentMonitor;
use crate::model::ModelRegistry;
use crate::orchestrator::Submitter;
use crate::storage::{MetaStore, MetricStore};
use crate::template::TemplateManager;
use std::io::BufRead;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// All core services (paper §3.2: "Submarine server consists of several
/// core services"). Examples/tests may use this directly without HTTP.
pub struct Services {
    pub store: Arc<MetaStore>,
    pub monitor: Arc<ExperimentMonitor>,
    pub metrics: Arc<MetricStore>,
    pub experiments: Arc<ExperimentManager>,
    pub templates: Arc<TemplateManager>,
    pub environments: Arc<EnvironmentManager>,
    pub models: Arc<ModelRegistry>,
    /// Background scheduler loop, present when the stack was assembled
    /// over the simulated YARN/K8s pipeline (`with_sim_executor`). Feeds
    /// the extended `GET /cluster` payload; dropping `Services` stops
    /// the loop.
    pub executor: Option<Arc<crate::orchestrator::engine::ExecutionEngine>>,
}

impl Services {
    /// Assemble the full service stack around a submitter.
    pub fn new(
        store: Arc<MetaStore>,
        submitter: Arc<dyn Submitter>,
    ) -> Services {
        let monitor = Arc::new(ExperimentMonitor::new());
        let metrics = Arc::new(MetricStore::new());
        Self::with_parts(store, monitor, metrics, submitter)
    }

    pub fn with_parts(
        store: Arc<MetaStore>,
        monitor: Arc<ExperimentMonitor>,
        metrics: Arc<MetricStore>,
        submitter: Arc<dyn Submitter>,
    ) -> Services {
        let experiments = Arc::new(ExperimentManager::new(
            Arc::clone(&store),
            Arc::clone(&monitor),
            submitter,
        ));
        // Mirror monitor-derived statuses into the experiment docs so
        // the persisted status (and its secondary index, which backs
        // the v2 `?status=` filter) tracks the live lifecycle.
        let status_sink = Arc::clone(&store);
        monitor.set_observer(Box::new(move |id, st| {
            crate::experiment::manager::persist_status(
                &status_sink,
                id,
                st,
            )
        }));
        Services {
            templates: Arc::new(TemplateManager::new(Arc::clone(&store))),
            environments: Arc::new(EnvironmentManager::new(Arc::clone(
                &store,
            ))),
            models: Arc::new(ModelRegistry::new(Arc::clone(&store))),
            experiments,
            monitor,
            metrics,
            store,
            executor: None,
        }
    }

    /// Assemble the full stack over the simulated execution pipeline:
    /// experiments POSTed to the API are gang-scheduled onto the cluster
    /// sim by a background loop and run to tracked completion (the
    /// paper's Fig. 4 serving path). The submitter must already carry
    /// the monitor it reports into.
    pub fn with_sim_executor(
        store: Arc<MetaStore>,
        submitter: Arc<crate::orchestrator::sim_submitter::SimSubmitter>,
        metrics: Arc<MetricStore>,
        cfg: crate::orchestrator::engine::EngineConfig,
    ) -> Services {
        let monitor = Arc::clone(submitter.monitor());
        let mut services = Services::with_parts(
            store,
            monitor,
            metrics,
            Arc::clone(&submitter) as Arc<dyn Submitter>,
        );
        services.executor = Some(
            crate::orchestrator::engine::ExecutionEngine::start(
                submitter, cfg,
            ),
        );
        services
    }
}

/// Hard cap on requests served per connection (bounds one client's hold
/// on a connection thread).
const MAX_KEEPALIVE_REQUESTS: usize = 1024;

/// Maximum concurrent connections. Keep-alive pins a thread per
/// *connection* (not per request as in the seed design), so instead of
/// a small fixed pool with an unbounded queue — which 8 long-lived
/// clients could starve — each connection gets its own thread up to
/// this cap, and connections beyond it are shed immediately with 503
/// rather than queued behind busy ones.
const MAX_CONNECTIONS: usize = 256;

/// How long a keep-alive connection may sit idle between requests
/// before the server reclaims its thread.
const IDLE_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(5);

/// The HTTP server.
pub struct Server {
    router: Arc<Router>,
    listener: TcpListener,
    active: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
    local_addr: std::net::SocketAddr,
}

/// Decrements the live-connection count even if a handler panics.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

impl Server {
    /// Bind on `127.0.0.1:port` (0 = ephemeral) with routes over
    /// `services`.
    pub fn bind(
        services: Arc<Services>,
        port: u16,
        auth_token: Option<&str>,
    ) -> crate::Result<Server> {
        Self::bind_with_config(
            services,
            port,
            &ApiConfig {
                auth_token: auth_token.map(str::to_string),
                rate_limit: None,
            },
        )
    }

    /// Bind with the full API configuration (auth + rate limiting).
    pub fn bind_with_config(
        services: Arc<Services>,
        port: u16,
        cfg: &ApiConfig,
    ) -> crate::Result<Server> {
        let router = build_api(services, cfg);
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let local_addr = listener.local_addr()?;
        Ok(Server {
            router: Arc::new(router),
            listener,
            active: Arc::new(AtomicUsize::new(0)),
            stop: Arc::new(AtomicBool::new(false)),
            local_addr,
        })
    }

    pub fn port(&self) -> u16 {
        self.local_addr.port()
    }

    /// Handle for stopping the accept loop from another thread.
    pub fn stopper(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Run the accept loop until stopped (blocking).
    pub fn serve(&self) -> crate::Result<()> {
        crate::info!("httpd", "listening on {}", self.local_addr);
        self.listener.set_nonblocking(false)?;
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::Relaxed) {
                break;
            }
            match conn {
                Ok(stream) => {
                    if self.active.load(Ordering::Relaxed)
                        >= MAX_CONNECTIONS
                    {
                        // Shed instead of queueing behind busy
                        // connections: a prompt 503 beats an unbounded
                        // backlog. The lingering close runs on its own
                        // short-lived thread so a slow peer cannot
                        // stall the accept loop at exactly the moment
                        // the server is overloaded.
                        let _ = std::thread::Builder::new()
                            .name("submarine-shed".into())
                            .spawn(move || shed_connection(stream));
                        continue;
                    }
                    self.active.fetch_add(1, Ordering::Relaxed);
                    let guard = ConnGuard(Arc::clone(&self.active));
                    let router = Arc::clone(&self.router);
                    let spawned = std::thread::Builder::new()
                        .name("submarine-conn".into())
                        .spawn(move || {
                            let _guard = guard;
                            handle(&router, stream);
                        });
                    if spawned.is_err() {
                        crate::warnlog!(
                            "httpd",
                            "failed to spawn connection thread"
                        );
                        // guard was moved into the dropped closure, so
                        // the count is already back down
                    }
                }
                Err(e) => {
                    crate::warnlog!("httpd", "accept error: {e}");
                }
            }
        }
        Ok(())
    }

    /// Serve on a background thread; returns a join handle. Stop by
    /// setting `stopper()` and making one dummy connection.
    pub fn serve_background(self: Arc<Self>) -> std::thread::JoinHandle<()> {
        std::thread::Builder::new()
            .name("submarine-httpd".into())
            .spawn(move || {
                let _ = self.serve();
            })
            .expect("spawn httpd thread")
    }
}

/// Refuse a connection with 503 and a lingering close. Writing first
/// and then draining (bounded) before closing keeps the kernel from
/// sending RST over unread input, which would discard the 503 in
/// flight. Transport-layer errors like this one use the flat v1 error
/// envelope: the request is never parsed, so the path (and thus the
/// API version) is unknown.
fn shed_connection(stream: TcpStream) {
    use std::io::Read;
    let _ = stream.set_read_timeout(Some(
        std::time::Duration::from_millis(250),
    ));
    let resp = Response::error(503, "server at connection capacity");
    let _ = resp.write_to_opts(&stream, false, false);
    let _ = stream.shutdown(std::net::Shutdown::Write);
    // bounded drain: at most ~64KB or ~8 read timeouts, then close
    let mut sink = [0u8; 8192];
    for _ in 0..8 {
        match (&stream).read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Serve one connection: keep-alive request loop. One `BufReader`
/// spans the connection so pipelined read-ahead is never dropped.
fn handle(router: &Router, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(IDLE_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let mut reader = std::io::BufReader::new(&stream);
    for served in 0..MAX_KEEPALIVE_REQUESTS {
        // Idle window first: waiting here separates "client sent
        // nothing for IDLE_TIMEOUT" (routine keep-alive expiry — close
        // silently) from a timeout in the middle of a request below
        // (protocol problem — answer 408).
        match reader.fill_buf() {
            Ok(buf) if buf.is_empty() => break, // clean EOF
            Ok(_) => {}
            Err(_) => break, // idle timeout or dead socket
        }
        let mut seen_path: Option<String> = None;
        match Request::read_next_tracked(&mut reader, &mut seen_path) {
            Ok(None) => break, // peer closed between requests
            Ok(Some(req)) => {
                let resp = router.dispatch(&req);
                // A streaming response (watch) owns the socket until it
                // ends and always closes — its length is unframed.
                let keep = req.wants_keep_alive()
                    && served + 1 < MAX_KEEPALIVE_REQUESTS
                    && !resp.is_stream();
                let head_only = req.method.eq_ignore_ascii_case("HEAD");
                if resp
                    .write_to_opts(&stream, keep, head_only)
                    .is_err()
                {
                    break;
                }
                if !keep {
                    break;
                }
            }
            Err(e) => {
                // The request started arriving but didn't finish in
                // time (trickled body) or didn't parse. The request
                // line may already have revealed which API version the
                // client speaks — answer in that envelope rather than
                // defaulting to the flat v1 shape.
                let timed_out = matches!(
                    &e,
                    crate::SubmarineError::Io(io) if matches!(
                        io.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                    )
                );
                let envelope = envelope_of_path(
                    seen_path.as_deref().unwrap_or(""),
                );
                let resp = if timed_out {
                    error_json(
                        envelope,
                        408,
                        "Timeout",
                        "request incomplete",
                    )
                } else {
                    error_json(
                        envelope,
                        400,
                        "InvalidSpec",
                        &e.to_string(),
                    )
                };
                let _ = resp.write_to_opts(&stream, false, false);
                break;
            }
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Build the default-config router (v1 compat + v2). Kept for direct
/// router-level use in tests and benches.
pub fn build_router(s: Arc<Services>) -> Router {
    build_api(s, &ApiConfig::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::spec::ExperimentSpec;
    use crate::util::json::Json;
    use std::io::{BufRead, BufReader, Read, Write};

    struct NullSubmitter;
    impl Submitter for NullSubmitter {
        fn name(&self) -> &'static str {
            "null"
        }
        fn submit(&self, _: &str, _: &ExperimentSpec) -> crate::Result<()> {
            Ok(())
        }
        fn kill(&self, _: &str) -> crate::Result<()> {
            Ok(())
        }
    }

    fn services() -> Arc<Services> {
        Arc::new(Services::new(
            Arc::new(MetaStore::in_memory()),
            Arc::new(NullSubmitter),
        ))
    }

    fn start() -> (Arc<Server>, u16, Arc<AtomicBool>,
                   std::thread::JoinHandle<()>) {
        let srv = Arc::new(Server::bind(services(), 0, None).unwrap());
        let port = srv.port();
        let stop = srv.stopper();
        let handle = Arc::clone(&srv).serve_background();
        (srv, port, stop, handle)
    }

    fn shutdown(
        port: u16,
        stop: Arc<AtomicBool>,
        handle: std::thread::JoinHandle<()>,
    ) {
        stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(("127.0.0.1", port));
        handle.join().unwrap();
    }

    /// Read one content-length-framed response off a reused stream.
    fn read_response(
        reader: &mut BufReader<&TcpStream>,
    ) -> (u16, String) {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let status: u16 =
            line.split(' ').nth(1).unwrap().parse().unwrap();
        let mut len = 0usize;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).unwrap();
            let h = h.trim_end().to_ascii_lowercase();
            if h.is_empty() {
                break;
            }
            if let Some(v) = h.strip_prefix("content-length:") {
                len = v.trim().parse().unwrap();
            }
        }
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).unwrap();
        (status, String::from_utf8(body).unwrap())
    }

    #[test]
    fn end_to_end_over_tcp() {
        let (_srv, port, stop, handle) = start();
        let mut stream =
            TcpStream::connect(("127.0.0.1", port)).unwrap();
        write!(
            stream,
            "GET /api/v1/cluster HTTP/1.1\r\nhost: x\r\nconnection: close\r\n\r\n"
        )
        .unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert!(buf.contains("200 OK"), "{buf}");
        assert!(buf.contains("RUNNING"));
        assert!(buf.contains("connection: close"));
        shutdown(port, stop, handle);
    }

    #[test]
    fn keep_alive_serves_many_requests_per_connection() {
        let (_srv, port, stop, handle) = start();
        let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(&stream);
        for i in 0..5 {
            write!(
                &stream,
                "GET /api/v2/cluster HTTP/1.1\r\nhost: x\r\n\r\n"
            )
            .unwrap();
            let (status, body) = read_response(&mut reader);
            assert_eq!(status, 200, "request {i}: {body}");
            assert!(body.contains("RUNNING"));
        }
        drop(reader);
        drop(stream);
        shutdown(port, stop, handle);
    }

    #[test]
    fn head_is_answered_without_body() {
        let (_srv, port, stop, handle) = start();
        let mut stream =
            TcpStream::connect(("127.0.0.1", port)).unwrap();
        write!(
            stream,
            "HEAD /api/v1/cluster HTTP/1.1\r\nhost: x\r\nconnection: close\r\n\r\n"
        )
        .unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert!(buf.contains("200 OK"), "{buf}");
        // content-length advertised, but no body bytes follow
        assert!(buf.contains("content-length:"));
        assert!(buf.trim_end().ends_with("connection: close"), "{buf}");
        shutdown(port, stop, handle);
    }

    #[test]
    fn unknown_method_gets_allow_header_over_tcp() {
        let (_srv, port, stop, handle) = start();
        let mut stream =
            TcpStream::connect(("127.0.0.1", port)).unwrap();
        write!(
            stream,
            "PATCH /api/v1/cluster HTTP/1.1\r\nhost: x\r\nconnection: close\r\n\r\n"
        )
        .unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert!(buf.contains("405"), "{buf}");
        assert!(buf.contains("Allow: GET, HEAD"), "{buf}");
        shutdown(port, stop, handle);
    }

    #[test]
    fn router_smoke_over_build_router() {
        let r = build_router(services());
        let resp =
            r.dispatch(&Request::synthetic("GET", "/api/v2/cluster"));
        assert_eq!(resp.status, 200);
        let j = Json::parse(
            std::str::from_utf8(&resp.body).unwrap(),
        )
        .unwrap();
        assert_eq!(j.num_field("code"), Some(200.0));
    }
}
