//! The Submarine server (paper Fig. 1 control plane): wires every core
//! service behind the REST API and runs the accept loop on a thread pool.

use super::http::{Request, Response};
use super::router::Router;
use crate::environment::{Environment, EnvironmentManager};
use crate::experiment::manager::ExperimentManager;
use crate::experiment::monitor::ExperimentMonitor;
use crate::experiment::spec::ExperimentSpec;
use crate::model::ModelRegistry;
use crate::orchestrator::Submitter;
use crate::storage::{MetaStore, MetricStore};
use crate::template::{Template, TemplateManager};
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use std::collections::BTreeMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// All core services (paper §3.2: "Submarine server consists of several
/// core services"). Examples/tests may use this directly without HTTP.
pub struct Services {
    pub store: Arc<MetaStore>,
    pub monitor: Arc<ExperimentMonitor>,
    pub metrics: Arc<MetricStore>,
    pub experiments: Arc<ExperimentManager>,
    pub templates: Arc<TemplateManager>,
    pub environments: Arc<EnvironmentManager>,
    pub models: Arc<ModelRegistry>,
}

impl Services {
    /// Assemble the full service stack around a submitter.
    pub fn new(
        store: Arc<MetaStore>,
        submitter: Arc<dyn Submitter>,
    ) -> Services {
        let monitor = Arc::new(ExperimentMonitor::new());
        let metrics = Arc::new(MetricStore::new());
        Self::with_parts(store, monitor, metrics, submitter)
    }

    pub fn with_parts(
        store: Arc<MetaStore>,
        monitor: Arc<ExperimentMonitor>,
        metrics: Arc<MetricStore>,
        submitter: Arc<dyn Submitter>,
    ) -> Services {
        let experiments = Arc::new(ExperimentManager::new(
            Arc::clone(&store),
            Arc::clone(&monitor),
            submitter,
        ));
        Services {
            templates: Arc::new(TemplateManager::new(Arc::clone(&store))),
            environments: Arc::new(EnvironmentManager::new(Arc::clone(
                &store,
            ))),
            models: Arc::new(ModelRegistry::new(Arc::clone(&store))),
            experiments,
            monitor,
            metrics,
            store,
        }
    }
}

/// The HTTP server.
pub struct Server {
    router: Arc<Router>,
    listener: TcpListener,
    pool: ThreadPool,
    stop: Arc<AtomicBool>,
    local_addr: std::net::SocketAddr,
}

impl Server {
    /// Bind on `127.0.0.1:port` (0 = ephemeral) with routes over
    /// `services`.
    pub fn bind(
        services: Arc<Services>,
        port: u16,
        auth_token: Option<&str>,
    ) -> crate::Result<Server> {
        let mut router = build_router(services);
        if let Some(t) = auth_token {
            router = router.with_auth(t);
        }
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let local_addr = listener.local_addr()?;
        Ok(Server {
            router: Arc::new(router),
            listener,
            pool: ThreadPool::new(8),
            stop: Arc::new(AtomicBool::new(false)),
            local_addr,
        })
    }

    pub fn port(&self) -> u16 {
        self.local_addr.port()
    }

    /// Handle for stopping the accept loop from another thread.
    pub fn stopper(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Run the accept loop until stopped (blocking).
    pub fn serve(&self) -> crate::Result<()> {
        crate::info!("httpd", "listening on {}", self.local_addr);
        self.listener.set_nonblocking(false)?;
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::Relaxed) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let router = Arc::clone(&self.router);
                    self.pool.execute(move || handle(&router, stream));
                }
                Err(e) => {
                    crate::warnlog!("httpd", "accept error: {e}");
                }
            }
        }
        Ok(())
    }

    /// Serve on a background thread; returns a join handle. Stop by
    /// setting `stopper()` and making one dummy connection.
    pub fn serve_background(self: Arc<Self>) -> std::thread::JoinHandle<()> {
        std::thread::Builder::new()
            .name("submarine-httpd".into())
            .spawn(move || {
                let _ = self.serve();
            })
            .expect("spawn httpd thread")
    }
}

fn handle(router: &Router, stream: TcpStream) {
    let peer = stream.peer_addr().ok();
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_secs(10)));
    let response = match Request::read_from(&stream) {
        Ok(req) => {
            let resp = router.dispatch(&req);
            crate::debuglog!(
                "httpd",
                "{} {} -> {} ({:?})",
                req.method,
                req.path,
                resp.status,
                peer
            );
            resp
        }
        Err(e) => Response::error(400, &e.to_string()),
    };
    let _ = response.write_to(&stream);
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Build the v1 REST routes (mirrors Apache Submarine's API surface).
pub fn build_router(s: Arc<Services>) -> Router {
    let mut r = Router::new();

    // ---- health / version
    r.add("GET", "/api/v1/cluster", |_, _| {
        Response::ok_result(
            Json::obj()
                .set("version", Json::Str(crate::version().into()))
                .set("status", Json::Str("RUNNING".into())),
        )
    });

    // ---- experiments
    {
        let s = Arc::clone(&s);
        r.add("POST", "/api/v1/experiment", move |req, _| {
            match req
                .json()
                .and_then(|j| ExperimentSpec::from_json(&j))
                .and_then(|spec| s.experiments.submit(&spec))
            {
                Ok(id) => Response::ok_result(
                    Json::obj().set("experimentId", Json::Str(id)),
                ),
                Err(e) => Response::from_err(&e),
            }
        });
    }
    {
        let s = Arc::clone(&s);
        r.add("GET", "/api/v1/experiment", move |_, _| {
            let list: Vec<Json> = s
                .experiments
                .list()
                .into_iter()
                .map(|(id, st)| {
                    Json::obj()
                        .set("experimentId", Json::Str(id))
                        .set("status", Json::Str(st.as_str().into()))
                })
                .collect();
            Response::ok_result(Json::Arr(list))
        });
    }
    {
        let s = Arc::clone(&s);
        r.add("GET", "/api/v1/experiment/:id", move |_, p| {
            match s.experiments.get(&p["id"]) {
                Ok(doc) => Response::ok_result(doc),
                Err(e) => Response::from_err(&e),
            }
        });
    }
    {
        let s = Arc::clone(&s);
        r.add("DELETE", "/api/v1/experiment/:id", move |_, p| {
            match s
                .experiments
                .kill(&p["id"])
                .and_then(|_| s.experiments.delete(&p["id"]))
            {
                Ok(()) => Response::ok_result(Json::Bool(true)),
                Err(e) => Response::from_err(&e),
            }
        });
    }
    {
        let s = Arc::clone(&s);
        r.add("POST", "/api/v1/experiment/:id/kill", move |_, p| {
            match s.experiments.kill(&p["id"]) {
                Ok(()) => Response::ok_result(Json::Bool(true)),
                Err(e) => Response::from_err(&e),
            }
        });
    }
    {
        let s = Arc::clone(&s);
        r.add("GET", "/api/v1/experiment/:id/metrics", move |req, p| {
            let metric = req
                .query
                .get("metric")
                .cloned()
                .unwrap_or_else(|| "loss".to_string());
            let series = s.metrics.series(&p["id"], &metric);
            let points: Vec<Json> = series
                .iter()
                .map(|pt| {
                    Json::obj()
                        .set("step", Json::Num(pt.step as f64))
                        .set("value", Json::Num(pt.value))
                })
                .collect();
            Response::ok_result(Json::Arr(points))
        });
    }

    // ---- templates (paper §3.2.3)
    {
        let s = Arc::clone(&s);
        r.add("POST", "/api/v1/template", move |req, _| {
            match req
                .json()
                .and_then(|j| Template::from_json(&j))
                .and_then(|t| s.templates.register(&t))
            {
                Ok(()) => Response::ok_result(Json::Bool(true)),
                Err(e) => Response::from_err(&e),
            }
        });
    }
    {
        let s = Arc::clone(&s);
        r.add("GET", "/api/v1/template", move |_, _| {
            Response::ok_result(Json::Arr(
                s.templates
                    .list()
                    .into_iter()
                    .map(Json::Str)
                    .collect(),
            ))
        });
    }
    {
        let s = Arc::clone(&s);
        r.add("GET", "/api/v1/template/:name", move |_, p| {
            match s.templates.get(&p["name"]) {
                Ok(t) => Response::ok_result(t.to_json()),
                Err(e) => Response::from_err(&e),
            }
        });
    }
    {
        // "users can run experiments without writing one line of code":
        // POST { "params": {name: value} } -> submitted experiment.
        let s = Arc::clone(&s);
        r.add("POST", "/api/v1/template/:name/submit", move |req, p| {
            let values: BTreeMap<String, String> = match req.json() {
                Ok(j) => j
                    .get("params")
                    .and_then(Json::as_obj)
                    .map(|o| {
                        o.iter()
                            .map(|(k, v)| {
                                (
                                    k.clone(),
                                    match v {
                                        Json::Str(s) => s.clone(),
                                        other => other.dump(),
                                    },
                                )
                            })
                            .collect()
                    })
                    .unwrap_or_default(),
                Err(e) => return Response::from_err(&e),
            };
            match s
                .templates
                .instantiate(&p["name"], &values)
                .and_then(|spec| s.experiments.submit(&spec))
            {
                Ok(id) => Response::ok_result(
                    Json::obj().set("experimentId", Json::Str(id)),
                ),
                Err(e) => Response::from_err(&e),
            }
        });
    }

    // ---- environments (paper §3.2.1)
    {
        let s = Arc::clone(&s);
        r.add("POST", "/api/v1/environment", move |req, _| {
            match req
                .json()
                .and_then(|j| Environment::from_json(&j))
                .and_then(|e| s.environments.register(&e))
            {
                Ok(()) => Response::ok_result(Json::Bool(true)),
                Err(e) => Response::from_err(&e),
            }
        });
    }
    {
        let s = Arc::clone(&s);
        r.add("GET", "/api/v1/environment", move |_, _| {
            Response::ok_result(Json::Arr(
                s.environments
                    .list()
                    .into_iter()
                    .map(Json::Str)
                    .collect(),
            ))
        });
    }
    {
        let s = Arc::clone(&s);
        r.add("GET", "/api/v1/environment/:name", move |_, p| {
            match s.environments.get(&p["name"]) {
                Ok(env) => {
                    let lock = s
                        .environments
                        .lock_of(&p["name"])
                        .unwrap_or_default();
                    Response::ok_result(env.to_json().set(
                        "lock",
                        Json::Arr(
                            lock.into_iter().map(Json::Str).collect(),
                        ),
                    ))
                }
                Err(e) => Response::from_err(&e),
            }
        });
    }

    // ---- models (paper §4.2)
    {
        let s = Arc::clone(&s);
        r.add("GET", "/api/v1/model/:name", move |_, p| {
            let versions = s.models.versions(&p["name"]);
            if versions.is_empty() {
                return Response::error(
                    404,
                    &format!("model {} not found", p["name"]),
                );
            }
            Response::ok_result(Json::Arr(
                versions
                    .iter()
                    .map(|m| {
                        Json::obj()
                            .set(
                                "version",
                                Json::Num(m.version as f64),
                            )
                            .set(
                                "stage",
                                Json::Str(m.stage.as_str().into()),
                            )
                            .set(
                                "experimentId",
                                Json::Str(m.experiment_id.clone()),
                            )
                    })
                    .collect(),
            ))
        });
    }

    r
}

#[cfg(test)]
mod tests {
    use super::*;

    struct NullSubmitter;
    impl Submitter for NullSubmitter {
        fn name(&self) -> &'static str {
            "null"
        }
        fn submit(&self, _: &str, _: &ExperimentSpec) -> crate::Result<()> {
            Ok(())
        }
        fn kill(&self, _: &str) -> crate::Result<()> {
            Ok(())
        }
    }

    fn services() -> Arc<Services> {
        Arc::new(Services::new(
            Arc::new(MetaStore::in_memory()),
            Arc::new(NullSubmitter),
        ))
    }

    fn dispatch(
        router: &Router,
        method: &str,
        path: &str,
        body: &str,
    ) -> (u16, Json) {
        let req = Request {
            method: method.into(),
            path: path.into(),
            query: BTreeMap::new(),
            headers: BTreeMap::new(),
            body: body.as_bytes().to_vec(),
        };
        let resp = router.dispatch(&req);
        let j = Json::parse(
            std::str::from_utf8(&resp.body).unwrap_or("null"),
        )
        .unwrap_or(Json::Null);
        (resp.status, j)
    }

    const SPEC: &str = r#"{"meta":{"name":"mnist"},
        "spec":{"Worker":{"replicas":1,"resources":"cpu=1"}}}"#;

    #[test]
    fn experiment_crud_over_router() {
        let r = build_router(services());
        let (st, j) = dispatch(&r, "POST", "/api/v1/experiment", SPEC);
        assert_eq!(st, 200);
        let id = j
            .at(&["result", "experimentId"])
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        let (st, j) =
            dispatch(&r, "GET", &format!("/api/v1/experiment/{id}"), "");
        assert_eq!(st, 200);
        assert_eq!(
            j.at(&["result", "status"]).unwrap().as_str(),
            Some("Accepted")
        );
        let (st, _) = dispatch(&r, "GET", "/api/v1/experiment", "");
        assert_eq!(st, 200);
        let (st, _) = dispatch(
            &r,
            "POST",
            &format!("/api/v1/experiment/{id}/kill"),
            "",
        );
        assert_eq!(st, 200);
        let (st, j) = dispatch(
            &r,
            "DELETE",
            &format!("/api/v1/experiment/{id}"),
            "",
        );
        assert_eq!(st, 200, "{j:?}");
    }

    #[test]
    fn bad_spec_is_400() {
        let r = build_router(services());
        let (st, _) = dispatch(&r, "POST", "/api/v1/experiment", "{}");
        assert_eq!(st, 400);
        let (st, _) =
            dispatch(&r, "POST", "/api/v1/experiment", "not json");
        assert_eq!(st, 400);
    }

    #[test]
    fn template_register_and_submit() {
        let r = build_router(services());
        let tpl = crate::template::tf_mnist_template().to_json().dump();
        let (st, _) = dispatch(&r, "POST", "/api/v1/template", &tpl);
        assert_eq!(st, 200);
        let (st, j) = dispatch(
            &r,
            "POST",
            "/api/v1/template/tf-mnist-template/submit",
            r#"{"params":{"learning_rate":"0.01","batch_size":"64"}}"#,
        );
        assert_eq!(st, 200, "{j:?}");
        assert!(j.at(&["result", "experimentId"]).is_some());
    }

    #[test]
    fn environment_register_and_lock() {
        let r = build_router(services());
        let (st, _) = dispatch(
            &r,
            "POST",
            "/api/v1/environment",
            r#"{"name":"tf","image":"submarine:tf",
                "dependencies":["tensorflow>=2.0"]}"#,
        );
        assert_eq!(st, 200);
        let (st, j) =
            dispatch(&r, "GET", "/api/v1/environment/tf", "");
        assert_eq!(st, 200);
        let lock = j.at(&["result", "lock"]).unwrap().as_arr().unwrap();
        assert!(!lock.is_empty());
    }

    #[test]
    fn end_to_end_over_tcp() {
        let srv =
            Arc::new(Server::bind(services(), 0, None).unwrap());
        let port = srv.port();
        let stop = srv.stopper();
        let handle = Arc::clone(&srv).serve_background();
        // real HTTP round trip
        let mut stream =
            TcpStream::connect(("127.0.0.1", port)).unwrap();
        use std::io::{Read, Write};
        write!(stream, "GET /api/v1/cluster HTTP/1.1\r\nhost: x\r\n\r\n")
            .unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert!(buf.contains("200 OK"), "{buf}");
        assert!(buf.contains("RUNNING"));
        // shutdown
        stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(("127.0.0.1", port));
        handle.join().unwrap();
    }
}
