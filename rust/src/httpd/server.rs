//! The Submarine server (paper Fig. 1 control plane): wires every core
//! service behind the REST API and serves it from a **bounded worker
//! pool** fed by the accept loop (ISSUE 5; the previous design spawned
//! one OS thread per connection). Beyond [`MAX_CONNECTIONS`] live
//! connections, new ones are shed with 503 rather than queued.
//!
//! Connections are HTTP/1.1 keep-alive. A pool worker serves a
//! connection's requests back-to-back while data keeps arriving; a
//! connection that goes quiet is *parked* back onto the queue so the
//! worker can serve others, and resumes on a later slice (workers
//! multiplex idle connections instead of pinning a thread each). The
//! two long-lived request shapes — `?watch=1` long-polls and
//! `&stream=1` chunked streams — migrate off the pool onto dedicated
//! threads the moment they are recognized, so parked watchers can
//! never starve request workers. Each connection owns a reusable read
//! buffer (its `BufReader`) and write buffer: a framed response is
//! assembled once and hits the socket as a single `write`.

use super::http::{Request, Response};
use super::router::{envelope_of_path, error_json, Router};
use super::v2::{build_api, ApiConfig};
use crate::analysis::lock_order::LockRank;
use crate::analysis::tracker;
use crate::environment::EnvironmentManager;
use crate::experiment::manager::ExperimentManager;
use crate::experiment::monitor::ExperimentMonitor;
use crate::model::ModelRegistry;
use crate::orchestrator::Submitter;
use crate::storage::{MetaStore, MetricStore};
use crate::template::TemplateManager;
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// All core services (paper §3.2: "Submarine server consists of several
/// core services"). Examples/tests may use this directly without HTTP.
pub struct Services {
    pub store: Arc<MetaStore>,
    pub monitor: Arc<ExperimentMonitor>,
    pub metrics: Arc<MetricStore>,
    pub experiments: Arc<ExperimentManager>,
    pub templates: Arc<TemplateManager>,
    pub environments: Arc<EnvironmentManager>,
    pub models: Arc<ModelRegistry>,
    /// Background scheduler loop, present when the stack was assembled
    /// over the simulated YARN/K8s pipeline (`with_sim_executor`). Feeds
    /// the extended `GET /cluster` payload; dropping `Services` stops
    /// the loop.
    pub executor: Option<Arc<crate::orchestrator::engine::ExecutionEngine>>,
}

impl Services {
    /// Assemble the full service stack around a submitter.
    pub fn new(
        store: Arc<MetaStore>,
        submitter: Arc<dyn Submitter>,
    ) -> Services {
        let monitor = Arc::new(ExperimentMonitor::new());
        let metrics = Arc::new(MetricStore::new());
        Self::with_parts(store, monitor, metrics, submitter)
    }

    pub fn with_parts(
        store: Arc<MetaStore>,
        monitor: Arc<ExperimentMonitor>,
        metrics: Arc<MetricStore>,
        submitter: Arc<dyn Submitter>,
    ) -> Services {
        let experiments = Arc::new(ExperimentManager::new(
            Arc::clone(&store),
            Arc::clone(&monitor),
            submitter,
        ));
        // Mirror monitor-derived statuses into the experiment docs so
        // the persisted status (and its secondary index, which backs
        // the v2 `?status=` filter) tracks the live lifecycle.
        let status_sink = Arc::clone(&store);
        monitor.set_observer(Box::new(move |id, st| {
            crate::experiment::manager::persist_status(
                &status_sink,
                id,
                st,
            )
        }));
        Services {
            templates: Arc::new(TemplateManager::new(Arc::clone(&store))),
            environments: Arc::new(EnvironmentManager::new(Arc::clone(
                &store,
            ))),
            models: Arc::new(ModelRegistry::new(Arc::clone(&store))),
            experiments,
            monitor,
            metrics,
            store,
            executor: None,
        }
    }

    /// Assemble the full stack over the simulated execution pipeline:
    /// experiments POSTed to the API are gang-scheduled onto the cluster
    /// sim by a background loop and run to tracked completion (the
    /// paper's Fig. 4 serving path). The submitter must already carry
    /// the monitor it reports into.
    pub fn with_sim_executor(
        store: Arc<MetaStore>,
        submitter: Arc<crate::orchestrator::sim_submitter::SimSubmitter>,
        metrics: Arc<MetricStore>,
        cfg: crate::orchestrator::engine::EngineConfig,
    ) -> Services {
        let monitor = Arc::clone(submitter.monitor());
        let mut services = Services::with_parts(
            store,
            monitor,
            metrics,
            Arc::clone(&submitter) as Arc<dyn Submitter>,
        );
        services.executor = Some(
            crate::orchestrator::engine::ExecutionEngine::start(
                submitter, cfg,
            ),
        );
        services
    }
}

/// Hard cap on requests served per connection (bounds one client's hold
/// on the pool).
const MAX_KEEPALIVE_REQUESTS: usize = 1024;

/// Default cap on concurrent connections; beyond it, new connections
/// are shed immediately with 503 rather than queued behind busy ones.
const MAX_CONNECTIONS: usize = 256;

/// How long a keep-alive connection may sit idle between requests
/// before the server reclaims it.
const IDLE_TIMEOUT: Duration = Duration::from_secs(5);

/// How long a worker lingers on a connection waiting for its next
/// request before parking it back onto the queue. Small enough that a
/// worker stuck behind quiet connections frees up quickly; large
/// enough that a request/response client usually stays on one worker.
const PARK_POLL: Duration = Duration::from_millis(20);

/// Sizing and shedding knobs for [`Server`] (tests pin them; the CLI
/// uses the defaults).
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Request-worker pool size. `None` resolves `SUBMARINE_HTTP_WORKERS`
    /// first (CI pins it to exercise saturation deterministically on
    /// few-core runners), then `available_parallelism`.
    pub workers: Option<usize>,
    /// Live-connection cap above which new connections get 503.
    pub max_connections: usize,
}

impl Default for ServerOptions {
    fn default() -> ServerOptions {
        ServerOptions {
            workers: None,
            max_connections: MAX_CONNECTIONS,
        }
    }
}

fn default_workers() -> usize {
    std::env::var("SUBMARINE_HTTP_WORKERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(8)
                .clamp(4, 32)
        })
}

/// The HTTP server.
pub struct Server {
    router: Arc<Router>,
    listener: TcpListener,
    active: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
    local_addr: std::net::SocketAddr,
    opts: ServerOptions,
}

/// Decrements the live-connection count even if a handler panics.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

impl Server {
    /// Bind on `127.0.0.1:port` (0 = ephemeral) with routes over
    /// `services`.
    pub fn bind(
        services: Arc<Services>,
        port: u16,
        auth_token: Option<&str>,
    ) -> crate::Result<Server> {
        Self::bind_with_config(
            services,
            port,
            &ApiConfig {
                auth_token: auth_token.map(str::to_string),
                rate_limit: None,
            },
        )
    }

    /// Bind with the full API configuration (auth + rate limiting).
    pub fn bind_with_config(
        services: Arc<Services>,
        port: u16,
        cfg: &ApiConfig,
    ) -> crate::Result<Server> {
        Self::bind_with_options(services, port, cfg, ServerOptions::default())
    }

    /// Bind with explicit pool sizing (saturation tests pin `workers`
    /// and `max_connections` instead of relying on the machine shape).
    pub fn bind_with_options(
        services: Arc<Services>,
        port: u16,
        cfg: &ApiConfig,
        opts: ServerOptions,
    ) -> crate::Result<Server> {
        let router = build_api(services, cfg);
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let local_addr = listener.local_addr()?;
        Ok(Server {
            router: Arc::new(router),
            listener,
            active: Arc::new(AtomicUsize::new(0)),
            stop: Arc::new(AtomicBool::new(false)),
            local_addr,
            opts,
        })
    }

    pub fn port(&self) -> u16 {
        self.local_addr.port()
    }

    /// Handle for stopping the accept loop from another thread.
    pub fn stopper(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Run the accept loop until stopped (blocking): spin up the worker
    /// pool, then feed it accepted connections.
    pub fn serve(&self) -> crate::Result<()> {
        let workers = self.opts.workers.unwrap_or_else(default_workers);
        crate::info!(
            "httpd",
            "listening on {} ({workers} request workers)",
            self.local_addr
        );
        self.listener.set_nonblocking(false)?;
        let queue = Arc::new(ConnQueue::default());
        let mut pool = Vec::with_capacity(workers);
        for i in 0..workers {
            let worker_queue = Arc::clone(&queue);
            let router = Arc::clone(&self.router);
            let spawned = std::thread::Builder::new()
                .name(format!("submarine-worker-{i}"))
                .spawn(move || worker_loop(&router, &worker_queue));
            match spawned {
                Ok(h) => pool.push(h),
                Err(e) => {
                    // unwind the partial pool before reporting failure
                    queue.close();
                    for h in pool {
                        let _ = h.join();
                    }
                    return Err(crate::SubmarineError::Runtime(
                        format!("spawning request worker {i}: {e}"),
                    ));
                }
            }
        }
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::Relaxed) {
                break;
            }
            match conn {
                Ok(stream) => {
                    if self.active.load(Ordering::Relaxed)
                        >= self.opts.max_connections
                    {
                        // Shed instead of queueing behind busy
                        // connections: a prompt 503 beats an unbounded
                        // backlog. The lingering close runs on its own
                        // short-lived thread so a slow peer cannot
                        // stall the accept loop at exactly the moment
                        // the server is overloaded.
                        let _ = std::thread::Builder::new()
                            .name("submarine-shed".into())
                            .spawn(move || shed_connection(stream));
                        continue;
                    }
                    self.active.fetch_add(1, Ordering::Relaxed);
                    let guard = ConnGuard(Arc::clone(&self.active));
                    queue.push(Conn::new(stream, guard));
                }
                Err(e) => {
                    crate::warnlog!("httpd", "accept error: {e}");
                }
            }
        }
        queue.close();
        for h in pool {
            let _ = h.join();
        }
        Ok(())
    }

    /// Serve on a background thread; returns a join handle. Stop by
    /// setting `stopper()` and making one dummy connection.
    pub fn serve_background(self: Arc<Self>) -> std::thread::JoinHandle<()> {
        std::thread::Builder::new()
            .name("submarine-httpd".into())
            .spawn(move || {
                let _ = self.serve();
            })
            .expect("spawn httpd thread")
    }
}

/// Refuse a connection with 503 and a lingering close. Writing first
/// and then draining (bounded) before closing keeps the kernel from
/// sending RST over unread input, which would discard the 503 in
/// flight. Transport-layer errors like this one use the flat v1 error
/// envelope: the request is never parsed, so the path (and thus the
/// API version) is unknown.
fn shed_connection(stream: TcpStream) {
    use std::io::Read;
    let _ = stream.set_read_timeout(Some(
        std::time::Duration::from_millis(250),
    ));
    let resp = Response::error(503, "server at connection capacity");
    let _ = resp.write_to_opts(&stream, false, false);
    let _ = stream.shutdown(std::net::Shutdown::Write);
    // bounded drain: at most ~64KB or ~8 read timeouts, then close
    let mut sink = [0u8; 8192];
    for _ in 0..8 {
        match (&stream).read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

// ------------------------------------------------------- connection pool

/// Connections waiting for a worker, in two lanes: `fresh` holds
/// connections with work expected *now* (new accepts, and conns that
/// just finished a slice with data pending), `parked` holds quiet
/// keep-alive connections being revisited round-robin. Workers drain
/// `fresh` first, so a new request never queues behind the 20ms
/// readiness polls of K idle connections — idle-conn polling only
/// happens when there is nothing better to do.
#[derive(Default)]
struct Lanes {
    fresh: VecDeque<Conn>,
    parked: VecDeque<Conn>,
}

#[derive(Default)]
struct ConnQueue {
    q: Mutex<Lanes>,
    cv: Condvar,
    stopping: AtomicBool,
}

impl ConnQueue {
    /// Lane guard + its lock-order token. Recovers from poisoning: a
    /// worker panicking mid-push must not brick the whole pool.
    fn lanes(&self) -> (MutexGuard<'_, Lanes>, tracker::Held) {
        let held = tracker::acquired(LockRank::ConnQueue, 0);
        (self.q.lock().unwrap_or_else(|e| e.into_inner()), held)
    }

    fn push(&self, conn: Conn) {
        let (mut q, _held) = self.lanes();
        q.fresh.push_back(conn);
        drop(q);
        self.cv.notify_one();
    }

    fn park(&self, conn: Conn) {
        let (mut q, _held) = self.lanes();
        q.parked.push_back(conn);
        drop(q);
        self.cv.notify_one();
    }

    fn pop(&self) -> Option<Conn> {
        let (mut q, _held) = self.lanes();
        loop {
            if self.stopping.load(Ordering::Relaxed) {
                // shutdown: drop whatever is still queued — the
                // sockets close as the queue drains out of scope
                q.fresh.clear();
                q.parked.clear();
                return None;
            }
            if let Some(c) = q.fresh.pop_front() {
                return Some(c);
            }
            if let Some(c) = q.parked.pop_front() {
                return Some(c);
            }
            q = self
                .cv
                .wait(q)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    fn close(&self) {
        self.stopping.store(true, Ordering::Relaxed);
        self.cv.notify_all();
    }
}

/// One live connection and its reusable per-connection buffers: the
/// `BufReader` (read buffer) spans the whole connection so pipelined
/// read-ahead survives parking, and `wbuf` is the write buffer every
/// framed response is assembled into before one `write_all`.
struct Conn {
    reader: BufReader<TcpStream>,
    wbuf: Vec<u8>,
    served: usize,
    idle_since: Instant,
    _guard: ConnGuard,
}

/// What a worker did with its current slice of a connection.
enum Slice {
    /// Connection finished (closed, errored, or request cap reached).
    Done,
    /// Quiet but alive: back onto the queue for a later slice.
    Park(Conn),
    /// Handed off to a dedicated watch thread.
    Migrated,
}

impl Conn {
    fn new(stream: TcpStream, guard: ConnGuard) -> Conn {
        let _ = stream.set_nodelay(true);
        Conn {
            reader: BufReader::new(stream),
            wbuf: Vec::with_capacity(1024),
            served: 0,
            idle_since: Instant::now(),
            _guard: guard,
        }
    }

    fn stream(&self) -> &TcpStream {
        self.reader.get_ref()
    }

    /// Write one response: streams go straight to the socket (each
    /// chunk must flush as it happens); framed responses are built in
    /// the reusable write buffer and sent with a single `write_all`.
    fn write_response(
        &mut self,
        resp: &Response,
        keep: bool,
        head_only: bool,
    ) -> std::io::Result<()> {
        if resp.is_stream() {
            return resp.write_to_opts(self.reader.get_ref(), keep, head_only);
        }
        self.wbuf.clear();
        resp.write_to_opts(&mut self.wbuf, keep, head_only)?;
        let mut stream = self.reader.get_ref();
        stream.write_all(&self.wbuf)
    }

    fn shutdown(&self) {
        let _ = self.stream().shutdown(std::net::Shutdown::Both);
    }
}

/// Request shapes that migrate off the worker pool to a dedicated
/// thread: long-lived watches/streams, and the known-long synchronous
/// handlers (a tune run submits and awaits whole child experiments —
/// minutes of wall time that must not pin a pool worker and
/// head-of-line block every other request).
fn is_long_request(req: &Request) -> bool {
    let flagged = |name: &str| {
        matches!(
            req.query.get(name).map(String::as_str),
            Some("1") | Some("true")
        )
    };
    flagged("watch")
        || flagged("stream")
        || (req.method.eq_ignore_ascii_case("POST")
            && req.path.ends_with("/experiment/tune"))
}

fn worker_loop(router: &Arc<Router>, queue: &Arc<ConnQueue>) {
    while let Some(conn) = queue.pop() {
        match serve_slice(router, conn) {
            Slice::Park(conn) => queue.park(conn),
            Slice::Done | Slice::Migrated => {}
        }
    }
}

/// Serve one slice of a connection: requests back-to-back while data
/// is ready, then park. The park/idle split preserves the previous
/// semantics — "client sent nothing for IDLE_TIMEOUT" closes silently,
/// a timeout *mid-request* answers 408.
fn serve_slice(router: &Arc<Router>, mut conn: Conn) -> Slice {
    // Readiness of the next request, decoupled from the `fill_buf`
    // borrow so the connection itself stays usable in the outcomes.
    enum Ready {
        Eof,
        Data,
        Quiet,
        Dead,
    }
    let _ = conn.stream().set_read_timeout(Some(PARK_POLL));
    loop {
        let ready = match conn.reader.fill_buf() {
            Ok(buf) if buf.is_empty() => Ready::Eof, // clean EOF
            Ok(_) => Ready::Data,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                Ready::Quiet
            }
            Err(_) => Ready::Dead,
        };
        match ready {
            Ready::Data => {}
            Ready::Eof | Ready::Dead => {
                conn.shutdown();
                return Slice::Done;
            }
            Ready::Quiet => {
                if conn.idle_since.elapsed() >= IDLE_TIMEOUT {
                    // routine keep-alive expiry: close silently
                    conn.shutdown();
                    return Slice::Done;
                }
                return Slice::Park(conn);
            }
        }
        // A request is arriving: from here reads may block up to the
        // idle window so a trickled body times out into a 408, not a
        // spurious park.
        let _ = conn.stream().set_read_timeout(Some(IDLE_TIMEOUT));
        match next_request(&mut conn, router) {
            Next::Continue => {
                conn.idle_since = Instant::now();
                let _ = conn.stream().set_read_timeout(Some(PARK_POLL));
            }
            Next::Close => {
                conn.shutdown();
                return Slice::Done;
            }
            Next::Migrate(req) => {
                let router = Arc::clone(router);
                match std::thread::Builder::new()
                    .name("submarine-watch".into())
                    .spawn(move || watch_conn(&router, conn, req))
                {
                    Ok(_) => return Slice::Migrated,
                    Err(_) => {
                        // can't spawn: the closure never ran, so both
                        // conn and req are gone — nothing safe to
                        // recover; the connection closes with them
                        crate::warnlog!(
                            "httpd",
                            "failed to spawn watch thread; dropping \
                             connection"
                        );
                        return Slice::Done;
                    }
                }
            }
        }
    }
}

enum Next {
    /// Response written, keep-alive continues.
    Continue,
    /// Connection is finished (close requested, error, cap).
    Close,
    /// A watch/stream request: hand the connection to a dedicated
    /// thread with this request still pending dispatch.
    Migrate(Request),
}

/// Read and serve exactly one request off the connection.
fn next_request(conn: &mut Conn, router: &Router) -> Next {
    let mut seen_path: Option<String> = None;
    match Request::read_next_tracked(&mut conn.reader, &mut seen_path) {
        Ok(None) => Next::Close, // peer closed between requests
        Ok(Some(req)) => {
            if is_long_request(&req) {
                return Next::Migrate(req);
            }
            dispatch_one(conn, router, &req)
        }
        Err(e) => {
            // The request started arriving but didn't finish in time
            // (trickled body) or didn't parse. The request line may
            // already have revealed which API version the client
            // speaks — answer in that envelope rather than defaulting
            // to the flat v1 shape.
            let timed_out = matches!(
                &e,
                crate::SubmarineError::Io(io) if matches!(
                    io.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                )
            );
            let envelope =
                envelope_of_path(seen_path.as_deref().unwrap_or(""));
            let resp = if timed_out {
                error_json(envelope, 408, "Timeout", "request incomplete")
            } else {
                error_json(envelope, 400, "InvalidSpec", &e.to_string())
            };
            let _ = conn.write_response(&resp, false, false);
            Next::Close
        }
    }
}

/// Dispatch one parsed request and write its response.
fn dispatch_one(conn: &mut Conn, router: &Router, req: &Request) -> Next {
    let resp = router.dispatch(req);
    // A streaming response (watch) owns the socket until it ends and
    // always closes — its length is unframed.
    let keep = req.wants_keep_alive()
        && conn.served + 1 < MAX_KEEPALIVE_REQUESTS
        && !resp.is_stream();
    let head_only = req.method.eq_ignore_ascii_case("HEAD");
    conn.served += 1;
    if conn.write_response(&resp, keep, head_only).is_err() || !keep {
        return Next::Close;
    }
    Next::Continue
}

/// Dedicated lane for long requests (`?watch=1` / `&stream=1` /
/// tune): the first (already parsed) long request dispatches here,
/// and the connection then keeps its own thread for the rest of its
/// life — long-lived parked watchers and long synchronous handlers
/// never occupy a pool worker. Plain requests arriving later on the
/// same connection are served here too.
fn watch_conn(router: &Arc<Router>, mut conn: Conn, first: Request) {
    let _ = conn.stream().set_read_timeout(Some(IDLE_TIMEOUT));
    match dispatch_one(&mut conn, router, &first) {
        Next::Close | Next::Migrate(_) => {
            conn.shutdown();
            return;
        }
        Next::Continue => {}
    }
    loop {
        // Idle window first: separates "client sent nothing" (close
        // silently) from a timeout mid-request (408 inside
        // next_request).
        match conn.reader.fill_buf() {
            Ok(buf) if buf.is_empty() => break, // clean EOF
            Ok(_) => {}
            Err(_) => break, // idle timeout or dead socket
        }
        match next_request(&mut conn, router) {
            Next::Continue => {}
            Next::Close => break,
            // already on a dedicated thread: dispatch in place
            Next::Migrate(req) => {
                match dispatch_one(&mut conn, router, &req) {
                    Next::Continue => {}
                    _ => break,
                }
            }
        }
    }
    conn.shutdown();
}

/// Build the default-config router (v1 compat + v2). Kept for direct
/// router-level use in tests and benches.
pub fn build_router(s: Arc<Services>) -> Router {
    build_api(s, &ApiConfig::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::spec::ExperimentSpec;
    use crate::util::json::Json;
    use std::io::{BufRead, BufReader, Read, Write};

    struct NullSubmitter;
    impl Submitter for NullSubmitter {
        fn name(&self) -> &'static str {
            "null"
        }
        fn submit(&self, _: &str, _: &ExperimentSpec) -> crate::Result<()> {
            Ok(())
        }
        fn kill(&self, _: &str) -> crate::Result<()> {
            Ok(())
        }
    }

    fn services() -> Arc<Services> {
        Arc::new(Services::new(
            Arc::new(MetaStore::in_memory()),
            Arc::new(NullSubmitter),
        ))
    }

    fn start() -> (Arc<Server>, u16, Arc<AtomicBool>,
                   std::thread::JoinHandle<()>) {
        let srv = Arc::new(Server::bind(services(), 0, None).unwrap());
        let port = srv.port();
        let stop = srv.stopper();
        let handle = Arc::clone(&srv).serve_background();
        (srv, port, stop, handle)
    }

    fn shutdown(
        port: u16,
        stop: Arc<AtomicBool>,
        handle: std::thread::JoinHandle<()>,
    ) {
        stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(("127.0.0.1", port));
        handle.join().unwrap();
    }

    /// Read one content-length-framed response off a reused stream.
    fn read_response(
        reader: &mut BufReader<&TcpStream>,
    ) -> (u16, String) {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let status: u16 =
            line.split(' ').nth(1).unwrap().parse().unwrap();
        let mut len = 0usize;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).unwrap();
            let h = h.trim_end().to_ascii_lowercase();
            if h.is_empty() {
                break;
            }
            if let Some(v) = h.strip_prefix("content-length:") {
                len = v.trim().parse().unwrap();
            }
        }
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).unwrap();
        (status, String::from_utf8(body).unwrap())
    }

    #[test]
    fn end_to_end_over_tcp() {
        let (_srv, port, stop, handle) = start();
        let mut stream =
            TcpStream::connect(("127.0.0.1", port)).unwrap();
        write!(
            stream,
            "GET /api/v1/cluster HTTP/1.1\r\nhost: x\r\nconnection: close\r\n\r\n"
        )
        .unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert!(buf.contains("200 OK"), "{buf}");
        assert!(buf.contains("RUNNING"));
        assert!(buf.contains("connection: close"));
        shutdown(port, stop, handle);
    }

    #[test]
    fn keep_alive_serves_many_requests_per_connection() {
        let (_srv, port, stop, handle) = start();
        let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(&stream);
        for i in 0..5 {
            write!(
                &stream,
                "GET /api/v2/cluster HTTP/1.1\r\nhost: x\r\n\r\n"
            )
            .unwrap();
            let (status, body) = read_response(&mut reader);
            assert_eq!(status, 200, "request {i}: {body}");
            assert!(body.contains("RUNNING"));
        }
        drop(reader);
        drop(stream);
        shutdown(port, stop, handle);
    }

    #[test]
    fn head_is_answered_without_body() {
        let (_srv, port, stop, handle) = start();
        let mut stream =
            TcpStream::connect(("127.0.0.1", port)).unwrap();
        write!(
            stream,
            "HEAD /api/v1/cluster HTTP/1.1\r\nhost: x\r\nconnection: close\r\n\r\n"
        )
        .unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert!(buf.contains("200 OK"), "{buf}");
        // content-length advertised, but no body bytes follow
        assert!(buf.contains("content-length:"));
        assert!(buf.trim_end().ends_with("connection: close"), "{buf}");
        shutdown(port, stop, handle);
    }

    #[test]
    fn unknown_method_gets_allow_header_over_tcp() {
        let (_srv, port, stop, handle) = start();
        let mut stream =
            TcpStream::connect(("127.0.0.1", port)).unwrap();
        write!(
            stream,
            "PATCH /api/v1/cluster HTTP/1.1\r\nhost: x\r\nconnection: close\r\n\r\n"
        )
        .unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert!(buf.contains("405"), "{buf}");
        assert!(buf.contains("Allow: GET, HEAD"), "{buf}");
        shutdown(port, stop, handle);
    }

    #[test]
    fn router_smoke_over_build_router() {
        let r = build_router(services());
        let resp =
            r.dispatch(&Request::synthetic("GET", "/api/v2/cluster"));
        assert_eq!(resp.status, 200);
        let j = Json::parse(
            std::str::from_utf8(&resp.body).unwrap(),
        )
        .unwrap();
        assert_eq!(j.num_field("code"), Some(200.0));
    }
}
