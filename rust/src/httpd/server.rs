//! The Submarine server (paper Fig. 1 control plane): wires every core
//! service behind the REST API and serves it from an **epoll readiness
//! reactor** (ISSUE 7; previous designs spawned one OS thread per
//! connection, then multiplexed a bounded pool over blocking sockets).
//! A single reactor thread owns every connection and drives the
//! per-connection state machine in [`super::conn`]; complete requests
//! are executed on a small worker pool and written back on
//! writability. Beyond [`ServerOptions::max_connections`] live
//! connections, new ones are shed with 503 rather than queued.
//!
//! Connections are HTTP/1.1 keep-alive with partial-read /
//! partial-write resumption over reusable per-connection buffers.
//! `?watch=1` long-polls and `&stream=1` chunked streams park in the
//! reactor as cheap tail entries (no thread each); only the
//! long-running synchronous `POST .../experiment/tune` handler still
//! migrates to a dedicated thread. See [`super::reactor`] for the
//! event-loop internals.

use super::http::{Request, Response};
use super::reactor::Reactor;
use super::router::Router;
use super::v2::{build_api, ApiConfig};
use crate::environment::EnvironmentManager;
use crate::experiment::manager::ExperimentManager;
use crate::experiment::monitor::ExperimentMonitor;
use crate::model::ModelRegistry;
use crate::orchestrator::Submitter;
use crate::storage::{MetaStore, MetricStore};
use crate::template::TemplateManager;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// All core services (paper §3.2: "Submarine server consists of several
/// core services"). Examples/tests may use this directly without HTTP.
pub struct Services {
    pub store: Arc<MetaStore>,
    pub monitor: Arc<ExperimentMonitor>,
    pub metrics: Arc<MetricStore>,
    pub experiments: Arc<ExperimentManager>,
    pub templates: Arc<TemplateManager>,
    pub environments: Arc<EnvironmentManager>,
    pub models: Arc<ModelRegistry>,
    /// Online inference tier over the registry: per-model micro-batch
    /// queues, canary routing, `/api/v2/serve` handlers.
    pub serving: Arc<crate::serving::ServingLayer>,
    /// Background scheduler loop, present when the stack was assembled
    /// over the simulated YARN/K8s pipeline (`with_sim_executor`). Feeds
    /// the extended `GET /cluster` payload; dropping `Services` stops
    /// the loop.
    pub executor: Option<Arc<crate::orchestrator::engine::ExecutionEngine>>,
}

impl Services {
    /// Assemble the full service stack around a submitter.
    pub fn new(
        store: Arc<MetaStore>,
        submitter: Arc<dyn Submitter>,
    ) -> Services {
        let monitor = Arc::new(ExperimentMonitor::new());
        let metrics = Arc::new(MetricStore::new());
        Self::with_parts(store, monitor, metrics, submitter)
    }

    pub fn with_parts(
        store: Arc<MetaStore>,
        monitor: Arc<ExperimentMonitor>,
        metrics: Arc<MetricStore>,
        submitter: Arc<dyn Submitter>,
    ) -> Services {
        let experiments = Arc::new(ExperimentManager::new(
            Arc::clone(&store),
            Arc::clone(&monitor),
            submitter,
        ));
        // Mirror monitor-derived statuses into the experiment docs so
        // the persisted status (and its secondary index, which backs
        // the v2 `?status=` filter) tracks the live lifecycle.
        let status_sink = Arc::clone(&store);
        monitor.set_observer(Box::new(move |id, st| {
            crate::experiment::manager::persist_status(
                &status_sink,
                id,
                st,
            )
        }));
        let models = Arc::new(ModelRegistry::new(Arc::clone(&store)));
        let serving = Arc::new(crate::serving::ServingLayer::new(
            Arc::clone(&store),
            Arc::clone(&metrics),
            Arc::clone(&models),
        ));
        Services {
            templates: Arc::new(TemplateManager::new(Arc::clone(&store))),
            environments: Arc::new(EnvironmentManager::new(Arc::clone(
                &store,
            ))),
            models,
            serving,
            experiments,
            monitor,
            metrics,
            store,
            executor: None,
        }
    }

    /// Assemble the full stack over the simulated execution pipeline:
    /// experiments POSTed to the API are gang-scheduled onto the cluster
    /// sim by a background loop and run to tracked completion (the
    /// paper's Fig. 4 serving path). The submitter must already carry
    /// the monitor it reports into.
    pub fn with_sim_executor(
        store: Arc<MetaStore>,
        submitter: Arc<crate::orchestrator::sim_submitter::SimSubmitter>,
        metrics: Arc<MetricStore>,
        cfg: crate::orchestrator::engine::EngineConfig,
    ) -> Services {
        let monitor = Arc::clone(submitter.monitor());
        let mut services = Services::with_parts(
            store,
            monitor,
            metrics,
            Arc::clone(&submitter) as Arc<dyn Submitter>,
        );
        services.executor = Some(
            crate::orchestrator::engine::ExecutionEngine::start(
                submitter, cfg,
            ),
        );
        services
    }
}

/// Hard cap on requests served per connection (bounds one client's hold
/// on the pool).
pub(crate) const MAX_KEEPALIVE_REQUESTS: usize = 1024;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

/// Sizing and shedding knobs for [`Server`] (tests pin them; the CLI
/// maps flags onto them; the env defaults below cover everything else).
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Request-worker pool size. `None` resolves `SUBMARINE_HTTP_WORKERS`
    /// first (CI pins it to exercise saturation deterministically on
    /// few-core runners), then `available_parallelism`.
    pub workers: Option<usize>,
    /// Live-connection cap above which new connections get 503.
    /// Default `SUBMARINE_HTTP_MAX_CONNS`, else 10240 — parked watch
    /// streams are cheap reactor entries now, so the cap is an fd
    /// budget, not a thread budget.
    pub max_connections: usize,
    /// Idle window: keep-alive connections quiet this long are
    /// reaped; a request trickling slower than this gets 408.
    /// Default `SUBMARINE_HTTP_IDLE_MS`, else 5000.
    pub idle_timeout: Duration,
    /// Per-connection outbound buffer cap. A parked watch consumer
    /// that stops reading while events accumulate past this many
    /// buffered bytes is evicted. Default `SUBMARINE_HTTP_WBUF_CAP`,
    /// else 1 MiB.
    pub write_buf_cap: usize,
}

impl Default for ServerOptions {
    fn default() -> ServerOptions {
        ServerOptions {
            workers: None,
            max_connections: env_usize(
                "SUBMARINE_HTTP_MAX_CONNS",
                10_240,
            ),
            idle_timeout: Duration::from_millis(env_usize(
                "SUBMARINE_HTTP_IDLE_MS",
                5_000,
            ) as u64),
            write_buf_cap: env_usize(
                "SUBMARINE_HTTP_WBUF_CAP",
                1 << 20,
            ),
        }
    }
}

fn default_workers() -> usize {
    std::env::var("SUBMARINE_HTTP_WORKERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(8)
                .clamp(4, 32)
        })
}

/// The HTTP server.
pub struct Server {
    router: Arc<Router>,
    listener: TcpListener,
    store: Arc<MetaStore>,
    metrics: Arc<MetricStore>,
    serving: Arc<crate::serving::ServingLayer>,
    active: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
    local_addr: std::net::SocketAddr,
    opts: ServerOptions,
}

/// Decrements the live-connection count even if a handler panics.
pub(crate) struct ConnGuard {
    pub(crate) active: Arc<AtomicUsize>,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.active.fetch_sub(1, Ordering::Relaxed);
    }
}

impl Server {
    /// Bind on `127.0.0.1:port` (0 = ephemeral) with routes over
    /// `services`.
    pub fn bind(
        services: Arc<Services>,
        port: u16,
        auth_token: Option<&str>,
    ) -> crate::Result<Server> {
        Self::bind_with_config(
            services,
            port,
            &ApiConfig {
                auth_token: auth_token.map(str::to_string),
                rate_limit: None,
            },
        )
    }

    /// Bind with the full API configuration (auth + rate limiting).
    pub fn bind_with_config(
        services: Arc<Services>,
        port: u16,
        cfg: &ApiConfig,
    ) -> crate::Result<Server> {
        Self::bind_with_options(services, port, cfg, ServerOptions::default())
    }

    /// Bind with explicit reactor sizing (saturation tests pin
    /// `workers` and `max_connections` instead of relying on the
    /// machine shape).
    pub fn bind_with_options(
        services: Arc<Services>,
        port: u16,
        cfg: &ApiConfig,
        opts: ServerOptions,
    ) -> crate::Result<Server> {
        // the reactor's feed pump needs the store after `services`
        // moves into the router
        let store = Arc::clone(&services.store);
        // the reactor sweep publishes doorbell failures here
        let metrics = Arc::clone(&services.metrics);
        // the reactor installs its doorbell into the serving tier so
        // batch fan-outs step freshly resolved predict tails promptly
        let serving = Arc::clone(&services.serving);
        let router = build_api(services, cfg);
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let local_addr = listener.local_addr()?;
        Ok(Server {
            router: Arc::new(router),
            listener,
            store,
            metrics,
            serving,
            active: Arc::new(AtomicUsize::new(0)),
            stop: Arc::new(AtomicBool::new(false)),
            local_addr,
            opts,
        })
    }

    pub fn port(&self) -> u16 {
        self.local_addr.port()
    }

    /// Handle for stopping the reactor from another thread (set it,
    /// then make one dummy connection to wake the epoll wait).
    pub fn stopper(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Run the reactor until stopped (blocking).
    pub fn serve(&self) -> crate::Result<()> {
        let workers = self.opts.workers.unwrap_or_else(default_workers);
        crate::info!(
            "httpd",
            "listening on {} (epoll reactor, {workers} request workers)",
            self.local_addr
        );
        let reactor = Reactor::new(
            self.listener.try_clone()?,
            Arc::clone(&self.router),
            Arc::clone(&self.store),
            Arc::clone(&self.metrics),
            Arc::clone(&self.serving),
            Arc::clone(&self.active),
            Arc::clone(&self.stop),
            workers,
            self.opts.max_connections,
            self.opts.idle_timeout,
            self.opts.write_buf_cap,
        )?;
        reactor.run()
    }

    /// Serve on a background thread; returns a join handle. Stop by
    /// setting `stopper()` and making one dummy connection.
    pub fn serve_background(self: Arc<Self>) -> std::thread::JoinHandle<()> {
        std::thread::Builder::new()
            .name("submarine-httpd".into())
            .spawn(move || {
                let _ = self.serve();
            })
            .expect("spawn httpd thread")
    }
}

/// Refuse a connection with 503 and a lingering close. Writing first
/// and then draining (bounded) before closing keeps the kernel from
/// sending RST over unread input, which would discard the 503 in
/// flight. Transport-layer errors like this one use the flat v1 error
/// envelope: the request is never parsed, so the path (and thus the
/// API version) is unknown. Runs on a short-lived thread with the
/// socket still in blocking mode (accepted sockets do not inherit the
/// listener's nonblocking flag on Linux), so the read timeout below
/// bounds the drain.
pub(crate) fn shed_connection(stream: TcpStream) {
    use std::io::Read;
    let _ = stream.set_read_timeout(Some(
        std::time::Duration::from_millis(250),
    ));
    let resp = Response::error(503, "server at connection capacity");
    let _ = resp.write_to_opts(&stream, false, false);
    let _ = stream.shutdown(std::net::Shutdown::Write);
    // bounded drain: at most ~64KB or ~8 read timeouts, then close
    let mut sink = [0u8; 8192];
    for _ in 0..8 {
        match (&stream).read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Build the default-config router (v1 compat + v2). Kept for direct
/// router-level use in tests and benches.
pub fn build_router(s: Arc<Services>) -> Router {
    build_api(s, &ApiConfig::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::spec::ExperimentSpec;
    use crate::util::json::Json;
    use std::io::{BufRead, BufReader, Read, Write};

    struct NullSubmitter;
    impl Submitter for NullSubmitter {
        fn name(&self) -> &'static str {
            "null"
        }
        fn submit(&self, _: &str, _: &ExperimentSpec) -> crate::Result<()> {
            Ok(())
        }
        fn kill(&self, _: &str) -> crate::Result<()> {
            Ok(())
        }
    }

    fn services() -> Arc<Services> {
        Arc::new(Services::new(
            Arc::new(MetaStore::in_memory()),
            Arc::new(NullSubmitter),
        ))
    }

    fn start() -> (Arc<Server>, u16, Arc<AtomicBool>,
                   std::thread::JoinHandle<()>) {
        let srv = Arc::new(Server::bind(services(), 0, None).unwrap());
        let port = srv.port();
        let stop = srv.stopper();
        let handle = Arc::clone(&srv).serve_background();
        (srv, port, stop, handle)
    }

    fn shutdown(
        port: u16,
        stop: Arc<AtomicBool>,
        handle: std::thread::JoinHandle<()>,
    ) {
        stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(("127.0.0.1", port));
        handle.join().unwrap();
    }

    /// Read one content-length-framed response off a reused stream.
    fn read_response(
        reader: &mut BufReader<&TcpStream>,
    ) -> (u16, String) {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let status: u16 =
            line.split(' ').nth(1).unwrap().parse().unwrap();
        let mut len = 0usize;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).unwrap();
            let h = h.trim_end().to_ascii_lowercase();
            if h.is_empty() {
                break;
            }
            if let Some(v) = h.strip_prefix("content-length:") {
                len = v.trim().parse().unwrap();
            }
        }
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).unwrap();
        (status, String::from_utf8(body).unwrap())
    }

    #[test]
    fn end_to_end_over_tcp() {
        let (_srv, port, stop, handle) = start();
        let mut stream =
            TcpStream::connect(("127.0.0.1", port)).unwrap();
        write!(
            stream,
            "GET /api/v1/cluster HTTP/1.1\r\nhost: x\r\nconnection: close\r\n\r\n"
        )
        .unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert!(buf.contains("200 OK"), "{buf}");
        assert!(buf.contains("RUNNING"));
        assert!(buf.contains("connection: close"));
        shutdown(port, stop, handle);
    }

    #[test]
    fn keep_alive_serves_many_requests_per_connection() {
        let (_srv, port, stop, handle) = start();
        let stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        let mut reader = BufReader::new(&stream);
        for i in 0..5 {
            write!(
                &stream,
                "GET /api/v2/cluster HTTP/1.1\r\nhost: x\r\n\r\n"
            )
            .unwrap();
            let (status, body) = read_response(&mut reader);
            assert_eq!(status, 200, "request {i}: {body}");
            assert!(body.contains("RUNNING"));
        }
        drop(reader);
        drop(stream);
        shutdown(port, stop, handle);
    }

    #[test]
    fn head_is_answered_without_body() {
        let (_srv, port, stop, handle) = start();
        let mut stream =
            TcpStream::connect(("127.0.0.1", port)).unwrap();
        write!(
            stream,
            "HEAD /api/v1/cluster HTTP/1.1\r\nhost: x\r\nconnection: close\r\n\r\n"
        )
        .unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert!(buf.contains("200 OK"), "{buf}");
        // content-length advertised, but no body bytes follow
        assert!(buf.contains("content-length:"));
        assert!(buf.trim_end().ends_with("connection: close"), "{buf}");
        shutdown(port, stop, handle);
    }

    #[test]
    fn unknown_method_gets_allow_header_over_tcp() {
        let (_srv, port, stop, handle) = start();
        let mut stream =
            TcpStream::connect(("127.0.0.1", port)).unwrap();
        write!(
            stream,
            "PATCH /api/v1/cluster HTTP/1.1\r\nhost: x\r\nconnection: close\r\n\r\n"
        )
        .unwrap();
        let mut buf = String::new();
        stream.read_to_string(&mut buf).unwrap();
        assert!(buf.contains("405"), "{buf}");
        assert!(buf.contains("Allow: GET, HEAD"), "{buf}");
        shutdown(port, stop, handle);
    }

    #[test]
    fn router_smoke_over_build_router() {
        let r = build_router(services());
        let resp =
            r.dispatch(&Request::synthetic("GET", "/api/v2/cluster"));
        assert_eq!(resp.status, 200);
        let j = Json::parse(
            std::str::from_utf8(&resp.body).unwrap(),
        )
        .unwrap();
        assert_eq!(j.num_field("code"), Some(200.0));
    }
}
