//! Composable middleware chain around route dispatch.
//!
//! Middlewares wrap the matched handler (or the 404/405 terminal) in
//! registration order: the first one added sees the request first and
//! the response last. The matched route *pattern* (not the concrete
//! path) is passed alongside so metrics aggregate per route, keeping
//! cardinality bounded.

use super::http::{Request, Response};
use super::router::error_response;
use crate::storage::MetricStore;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Continuation invoking the rest of the chain and the handler.
pub type Next<'a> = &'a dyn Fn(&Request) -> Response;

pub trait Middleware: Send + Sync {
    /// `route` is the matched route pattern, `None` when no route
    /// matched (the terminal will answer 404/405).
    fn handle(
        &self,
        req: &Request,
        route: Option<&str>,
        next: Next<'_>,
    ) -> Response;
}

/// Run `chain` around `terminal`.
pub fn run_chain(
    chain: &[Arc<dyn Middleware>],
    req: &Request,
    route: Option<&str>,
    terminal: &dyn Fn(&Request) -> Response,
) -> Response {
    match chain.split_first() {
        None => terminal(req),
        Some((m, rest)) => m.handle(req, route, &|r: &Request| {
            run_chain(rest, r, route, terminal)
        }),
    }
}

/// Bearer-token auth (§3.1: the REST service is responsible for
/// authentication). Rejects every request without the expected token.
pub struct AuthMiddleware {
    token: String,
}

impl AuthMiddleware {
    pub fn new(token: &str) -> AuthMiddleware {
        AuthMiddleware {
            token: token.to_string(),
        }
    }
}

impl Middleware for AuthMiddleware {
    fn handle(
        &self,
        req: &Request,
        _route: Option<&str>,
        next: Next<'_>,
    ) -> Response {
        if req.bearer_token() == Some(self.token.as_str()) {
            next(req)
        } else {
            error_response(
                &req.path,
                &crate::SubmarineError::Unauthorized(
                    "missing or bad token".into(),
                ),
            )
        }
    }
}

/// Request logging: method, path, status, latency.
#[derive(Default)]
pub struct LogMiddleware;

impl Middleware for LogMiddleware {
    fn handle(
        &self,
        req: &Request,
        route: Option<&str>,
        next: Next<'_>,
    ) -> Response {
        let start = Instant::now();
        let resp = next(req);
        crate::debuglog!(
            "httpd",
            "{} {} -> {} [{}] in {:.1}us",
            req.method,
            req.path,
            resp.status,
            route.unwrap_or("-"),
            start.elapsed().as_secs_f64() * 1e6
        );
        resp
    }
}

/// Experiment-id key under which HTTP metrics land in the
/// [`MetricStore`] (readable via the same metrics API as experiments).
pub const HTTP_METRICS_KEY: &str = "__http__";

/// Per-route latency/throughput metrics. Each request appends a
/// latency sample to the series `("__http__", "<METHOD> <route>")`;
/// series length over wall time gives throughput, and the store's
/// `summary`/`sparkline` give the latency profile. Retention is
/// bounded per route ([`HTTP_METRICS_CAP`] most recent samples) so a
/// long-running server doesn't grow the store without limit.
pub struct MetricsMiddleware {
    metrics: Arc<MetricStore>,
    seq: AtomicU64,
}

/// Minimum retained latency samples per route series (the store keeps
/// between this and twice this).
pub const HTTP_METRICS_CAP: usize = 4096;

impl MetricsMiddleware {
    pub fn new(metrics: Arc<MetricStore>) -> MetricsMiddleware {
        MetricsMiddleware {
            metrics,
            seq: AtomicU64::new(0),
        }
    }
}

impl Middleware for MetricsMiddleware {
    fn handle(
        &self,
        req: &Request,
        route: Option<&str>,
        next: Next<'_>,
    ) -> Response {
        let start = Instant::now();
        let resp = next(req);
        // Both label halves must be bounded: the route side is a
        // registered pattern (or "unmatched"), and the method side is
        // folded to a fixed set so arbitrary request-line tokens can't
        // mint unbounded metric series pre-auth.
        let method = req.method.to_uppercase();
        let method = match method.as_str() {
            "GET" | "HEAD" | "POST" | "PUT" | "DELETE" | "PATCH"
            | "OPTIONS" => method.as_str(),
            _ => "OTHER",
        };
        let label =
            format!("{} {}", method, route.unwrap_or("unmatched"));
        let step = self.seq.fetch_add(1, Ordering::Relaxed);
        self.metrics.log_bounded(
            HTTP_METRICS_KEY,
            &label,
            step,
            start.elapsed().as_secs_f64(),
            HTTP_METRICS_CAP,
        );
        resp
    }
}

/// Optional token-bucket rate limiter (global, `rate` requests/sec
/// sustained with a burst of `burst`). Over-limit requests get 429.
///
/// Lock-free (ISSUE 5): the bucket lives in one `AtomicU64` packing
/// milli-tokens (high 32 bits) and the last-refill time in wrapping
/// milliseconds since construction (low 32 bits). A grant is one CAS;
/// a denial is one load — the limiter stopped being a global mutex
/// every request had to queue on.
pub struct RateLimitMiddleware {
    rate: f64,
    /// Burst cap in milli-tokens (clamped so it packs into 32 bits).
    burst_m: u32,
    start: Instant,
    /// `(tokens_milli << 32) | last_refill_ms`.
    state: AtomicU64,
}

const MILLI: f64 = 1000.0;

fn pack(tokens_m: u32, last_ms: u32) -> u64 {
    ((tokens_m as u64) << 32) | last_ms as u64
}

fn unpack(state: u64) -> (u32, u32) {
    ((state >> 32) as u32, state as u32)
}

impl RateLimitMiddleware {
    pub fn new(rate: f64, burst: f64) -> RateLimitMiddleware {
        let rate = rate.max(1e-9);
        // full 32-bit range would overflow the milli-token packing
        let burst_m =
            (burst.max(1.0) * MILLI).min(u32::MAX as f64) as u32;
        RateLimitMiddleware {
            rate,
            burst_m,
            start: Instant::now(),
            state: AtomicU64::new(pack(burst_m, 0)),
        }
    }

    fn try_take(&self) -> bool {
        // wrapping ms: elapsed stays correct across the ~49-day wrap
        // as long as refills are less than 49 days apart
        let now_ms = self.start.elapsed().as_millis() as u32;
        loop {
            let cur = self.state.load(Ordering::Relaxed);
            let (tokens_m, last_ms) = unpack(cur);
            // A racing thread may have stored a *newer* timestamp than
            // this thread's `now_ms` sample; the wrapped difference
            // would then read as ~49 days and refill the whole burst.
            // Differences within 60s of the wrap point can only be
            // that race (threads diverge by scheduling delays, not
            // minutes): clamp them to zero and keep the newer
            // timestamp so time never flows backwards. Larger values
            // are genuine idle time and refill normally.
            let raw = now_ms.wrapping_sub(last_ms);
            let (elapsed_ms, new_last) = if raw > u32::MAX - 60_000 {
                (0.0, last_ms)
            } else {
                (raw as f64, now_ms)
            };
            let refilled = (tokens_m as f64 + elapsed_ms * self.rate)
                .min(self.burst_m as f64);
            if refilled < MILLI {
                // denial path: no write, no contention — the refill
                // credit stays derivable from the unchanged timestamp
                return false;
            }
            let next = pack((refilled - MILLI) as u32, new_last);
            if self
                .state
                .compare_exchange_weak(
                    cur,
                    next,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                )
                .is_ok()
            {
                return true;
            }
        }
    }
}

impl Middleware for RateLimitMiddleware {
    fn handle(
        &self,
        req: &Request,
        _route: Option<&str>,
        next: Next<'_>,
    ) -> Response {
        if self.try_take() {
            next(req)
        } else {
            error_response(
                &req.path,
                &crate::SubmarineError::RateLimited(
                    "request rate over limit; retry later".into(),
                ),
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn ok_terminal(_: &Request) -> Response {
        Response::ok(Json::Bool(true))
    }

    #[test]
    fn empty_chain_hits_terminal() {
        let req = Request::synthetic("GET", "/x");
        let resp = run_chain(&[], &req, None, &ok_terminal);
        assert_eq!(resp.status, 200);
    }

    #[test]
    fn auth_blocks_and_admits() {
        let chain: Vec<Arc<dyn Middleware>> =
            vec![Arc::new(AuthMiddleware::new("sekrit"))];
        let anon = Request::synthetic("GET", "/api/v2/experiment");
        let resp = run_chain(&chain, &anon, None, &ok_terminal);
        assert_eq!(resp.status, 401);
        let body =
            String::from_utf8(resp.body).unwrap();
        assert!(body.contains("Unauthorized"), "{body}");
        let mut authed = Request::synthetic("GET", "/api/v2/experiment");
        authed
            .headers
            .insert("authorization".into(), "Bearer sekrit".into());
        let resp = run_chain(&chain, &authed, None, &ok_terminal);
        assert_eq!(resp.status, 200);
    }

    #[test]
    fn metrics_record_per_route() {
        let store = Arc::new(MetricStore::new());
        let chain: Vec<Arc<dyn Middleware>> = vec![Arc::new(
            MetricsMiddleware::new(Arc::clone(&store)),
        )];
        let req = Request::synthetic("GET", "/api/v2/experiment/e-1");
        for _ in 0..3 {
            run_chain(
                &chain,
                &req,
                Some("/api/v2/experiment/:id"),
                &ok_terminal,
            );
        }
        let series = store.series(
            HTTP_METRICS_KEY,
            "GET /api/v2/experiment/:id",
        );
        assert_eq!(series.len(), 3);
        assert!(series.iter().all(|p| p.value >= 0.0));
    }

    #[test]
    fn unknown_methods_fold_into_one_series() {
        let store = Arc::new(MetricStore::new());
        let chain: Vec<Arc<dyn Middleware>> = vec![Arc::new(
            MetricsMiddleware::new(Arc::clone(&store)),
        )];
        for m in ["XQZ1", "XQZ2", "BREW"] {
            let req = Request::synthetic(m, "/api/v2/cluster");
            run_chain(&chain, &req, None, &ok_terminal);
        }
        let series =
            store.series(HTTP_METRICS_KEY, "OTHER unmatched");
        assert_eq!(series.len(), 3);
    }

    #[test]
    fn rate_limit_hits_429_past_burst() {
        let chain: Vec<Arc<dyn Middleware>> =
            vec![Arc::new(RateLimitMiddleware::new(0.000001, 2.0))];
        let req = Request::synthetic("GET", "/api/v2/cluster");
        assert_eq!(run_chain(&chain, &req, None, &ok_terminal).status, 200);
        assert_eq!(run_chain(&chain, &req, None, &ok_terminal).status, 200);
        let limited = run_chain(&chain, &req, None, &ok_terminal);
        assert_eq!(limited.status, 429);
    }

    #[test]
    fn rate_limiter_grants_exactly_burst_under_contention() {
        // negligible refill rate: 8 threads race for exactly 64 tokens
        let mw = Arc::new(RateLimitMiddleware::new(0.000001, 64.0));
        let granted = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let mw = Arc::clone(&mw);
                let granted = Arc::clone(&granted);
                std::thread::spawn(move || {
                    for _ in 0..64 {
                        if mw.try_take() {
                            granted.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(granted.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn chain_runs_in_registration_order() {
        struct Tag(&'static str);
        impl Middleware for Tag {
            fn handle(
                &self,
                req: &Request,
                _route: Option<&str>,
                next: Next<'_>,
            ) -> Response {
                next(req).with_header("x-tag", self.0)
            }
        }
        let chain: Vec<Arc<dyn Middleware>> =
            vec![Arc::new(Tag("outer")), Arc::new(Tag("inner"))];
        let req = Request::synthetic("GET", "/x");
        let resp = run_chain(&chain, &req, None, &ok_terminal);
        // inner (closest to terminal) attaches first, outer last
        let tags: Vec<&str> = resp
            .headers
            .iter()
            .map(|(_, v)| v.as_str())
            .collect();
        assert_eq!(tags, vec!["inner", "outer"]);
    }
}
