//! Compiled segment trie for route dispatch.
//!
//! The v1 router scanned a `Vec<Route>` per request — O(routes ×
//! segments) with a params allocation per candidate. The trie walks the
//! path once: each segment either follows a literal edge (BTreeMap
//! lookup) or the single `:param` edge, with backtracking so literal
//! routes shadow parameter routes only where they actually match (e.g.
//! `/a/b/d` and `/a/:x/c` coexist).

use std::collections::BTreeMap;

/// A per-path payload slot addressed by a `/seg/:param/...` pattern.
pub struct PathTrie<T> {
    root: Node<T>,
}

struct Node<T> {
    literal: BTreeMap<String, Node<T>>,
    /// At most one parameter edge per node: (param name, subtree).
    param: Option<Box<(String, Node<T>)>>,
    value: Option<T>,
    /// The registered pattern, for metrics/log labels.
    pattern: String,
}

impl<T> Default for Node<T> {
    fn default() -> Node<T> {
        Node {
            literal: BTreeMap::new(),
            param: None,
            value: None,
            pattern: String::new(),
        }
    }
}

impl<T> Default for PathTrie<T> {
    fn default() -> PathTrie<T> {
        PathTrie {
            root: Node::default(),
        }
    }
}

fn segments(path: &str) -> impl Iterator<Item = &str> {
    path.trim_matches('/').split('/').filter(|s| !s.is_empty())
}

impl<T> PathTrie<T> {
    pub fn new() -> PathTrie<T> {
        PathTrie::default()
    }

    /// Get-or-create the payload slot for `pattern`. Two patterns that
    /// differ only in parameter *names* share a slot (the first name
    /// wins), matching common router semantics.
    pub fn entry(&mut self, pattern: &str) -> &mut Option<T> {
        let mut node = &mut self.root;
        for seg in segments(pattern) {
            if let Some(name) = seg.strip_prefix(':') {
                let boxed = node.param.get_or_insert_with(|| {
                    Box::new((name.to_string(), Node::default()))
                });
                node = &mut boxed.1;
            } else {
                node = node
                    .literal
                    .entry(seg.to_string())
                    .or_default();
            }
        }
        if node.pattern.is_empty() {
            node.pattern = normalize(pattern);
        }
        &mut node.value
    }

    /// Walk `path`; on a hit returns the payload, the registered
    /// pattern, and the captured parameters.
    pub fn lookup(
        &self,
        path: &str,
    ) -> Option<(&T, &str, BTreeMap<String, String>)> {
        let parts: Vec<&str> = segments(path).collect();
        let mut captures: Vec<(String, String)> = Vec::new();
        let node = find(&self.root, &parts, &mut captures)?;
        let value = node.value.as_ref()?;
        Some((
            value,
            node.pattern.as_str(),
            captures.into_iter().collect(),
        ))
    }
}

fn normalize(pattern: &str) -> String {
    let mut out = String::new();
    for seg in segments(pattern) {
        out.push('/');
        out.push_str(seg);
    }
    if out.is_empty() {
        out.push('/');
    }
    out
}

fn find<'a, T>(
    node: &'a Node<T>,
    parts: &[&str],
    captures: &mut Vec<(String, String)>,
) -> Option<&'a Node<T>> {
    let (head, rest) = match parts.split_first() {
        None => return node.value.is_some().then_some(node),
        Some(x) => x,
    };
    if let Some(child) = node.literal.get(*head) {
        if let Some(hit) = find(child, rest, captures) {
            return Some(hit);
        }
    }
    if let Some(boxed) = &node.param {
        captures.push((boxed.0.clone(), head.to_string()));
        if let Some(hit) = find(&boxed.1, rest, captures) {
            return Some(hit);
        }
        captures.pop();
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_and_param_lookup() {
        let mut t = PathTrie::new();
        *t.entry("/api/v1/experiment") = Some(1);
        *t.entry("/api/v1/experiment/:id") = Some(2);
        let (v, pat, p) = t.lookup("/api/v1/experiment").unwrap();
        assert_eq!((*v, pat), (1, "/api/v1/experiment"));
        assert!(p.is_empty());
        let (v, pat, p) = t.lookup("/api/v1/experiment/e-7").unwrap();
        assert_eq!((*v, pat), (2, "/api/v1/experiment/:id"));
        assert_eq!(p["id"], "e-7");
        assert!(t.lookup("/api/v1/nope").is_none());
    }

    #[test]
    fn backtracks_from_literal_to_param() {
        let mut t = PathTrie::new();
        *t.entry("/a/b/d") = Some(1);
        *t.entry("/a/:x/c") = Some(2);
        let (v, _, p) = t.lookup("/a/b/c").unwrap();
        assert_eq!(*v, 2);
        assert_eq!(p["x"], "b");
        assert_eq!(*t.lookup("/a/b/d").unwrap().0, 1);
    }

    #[test]
    fn nested_params_capture_in_order() {
        let mut t = PathTrie::new();
        *t.entry("/m/:name/v/:version") = Some(0);
        let (_, _, p) = t.lookup("/m/bert/v/3").unwrap();
        assert_eq!(p["name"], "bert");
        assert_eq!(p["version"], "3");
    }

    #[test]
    fn trailing_slashes_ignored() {
        let mut t = PathTrie::new();
        *t.entry("/x/y/") = Some(1);
        assert!(t.lookup("/x/y").is_some());
        assert!(t.lookup("x/y/").is_some());
    }

    #[test]
    fn entry_is_reusable() {
        let mut t: PathTrie<Vec<u32>> = PathTrie::new();
        t.entry("/r").get_or_insert_with(Vec::new).push(1);
        t.entry("/r").get_or_insert_with(Vec::new).push(2);
        assert_eq!(t.lookup("/r").unwrap().0, &vec![1, 2]);
    }
}
