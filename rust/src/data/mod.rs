//! Synthetic workload data generators (DESIGN.md S18).
//!
//! The paper's workloads use proprietary data (Ke.com speech corpora,
//! LinkedIn member data, Criteo-style CTR logs). Per DESIGN.md
//! §Substitutions each generator produces a *learnable* synthetic
//! equivalent with a planted ground truth, so training through the
//! platform demonstrably reduces loss / achieves AUC > 0.5 while
//! exercising the identical code paths.

pub mod ctr;
pub mod mnist;
pub mod tokens;

pub use ctr::CtrGen;
pub use mnist::MnistGen;
pub use tokens::TokenGen;

use crate::runtime::engine::HostTensor;

/// A generator of batches matching a model's AOT batch signature
/// (everything except the trailing `lr` scalar).
pub trait BatchGen {
    /// Tensors for one step, in manifest order (e.g. `[ids, vals,
    /// labels]` for deepfm, `[x, y]` for mnist_mlp).
    fn next_batch(&mut self) -> Vec<HostTensor>;

    /// Inputs-only view for `predict` (drops label tensors).
    fn next_inputs(&mut self) -> Vec<HostTensor>;
}

/// Construct the right generator for a manifest model name.
pub fn for_model(
    model: &str,
    seed: u64,
) -> crate::Result<Box<dyn BatchGen + Send>> {
    match model {
        "deepfm" => Ok(Box::new(CtrGen::new(seed))),
        "mnist_mlp" => Ok(Box::new(MnistGen::new(seed))),
        "transformer_tiny" => Ok(Box::new(TokenGen::new(seed))),
        other => Err(crate::SubmarineError::NotFound(format!(
            "no data generator for model {other}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_covers_all_models() {
        for m in ["deepfm", "mnist_mlp", "transformer_tiny"] {
            assert!(for_model(m, 0).is_ok(), "{m}");
        }
        assert!(for_model("nope", 0).is_err());
    }
}
