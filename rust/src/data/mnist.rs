//! Synthetic MNIST-like classification data (the `mnist.py` workload of
//! paper Listings 1/2/4).
//!
//! Each class has a fixed random prototype image; samples are prototype +
//! Gaussian noise. Linearly separable enough for the MLP to converge in a
//! few hundred steps, matching the paper's demo workload scale.

use super::BatchGen;
use crate::runtime::engine::HostTensor;
use crate::util::rng::Rng;

/// Must match `python/compile/models/mnist_mlp.py`.
pub const BATCH: usize = 128;
pub const IN_DIM: usize = 784;
pub const CLASSES: usize = 10;
const NOISE: f32 = 0.35;

pub struct MnistGen {
    rng: Rng,
    prototypes: Vec<f32>, // [CLASSES * IN_DIM]
}

impl MnistGen {
    pub fn new(seed: u64) -> MnistGen {
        // Fixed prototypes (shared across workers); seed drives sampling.
        let mut proto_rng = Rng::new(0x00D1_6175);
        let prototypes = (0..CLASSES * IN_DIM)
            .map(|_| if proto_rng.chance(0.18) { 1.0 } else { 0.0 })
            .collect();
        MnistGen {
            rng: Rng::new(seed ^ 0x9A9A_0101),
            prototypes,
        }
    }

    /// (x [B*784], y [B])
    pub fn batch(&mut self) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(BATCH * IN_DIM);
        let mut y = Vec::with_capacity(BATCH);
        for _ in 0..BATCH {
            let c = self.rng.index(CLASSES);
            y.push(c as i32);
            let base = c * IN_DIM;
            for d in 0..IN_DIM {
                let noise = self.rng.normal() as f32 * NOISE;
                x.push((self.prototypes[base + d] + noise).clamp(-1.0, 2.0));
            }
        }
        (x, y)
    }
}

impl BatchGen for MnistGen {
    fn next_batch(&mut self) -> Vec<HostTensor> {
        let (x, y) = self.batch();
        vec![HostTensor::F32(x), HostTensor::I32(y)]
    }
    fn next_inputs(&mut self) -> Vec<HostTensor> {
        let mut b = self.next_batch();
        b.truncate(1);
        b
    }
}

/// Top-1 accuracy given flat logits `[B*CLASSES]`.
pub fn accuracy(logits: &[f32], labels: &[i32]) -> f64 {
    let b = labels.len();
    let mut hits = 0usize;
    for i in 0..b {
        let row = &logits[i * CLASSES..(i + 1) * CLASSES];
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        if argmax == labels[i] as usize {
            hits += 1;
        }
    }
    hits as f64 / b as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_label_range() {
        let mut g = MnistGen::new(1);
        let (x, y) = g.batch();
        assert_eq!(x.len(), BATCH * IN_DIM);
        assert_eq!(y.len(), BATCH);
        assert!(y.iter().all(|&c| (0..CLASSES as i32).contains(&c)));
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn classes_are_distinguishable() {
        // Nearest-prototype classification on clean generator output must
        // beat chance by a wide margin.
        let mut g = MnistGen::new(2);
        let (x, y) = g.batch();
        let mut hits = 0;
        for i in 0..BATCH {
            let xi = &x[i * IN_DIM..(i + 1) * IN_DIM];
            let mut best = (f32::MAX, 0usize);
            for c in 0..CLASSES {
                let p = &g.prototypes[c * IN_DIM..(c + 1) * IN_DIM];
                let d: f32 = xi
                    .iter()
                    .zip(p)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == y[i] as usize {
                hits += 1;
            }
        }
        assert!(hits as f64 / BATCH as f64 > 0.9, "hits={hits}");
    }

    #[test]
    fn accuracy_helper() {
        // logits favoring class == index order
        let logits = vec![
            1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, // -> 0
            0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, // -> 1
        ];
        assert_eq!(accuracy(&logits, &[0, 1]), 1.0);
        assert_eq!(accuracy(&logits, &[1, 0]), 0.0);
    }
}
