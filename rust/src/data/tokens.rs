//! Synthetic token sequences for the BERT-proxy workload (paper §6.2).
//!
//! Task: next-token prediction over a planted first-order Markov chain
//! (each token strongly prefers one successor), so the tiny transformer
//! has real structure to learn.

use super::BatchGen;
use crate::runtime::engine::HostTensor;
use crate::util::rng::Rng;

/// Must match `python/compile/models/transformer_tiny.py`.
pub const BATCH: usize = 8;
pub const SEQ: usize = 32;
pub const VOCAB: usize = 1_000;
/// Probability of following the planted successor chain.
const CHAIN_P: f64 = 0.85;

pub struct TokenGen {
    rng: Rng,
    successor: Vec<i32>, // planted successor per token
}

impl TokenGen {
    pub fn new(seed: u64) -> TokenGen {
        let mut chain_rng = Rng::new(0x70AD_70AD);
        let successor = (0..VOCAB)
            .map(|_| chain_rng.index(VOCAB) as i32)
            .collect();
        TokenGen {
            rng: Rng::new(seed ^ 0x5E5E_2323),
            successor,
        }
    }

    /// (ids [B*S], targets [B*S]) where targets[t] = ids[t+1].
    pub fn batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let mut ids = Vec::with_capacity(BATCH * SEQ);
        let mut targets = Vec::with_capacity(BATCH * SEQ);
        for _ in 0..BATCH {
            let mut tok = self.rng.index(VOCAB) as i32;
            let mut seq = Vec::with_capacity(SEQ + 1);
            for _ in 0..=SEQ {
                seq.push(tok);
                tok = if self.rng.chance(CHAIN_P) {
                    self.successor[tok as usize]
                } else {
                    self.rng.index(VOCAB) as i32
                };
            }
            ids.extend_from_slice(&seq[..SEQ]);
            targets.extend_from_slice(&seq[1..=SEQ]);
        }
        (ids, targets)
    }
}

impl BatchGen for TokenGen {
    fn next_batch(&mut self) -> Vec<HostTensor> {
        let (ids, targets) = self.batch();
        vec![HostTensor::I32(ids), HostTensor::I32(targets)]
    }
    fn next_inputs(&mut self) -> Vec<HostTensor> {
        let mut b = self.next_batch();
        b.truncate(1);
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let mut g = TokenGen::new(1);
        let (ids, targets) = g.batch();
        assert_eq!(ids.len(), BATCH * SEQ);
        assert_eq!(targets.len(), BATCH * SEQ);
        assert!(ids.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
    }

    #[test]
    fn targets_shift_ids() {
        let mut g = TokenGen::new(2);
        let (ids, targets) = g.batch();
        // within each row, targets[t] == ids[t+1]
        for b in 0..BATCH {
            for t in 0..SEQ - 1 {
                assert_eq!(targets[b * SEQ + t], ids[b * SEQ + t + 1]);
            }
        }
    }

    #[test]
    fn chain_structure_present() {
        let mut g = TokenGen::new(3);
        let (ids, targets) = g.batch();
        let follows: usize = ids
            .iter()
            .zip(&targets)
            .filter(|(&i, &t)| g.successor[i as usize] == t)
            .count();
        let frac = follows as f64 / ids.len() as f64;
        assert!(frac > 0.7, "chain fraction {frac}");
    }
}
