//! Criteo-like CTR data with a planted factorization-machine ground truth
//! (the DeepFM workload of paper Listing 3).
//!
//! Labels are drawn from `sigmoid(w·x + <v_i, v_j> interactions)` over a
//! hidden FM model, so a DeepFM learner can genuinely improve AUC — the
//! linear part alone is insufficient, which exercises the Pallas FM
//! kernel's contribution.

use super::BatchGen;
use crate::runtime::engine::HostTensor;
use crate::util::rng::Rng;

/// Must match `python/compile/models/deepfm.py`.
pub const BATCH: usize = 256;
pub const FIELDS: usize = 39;
pub const VOCAB: usize = 5_000;
const HIDDEN_K: usize = 4;

pub struct CtrGen {
    rng: Rng,
    /// Hidden linear weights (hashed by feature id).
    w: Vec<f32>,
    /// Hidden FM factors (hashed).
    v: Vec<f32>,
}

impl CtrGen {
    pub fn new(seed: u64) -> CtrGen {
        // A *fixed* ground-truth model (independent of `seed`, which only
        // drives sampling) so every worker shares the same distribution.
        let mut truth_rng = Rng::new(0xFEED_F00D);
        let w: Vec<f32> = (0..4096)
            .map(|_| truth_rng.normal() as f32 * 0.8)
            .collect();
        let v: Vec<f32> = (0..4096 * HIDDEN_K)
            .map(|_| truth_rng.normal() as f32 * 0.45)
            .collect();
        CtrGen {
            rng: Rng::new(seed ^ 0xC7C7_C7C7),
            w,
            v,
        }
    }

    /// One example: (ids, vals, label).
    fn example(&mut self) -> ([i32; FIELDS], [f32; FIELDS], f32) {
        let mut ids = [0i32; FIELDS];
        let mut vals = [0f32; FIELDS];
        let mut logit = -0.4f32; // base CTR below 50%
        let mut factors = [0f32; HIDDEN_K];
        let mut sq = [0f32; HIDDEN_K];
        for f in 0..FIELDS {
            // Per-field vocabulary stripe keeps fields distinguishable.
            let stripe = VOCAB / FIELDS;
            let id = (f * stripe)
                + self.rng.index(stripe.max(1));
            ids[f] = id as i32;
            vals[f] = 1.0;
            let h = id % 4096;
            logit += self.w[h];
            for k in 0..HIDDEN_K {
                let x = self.v[h * HIDDEN_K + k];
                factors[k] += x;
                sq[k] += x * x;
            }
        }
        // FM second-order term of the hidden model.
        for k in 0..HIDDEN_K {
            logit += 0.5 * (factors[k] * factors[k] - sq[k]);
        }
        let p = 1.0 / (1.0 + (-logit as f64 / 4.0).exp());
        let label = if self.rng.chance(p) { 1.0 } else { 0.0 };
        (ids, vals, label)
    }

    /// Generate a full batch: (ids [B*F], vals [B*F], labels [B]).
    pub fn batch(&mut self) -> (Vec<i32>, Vec<f32>, Vec<f32>) {
        let mut ids = Vec::with_capacity(BATCH * FIELDS);
        let mut vals = Vec::with_capacity(BATCH * FIELDS);
        let mut labels = Vec::with_capacity(BATCH);
        for _ in 0..BATCH {
            let (i, v, l) = self.example();
            ids.extend_from_slice(&i);
            vals.extend_from_slice(&v);
            labels.push(l);
        }
        (ids, vals, labels)
    }
}

impl BatchGen for CtrGen {
    fn next_batch(&mut self) -> Vec<HostTensor> {
        let (ids, vals, labels) = self.batch();
        vec![
            HostTensor::I32(ids),
            HostTensor::F32(vals),
            HostTensor::F32(labels),
        ]
    }
    fn next_inputs(&mut self) -> Vec<HostTensor> {
        let mut b = self.next_batch();
        b.truncate(2);
        b
    }
}

/// AUC (area under ROC) — evaluation metric for CTR (paper Listing 3
/// prints "Model AUC").
pub fn auc(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let mut pairs: Vec<(f32, f32)> = scores
        .iter()
        .cloned()
        .zip(labels.iter().cloned())
        .collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    // rank-sum (Mann-Whitney U) with tie-aware average ranks
    let n = pairs.len();
    let mut rank_sum_pos = 0.0f64;
    let (mut npos, mut nneg) = (0usize, 0usize);
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j < n && pairs[j].0 == pairs[i].0 {
            j += 1;
        }
        let avg_rank = (i + 1 + j) as f64 / 2.0; // average of ranks i+1..=j
        for p in &pairs[i..j] {
            if p.1 > 0.5 {
                rank_sum_pos += avg_rank;
                npos += 1;
            } else {
                nneg += 1;
            }
        }
        i = j;
    }
    if npos == 0 || nneg == 0 {
        return 0.5;
    }
    (rank_sum_pos - (npos * (npos + 1)) as f64 / 2.0)
        / (npos as f64 * nneg as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_ranges() {
        let mut g = CtrGen::new(1);
        let (ids, vals, labels) = g.batch();
        assert_eq!(ids.len(), BATCH * FIELDS);
        assert_eq!(vals.len(), BATCH * FIELDS);
        assert_eq!(labels.len(), BATCH);
        assert!(ids.iter().all(|&i| (0..VOCAB as i32).contains(&i)));
        assert!(labels.iter().all(|&l| l == 0.0 || l == 1.0));
    }

    #[test]
    fn labels_are_mixed_classes() {
        let mut g = CtrGen::new(2);
        let (_, _, labels) = g.batch();
        let pos: usize = labels.iter().filter(|&&l| l > 0.5).count();
        assert!(pos > 10 && pos < BATCH - 10, "pos={pos}");
    }

    #[test]
    fn ground_truth_is_learnable() {
        // The hidden model's own logit must rank labels well above chance:
        // AUC of p(label) vs label should be far from 0.5.
        let mut g = CtrGen::new(3);
        let mut scores = Vec::new();
        let mut labels = Vec::new();
        for _ in 0..8 {
            let (ids, _, ls) = g.batch();
            for (b, l) in ls.iter().enumerate() {
                // re-derive the hidden logit (linear part only is enough
                // to rank far better than chance)
                let mut logit = 0.0f32;
                for f in 0..FIELDS {
                    let h = ids[b * FIELDS + f] as usize % 4096;
                    logit += g.w[h];
                }
                scores.push(logit);
                labels.push(*l);
            }
        }
        let a = auc(&scores, &labels);
        assert!(a > 0.62, "auc={a}");
    }

    #[test]
    fn auc_sanity() {
        assert!((auc(&[0.1, 0.9], &[0.0, 1.0]) - 1.0).abs() < 1e-9);
        assert!((auc(&[0.9, 0.1], &[0.0, 1.0]) - 0.0).abs() < 1e-9);
        assert!((auc(&[0.5, 0.5], &[0.0, 1.0]) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn deterministic_per_seed() {
        let (a, _, _) = CtrGen::new(7).batch();
        let (b, _, _) = CtrGen::new(7).batch();
        assert_eq!(a, b);
        let (c, _, _) = CtrGen::new(8).batch();
        assert_ne!(a, c);
    }
}
