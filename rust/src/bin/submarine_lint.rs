//! `submarine-lint` — run the in-tree static analysis over `src/`.
//!
//! Exit status 0 when the tree is clean, 1 on any blocking finding,
//! 2 on usage/setup errors. CI runs this as a blocking step and
//! uploads the `--report` JSON as an artifact.
//!
//! ```text
//! submarine-lint [--root <crate-dir>] [--report <file>] [--write-baseline]
//! ```

use std::path::PathBuf;
use std::process::ExitCode;
use submarine::analysis;

struct Opts {
    root: PathBuf,
    report: Option<PathBuf>,
    write_baseline: bool,
}

fn parse_args() -> Result<Opts, String> {
    let mut opts = Opts {
        root: PathBuf::from(env!("CARGO_MANIFEST_DIR")),
        report: None,
        write_baseline: false,
    };
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => {
                opts.root = PathBuf::from(
                    args.next().ok_or("--root needs a path")?,
                );
            }
            "--report" => {
                opts.report = Some(PathBuf::from(
                    args.next().ok_or("--report needs a path")?,
                ));
            }
            "--write-baseline" => opts.write_baseline = true,
            "--help" | "-h" => {
                return Err(String::new()); // print usage, exit 2
            }
            other => {
                return Err(format!("unknown argument `{other}`"));
            }
        }
    }
    Ok(opts)
}

fn main() -> ExitCode {
    let opts = match parse_args() {
        Ok(o) => o,
        Err(msg) => {
            if !msg.is_empty() {
                eprintln!("submarine-lint: {msg}");
            }
            eprintln!(
                "usage: submarine-lint [--root <crate-dir>] \
                 [--report <file>] [--write-baseline]"
            );
            return ExitCode::from(2);
        }
    };

    let report = match analysis::run_all(&opts.root) {
        Ok(r) => r,
        Err(msg) => {
            eprintln!("submarine-lint: {msg}");
            return ExitCode::from(2);
        }
    };

    if opts.write_baseline {
        let path = opts
            .root
            .join("src")
            .join("analysis")
            .join("baseline.json");
        let text = analysis::baseline::render(
            &report.unwrap_counts,
            &report.unsafe_counts,
        );
        if let Err(e) = std::fs::write(&path, text) {
            eprintln!(
                "submarine-lint: writing {}: {e}",
                path.display()
            );
            return ExitCode::from(2);
        }
        println!("baseline rewritten: {}", path.display());
    }

    if let Some(path) = &opts.report {
        let json = report.to_json().dump();
        if let Err(e) = std::fs::write(path, json + "\n") {
            eprintln!(
                "submarine-lint: writing {}: {e}",
                path.display()
            );
            return ExitCode::from(2);
        }
    }

    for w in &report.warnings {
        eprintln!("warning: {}", w.render());
    }
    for f in &report.findings {
        eprintln!("error: {}", f.render());
    }
    println!(
        "submarine-lint: {} files scanned, {} blocking finding(s), \
         {} warning(s)",
        report.files_scanned,
        report.findings.len(),
        report.warnings.len()
    );
    for p in &report.passes {
        println!(
            "  pass {:<14} {:>4} finding(s) {:>7} us",
            p.name, p.findings, p.micros
        );
    }
    if report.ok() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
