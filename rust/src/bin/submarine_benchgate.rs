//! `submarine-benchgate` — CI bench-regression gate over `BENCH_*.json`.
//!
//! Exit status 0 when every recorded op's `optimized_ns/baseline_ns`
//! ratio is within tolerance, 1 on any regression (or when no records
//! exist at all — a silently-empty gate is a broken gate), 2 on
//! usage/setup errors. CI runs this as a blocking step right after the
//! bench smoke loop.
//!
//! ```text
//! submarine-benchgate [--dir <results-dir>] [--max-ratio <float>]
//! ```
//!
//! `--max-ratio` defaults to `BENCH_GATE_MAX_RATIO` (env), then 2.0.

use std::path::PathBuf;
use std::process::ExitCode;
use submarine::analysis::benchgate;

struct Opts {
    dir: PathBuf,
    max_ratio: f64,
}

fn parse_args() -> Result<Opts, String> {
    let mut opts = Opts {
        dir: PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(".."),
        max_ratio: std::env::var("BENCH_GATE_MAX_RATIO")
            .ok()
            .and_then(|v| v.parse::<f64>().ok())
            .unwrap_or(2.0),
    };
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--dir" => {
                opts.dir = PathBuf::from(
                    args.next().ok_or("--dir needs a path")?,
                );
            }
            "--max-ratio" => {
                opts.max_ratio = args
                    .next()
                    .ok_or("--max-ratio needs a number")?
                    .parse::<f64>()
                    .map_err(|_| {
                        "--max-ratio must be a float".to_string()
                    })?;
            }
            "--help" | "-h" => {
                return Err(String::new()); // print usage, exit 2
            }
            other => {
                return Err(format!("unknown argument `{other}`"));
            }
        }
    }
    if opts.max_ratio <= 0.0 {
        return Err("--max-ratio must be positive".to_string());
    }
    Ok(opts)
}

fn main() -> ExitCode {
    let opts = match parse_args() {
        Ok(o) => o,
        Err(msg) => {
            if !msg.is_empty() {
                eprintln!("submarine-benchgate: {msg}");
            }
            eprintln!(
                "usage: submarine-benchgate [--dir <results-dir>] \
                 [--max-ratio <float>]"
            );
            return ExitCode::from(2);
        }
    };

    let report = match benchgate::run(&opts.dir, opts.max_ratio) {
        Ok(r) => r,
        Err(msg) => {
            eprintln!("submarine-benchgate: {msg}");
            return ExitCode::from(1);
        }
    };

    println!("{}", report.render());
    println!(
        "submarine-benchgate: {} record(s), {} regression(s), \
         tolerance {:.2}",
        report.records.len(),
        report.violations.len(),
        report.max_ratio
    );
    if report.ok() {
        ExitCode::SUCCESS
    } else {
        for v in &report.violations {
            eprintln!(
                "error: {}/{} regressed: optimized {:.0}ns vs \
                 baseline {:.0}ns (ratio {:.3} > {:.2})",
                v.file,
                v.op,
                v.optimized_ns,
                v.baseline_ns,
                v.ratio(),
                report.max_ratio
            );
        }
        ExitCode::from(1)
    }
}
