//! # Submarine-RS
//!
//! A unified machine-learning platform — a Rust + JAX + Pallas
//! reproduction of *"Apache Submarine: A Unified Machine Learning Platform
//! Made Simple"* (Chen et al., 2021).
//!
//! Architecture (paper Fig. 1, realized as three layers):
//!
//! - **L3 (this crate)**: the Submarine server — REST API ([`httpd`]),
//!   experiment manager/submitter/monitor ([`experiment`],
//!   [`orchestrator`]), predefined templates ([`template`]), environments
//!   ([`environment`]), model registry ([`model`]), online inference
//!   serving tier ([`serving`]), metadata store
//!   ([`storage`]), and the cluster-simulator substrate ([`cluster`],
//!   [`scheduler`]) with YARN-like and Kubernetes-like orchestrators.
//! - **L2**: JAX models (DeepFM, MNIST MLP, tiny transformer) AOT-lowered
//!   to HLO text at build time (`python/compile/`).
//! - **L1**: Pallas kernels (FM interaction, blocked dense) inside those
//!   models (`python/compile/kernels/`).
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT C API and
//! executes them on the request path with no Python anywhere.
//!
//! The REST surface is versioned: `/api/v2` (typed handlers, structured
//! errors, pagination) with `/api/v1` as a compat shim, served over
//! keep-alive HTTP/1.1 by a trie router and middleware chain — see
//! [`httpd`] and the route reference in `docs/API.md` at the repo root.

pub mod analysis;
pub mod error;
pub mod util;

pub mod cluster;
pub mod resource;
pub mod scheduler;
pub mod storage;

pub mod automl;
pub mod data;
pub mod environment;
pub mod experiment;
pub mod model;
pub mod orchestrator;
pub mod platform;
pub mod runtime;
pub mod serving;
pub mod template;

pub mod cli;
pub mod httpd;
pub mod sdk;

pub use error::{Result, SubmarineError};

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
