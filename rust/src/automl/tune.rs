//! Tune API plumbing: the request shape behind `POST /experiment/tune`
//! and the driver that runs a search strategy where every trial is a
//! *real child experiment* submitted through the execution pipeline
//! (manager → scheduler → cluster sim → monitor), not an in-process
//! function call.
//!
//! The search strategies themselves live in [`crate::automl`]
//! ([`random_search`] / [`successive_halving`]); this module parses the
//! request JSON, records each trial's experiment id alongside its score,
//! and assembles the response payload.

use super::{random_search, successive_halving, ParamSpace};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Hard cap on trials per tune request (each trial is a scheduled
/// experiment; unbounded fan-out would let one request flood the queue).
pub const MAX_TRIALS: usize = 64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    RandomSearch,
    SuccessiveHalving,
}

impl Strategy {
    pub fn as_str(&self) -> &'static str {
        match self {
            Strategy::RandomSearch => "random_search",
            Strategy::SuccessiveHalving => "successive_halving",
        }
    }
}

/// Parsed `POST /experiment/tune` body.
#[derive(Debug, Clone)]
pub struct TuneRequest {
    pub strategy: Strategy,
    /// Number of configurations (rung-0 size for halving).
    pub trials: usize,
    /// Full budget per trial (random search).
    pub budget: u32,
    /// Rung-0 / ceiling budgets (successive halving).
    pub min_budget: u32,
    pub max_budget: u32,
    pub seed: u64,
    /// Per-trial wall-clock cap; a trial still running past it is
    /// killed and scored as failed.
    pub trial_timeout_ms: u64,
    pub space: BTreeMap<String, ParamSpace>,
    /// Registered template to instantiate per trial...
    pub template: Option<String>,
    /// ...or a raw experiment spec with `{{param}}` placeholders.
    pub base_spec: Option<Json>,
}

fn bad(msg: String) -> crate::SubmarineError {
    crate::SubmarineError::InvalidSpec(msg)
}

fn two_floats(j: &Json, kind: &str, name: &str) -> crate::Result<(f64, f64)> {
    let arr = j.as_arr().unwrap_or(&[]);
    let (lo, hi) = match (arr.first(), arr.get(1)) {
        (Some(a), Some(b)) => (a.as_f64(), b.as_f64()),
        _ => (None, None),
    };
    match (lo, hi) {
        (Some(lo), Some(hi)) if lo.is_finite() && hi.is_finite() && lo < hi => {
            Ok((lo, hi))
        }
        _ => Err(bad(format!(
            "space.{name}.{kind} must be [lo, hi] with lo < hi"
        ))),
    }
}

/// `{"log_uniform":[lo,hi]}` | `{"uniform":[lo,hi]}` |
/// `{"choice":["a","b",...]}`.
fn parse_space_entry(name: &str, j: &Json) -> crate::Result<ParamSpace> {
    if let Some(r) = j.get("log_uniform") {
        let (lo, hi) = two_floats(r, "log_uniform", name)?;
        if lo <= 0.0 {
            return Err(bad(format!(
                "space.{name}.log_uniform needs lo > 0"
            )));
        }
        return Ok(ParamSpace::LogUniform { lo, hi });
    }
    if let Some(r) = j.get("uniform") {
        let (lo, hi) = two_floats(r, "uniform", name)?;
        return Ok(ParamSpace::Uniform { lo, hi });
    }
    if let Some(r) = j.get("choice") {
        let choices: Vec<String> = r
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|v| match v {
                Json::Str(s) => s.clone(),
                other => other.dump(),
            })
            .collect();
        if choices.is_empty() {
            return Err(bad(format!(
                "space.{name}.choice must be a non-empty array"
            )));
        }
        return Ok(ParamSpace::Choice(choices));
    }
    Err(bad(format!(
        "space.{name} needs one of log_uniform | uniform | choice"
    )))
}

/// Parse and validate the tune request body.
pub fn parse_request(j: &Json) -> crate::Result<TuneRequest> {
    let strategy = match j.str_field("strategy").unwrap_or("random_search")
    {
        "random_search" => Strategy::RandomSearch,
        "successive_halving" => Strategy::SuccessiveHalving,
        other => {
            return Err(bad(format!(
                "unknown strategy {other:?} \
                 (random_search | successive_halving)"
            )))
        }
    };
    let trials = j.num_field("trials").unwrap_or(8.0) as usize;
    if trials == 0 || trials > MAX_TRIALS {
        return Err(bad(format!(
            "trials must be in 1..={MAX_TRIALS}"
        )));
    }
    let budget = j.num_field("budget").unwrap_or(100.0) as u32;
    if budget == 0 {
        return Err(bad("budget must be >= 1".into()));
    }
    let min_budget =
        j.num_field("min_budget").unwrap_or((budget / 4).max(1) as f64)
            as u32;
    let max_budget = j.num_field("max_budget").unwrap_or(budget as f64) as u32;
    if min_budget == 0 || max_budget < min_budget {
        return Err(bad(
            "need 1 <= min_budget <= max_budget".into(),
        ));
    }
    let mut space = BTreeMap::new();
    if let Some(Json::Obj(entries)) = j.get("space") {
        for (name, entry) in entries {
            space.insert(name.clone(), parse_space_entry(name, entry)?);
        }
    }
    if space.is_empty() {
        return Err(bad(
            "space must declare at least one parameter".into(),
        ));
    }
    let template = j.str_field("template").map(str::to_string);
    let base_spec = j.get("spec").cloned();
    if template.is_some() == base_spec.is_some() {
        return Err(bad(
            "provide exactly one of template (registered name) or \
             spec (raw experiment spec with {{param}} placeholders)"
                .into(),
        ));
    }
    Ok(TuneRequest {
        strategy,
        trials,
        budget,
        min_budget,
        max_budget,
        seed: j.num_field("seed").unwrap_or(42.0) as u64,
        trial_timeout_ms: j
            .num_field("trial_timeout_ms")
            .unwrap_or(10_000.0) as u64,
        space,
        template,
        base_spec,
    })
}

/// One completed trial: the child experiment it ran as, plus its score.
#[derive(Debug, Clone)]
pub struct TrialRun {
    pub experiment_id: String,
    pub params: BTreeMap<String, String>,
    pub score: f64,
    pub budget: u32,
    pub status: String,
}

impl TrialRun {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("experimentId", Json::Str(self.experiment_id.clone()))
            .set("params", Json::from_map(&self.params))
            .set("score", Json::Num(self.score))
            .set("budget", Json::Num(self.budget as f64))
            .set("status", Json::Str(self.status.clone()))
    }
}

/// Deterministic stand-in objective for simulated trials. The cluster
/// sim runs no real training, so tune over the sim pipeline scores a
/// reproducible surrogate: a concave bowl peaking at learning rate 0.05
/// plus pseudo-noise that shrinks with budget (so successive halving's
/// rungs behave as they would against a real objective). Callers prefer
/// a real logged metric when one exists (local submitter trials).
pub fn surrogate_objective(
    params: &BTreeMap<String, String>,
    budget: u32,
    seed: u64,
) -> f64 {
    let mut quality = 0.0;
    let mut h: u64 = seed ^ 0x9E37_79B9_7F4A_7C15;
    for (k, v) in params {
        for b in k.bytes().chain(v.bytes()) {
            h = h.wrapping_mul(1_099_511_628_211).wrapping_add(b as u64);
        }
        if !(k.contains("lr") || k.contains("learning_rate")) {
            continue;
        }
        if let Ok(x) = v.parse::<f64>() {
            if x > 0.0 {
                quality -= (x.ln() - (0.05f64).ln()).powi(2);
            }
        }
    }
    let mut rng = crate::util::rng::Rng::new(h);
    let noise = (rng.f64() - 0.5) / (budget.max(1) as f64).sqrt();
    quality + 0.1 * noise
}

/// Run the requested strategy, with `run_trial` executing each
/// configuration as a child experiment. Returns the response payload.
pub fn run_tune(
    req: &TuneRequest,
    mut run_trial: impl FnMut(&BTreeMap<String, String>, u32) -> TrialRun,
) -> Json {
    let mut runs: Vec<TrialRun> = Vec::new();
    let result = {
        let eval =
            |params: &BTreeMap<String, String>, budget: u32| -> f64 {
                let r = run_trial(params, budget);
                let score = r.score;
                runs.push(r);
                score
            };
        match req.strategy {
            Strategy::RandomSearch => random_search(
                &req.space, req.trials, req.budget, req.seed, eval,
            ),
            Strategy::SuccessiveHalving => successive_halving(
                &req.space,
                req.trials,
                req.min_budget,
                req.max_budget,
                req.seed,
                eval,
            ),
        }
    };
    let best_id = runs
        .iter()
        .rev()
        .find(|r| {
            r.params == result.best.params
                && r.budget == result.best.budget
        })
        .map(|r| r.experiment_id.clone())
        .unwrap_or_default();
    Json::obj()
        .set("strategy", Json::Str(req.strategy.as_str().into()))
        .set(
            "best",
            Json::obj()
                .set("experimentId", Json::Str(best_id))
                .set("params", Json::from_map(&result.best.params))
                .set("score", Json::Num(result.best.score))
                .set("budget", Json::Num(result.best.budget as f64)),
        )
        .set(
            "trials",
            Json::Arr(runs.iter().map(TrialRun::to_json).collect()),
        )
        .set("total_budget", Json::Num(result.total_budget as f64))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn body(extra: &str) -> Json {
        Json::parse(&format!(
            r#"{{"template":"t","space":{{"learning_rate":
                {{"log_uniform":[0.0001,1.0]}}}}{extra}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn parses_defaults() {
        let r = parse_request(&body("")).unwrap();
        assert_eq!(r.strategy, Strategy::RandomSearch);
        assert_eq!(r.trials, 8);
        assert_eq!(r.budget, 100);
        assert_eq!(r.template.as_deref(), Some("t"));
        assert!(r.base_spec.is_none());
    }

    #[test]
    fn rejects_bad_requests() {
        // no spec source
        assert!(parse_request(
            &Json::parse(
                r#"{"space":{"x":{"uniform":[0,1]}}}"#
            )
            .unwrap()
        )
        .is_err());
        // empty space
        assert!(parse_request(
            &Json::parse(r#"{"template":"t","space":{}}"#).unwrap()
        )
        .is_err());
        // bad strategy / trials / range
        assert!(parse_request(&body(r#","strategy":"grid""#)).is_err());
        assert!(parse_request(&body(r#","trials":0"#)).is_err());
        assert!(parse_request(&body(r#","trials":1000"#)).is_err());
        let bad_range = Json::parse(
            r#"{"template":"t",
                "space":{"x":{"uniform":[1.0,0.0]}}}"#,
        )
        .unwrap();
        assert!(parse_request(&bad_range).is_err());
        let bad_log = Json::parse(
            r#"{"template":"t",
                "space":{"x":{"log_uniform":[0.0,1.0]}}}"#,
        )
        .unwrap();
        assert!(parse_request(&bad_log).is_err());
    }

    #[test]
    fn choice_space_parses_mixed_values() {
        let j = Json::parse(
            r#"{"template":"t",
                "space":{"batch":{"choice":[64,"128"]}}}"#,
        )
        .unwrap();
        let r = parse_request(&j).unwrap();
        match &r.space["batch"] {
            ParamSpace::Choice(c) => {
                assert_eq!(c, &vec!["64".to_string(), "128".to_string()])
            }
            other => panic!("wrong space: {other:?}"),
        }
    }

    #[test]
    fn surrogate_peaks_near_good_lr_and_is_deterministic() {
        let p = |lr: &str| {
            let mut m = BTreeMap::new();
            m.insert("learning_rate".to_string(), lr.to_string());
            m
        };
        let good = surrogate_objective(&p("0.05"), 100, 7);
        let bad = surrogate_objective(&p("0.8"), 100, 7);
        assert!(good > bad, "good={good} bad={bad}");
        assert_eq!(
            surrogate_objective(&p("0.1"), 50, 7),
            surrogate_objective(&p("0.1"), 50, 7)
        );
    }

    #[test]
    fn run_tune_records_every_trial_and_best_id() {
        let req = parse_request(&body(r#","trials":5,"budget":10"#))
            .unwrap();
        let mut n = 0;
        let out = run_tune(&req, |params, budget| {
            n += 1;
            TrialRun {
                experiment_id: format!("exp-{n}"),
                params: params.clone(),
                score: surrogate_objective(params, budget, 1),
                budget,
                status: "Succeeded".into(),
            }
        });
        let trials = out.get("trials").unwrap().as_arr().unwrap();
        assert_eq!(trials.len(), 5);
        let best_id = out
            .at(&["best", "experimentId"])
            .and_then(Json::as_str)
            .unwrap();
        assert!(best_id.starts_with("exp-"), "{best_id}");
        assert_eq!(out.num_field("total_budget"), Some(50.0));
    }
}
