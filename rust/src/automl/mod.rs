//! AutoML: hyperparameter search over predefined templates (paper §4.1 —
//! the in-progress feature, implemented).
//!
//! Two search strategies over a template's parameter space:
//! - [`random_search`]: N trials sampled from the declared ranges.
//! - [`successive_halving`]: the standard multi-fidelity racing scheme —
//!   start many cheap trials, keep the best half at each rung with a
//!   growing budget.
//!
//! Both treat the trial as a black box `params -> score` so they can
//! drive real training (examples) or a surrogate (tests/benches).

use crate::util::rng::Rng;
use std::collections::BTreeMap;

pub mod tune;

/// Search space for one parameter.
#[derive(Debug, Clone)]
pub enum ParamSpace {
    /// Log-uniform over `[lo, hi]` (learning rates etc.).
    LogUniform { lo: f64, hi: f64 },
    /// Uniform over `[lo, hi]`.
    Uniform { lo: f64, hi: f64 },
    /// One of the given choices.
    Choice(Vec<String>),
}

impl ParamSpace {
    fn sample(&self, rng: &mut Rng) -> String {
        match self {
            ParamSpace::LogUniform { lo, hi } => {
                let v = (lo.ln() + rng.f64() * (hi.ln() - lo.ln())).exp();
                format!("{v:.6}")
            }
            ParamSpace::Uniform { lo, hi } => {
                format!("{:.6}", lo + rng.f64() * (hi - lo))
            }
            ParamSpace::Choice(cs) => rng.choose(cs).clone(),
        }
    }
}

/// One completed trial.
#[derive(Debug, Clone)]
pub struct Trial {
    pub params: BTreeMap<String, String>,
    pub score: f64,
    /// Budget (e.g. training steps) the trial ran with.
    pub budget: u32,
}

/// Result of a search.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub best: Trial,
    pub trials: Vec<Trial>,
    pub total_budget: u64,
}

/// Random search: `n` trials at full `budget`. Maximizes `eval`.
pub fn random_search(
    space: &BTreeMap<String, ParamSpace>,
    n: usize,
    budget: u32,
    seed: u64,
    mut eval: impl FnMut(&BTreeMap<String, String>, u32) -> f64,
) -> SearchResult {
    let mut rng = Rng::new(seed);
    let mut trials = Vec::with_capacity(n);
    for _ in 0..n {
        let params: BTreeMap<String, String> = space
            .iter()
            .map(|(k, s)| (k.clone(), s.sample(&mut rng)))
            .collect();
        let score = eval(&params, budget);
        trials.push(Trial {
            params,
            score,
            budget,
        });
    }
    finish(trials, n as u64 * budget as u64)
}

/// Successive halving: start `n` configs at `min_budget`, keep the best
/// half each rung, double the budget, until one survives or the budget
/// reaches `max_budget`. Maximizes `eval`.
pub fn successive_halving(
    space: &BTreeMap<String, ParamSpace>,
    n: usize,
    min_budget: u32,
    max_budget: u32,
    seed: u64,
    mut eval: impl FnMut(&BTreeMap<String, String>, u32) -> f64,
) -> SearchResult {
    let mut rng = Rng::new(seed);
    let mut alive: Vec<BTreeMap<String, String>> = (0..n.max(1))
        .map(|_| {
            space
                .iter()
                .map(|(k, s)| (k.clone(), s.sample(&mut rng)))
                .collect()
        })
        .collect();
    let mut budget = min_budget.max(1);
    let mut all = Vec::new();
    let mut total = 0u64;
    loop {
        let mut scored: Vec<Trial> = alive
            .iter()
            .map(|p| {
                total += budget as u64;
                Trial {
                    params: p.clone(),
                    score: eval(p, budget),
                    budget,
                }
            })
            .collect();
        scored.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        all.extend(scored.iter().cloned());
        if scored.len() == 1 || budget >= max_budget {
            return finish(all, total);
        }
        let keep = (scored.len() + 1) / 2;
        alive = scored
            .into_iter()
            .take(keep)
            .map(|t| t.params)
            .collect();
        budget = (budget * 2).min(max_budget);
    }
}

fn finish(trials: Vec<Trial>, total_budget: u64) -> SearchResult {
    let best = trials
        .iter()
        .max_by(|a, b| {
            (a.score, a.budget)
                .partial_cmp(&(b.score, b.budget))
                .unwrap()
        })
        .cloned()
        .expect("at least one trial");
    SearchResult {
        best,
        trials,
        total_budget,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space() -> BTreeMap<String, ParamSpace> {
        let mut s = BTreeMap::new();
        s.insert(
            "learning_rate".to_string(),
            ParamSpace::LogUniform {
                lo: 1e-4,
                hi: 1.0,
            },
        );
        s.insert(
            "batch_size".to_string(),
            ParamSpace::Choice(vec![
                "64".into(),
                "128".into(),
                "256".into(),
            ]),
        );
        s
    }

    /// Surrogate objective: peak at lr=0.05, more budget -> less noise.
    fn surrogate(p: &BTreeMap<String, String>, budget: u32) -> f64 {
        let lr: f64 = p["learning_rate"].parse().unwrap();
        let noise = 1.0 / (budget as f64).sqrt();
        let quality = -((lr.ln() - (0.05f64).ln()).powi(2));
        quality - noise * 0.1
    }

    #[test]
    fn random_search_finds_good_region() {
        let r = random_search(&space(), 40, 10, 7, surrogate);
        assert_eq!(r.trials.len(), 40);
        let lr: f64 = r.best.params["learning_rate"].parse().unwrap();
        assert!(lr > 0.003 && lr < 0.8, "lr={lr}");
        assert_eq!(r.total_budget, 400);
    }

    #[test]
    fn halving_spends_less_than_full_random() {
        let r = successive_halving(&space(), 16, 5, 40, 7, surrogate);
        // full random at max budget would be 16*40=640
        assert!(r.total_budget < 640, "{}", r.total_budget);
        // survivor ran at (close to) max budget
        assert!(r.best.budget >= 20);
    }

    #[test]
    fn halving_prefers_better_configs() {
        let r = successive_halving(&space(), 32, 4, 64, 3, surrogate);
        let best_lr: f64 =
            r.best.params["learning_rate"].parse().unwrap();
        // all surviving scores must dominate first-rung median
        assert!(best_lr > 1e-3 && best_lr < 1.0);
        assert!(r.best.score >= r.trials[0].score - 1e-9);
    }

    #[test]
    fn deterministic_for_seed() {
        let a = random_search(&space(), 5, 1, 11, surrogate);
        let b = random_search(&space(), 5, 1, 11, surrogate);
        assert_eq!(a.best.params, b.best.params);
    }

    #[test]
    fn choice_sampling_respects_options() {
        let r = random_search(&space(), 20, 1, 1, surrogate);
        for t in &r.trials {
            assert!(["64", "128", "256"]
                .contains(&t.params["batch_size"].as_str()));
        }
    }
}
