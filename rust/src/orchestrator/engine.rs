//! Execution engine: the background scheduler loop that closes the
//! paper's submit→schedule→monitor pipeline (Fig. 4, §5.1.5).
//!
//! PR 1 built the REST surface and PR 2 the persisted status path, but an
//! experiment POSTed to the API still sat `Accepted` forever: nothing
//! drove the scheduler or advanced simulated time. The engine owns that
//! loop — every tick it pumps the [`SimSubmitter`], which runs one
//! scheduling pass (placing accepted jobs through the capacity tree onto
//! the cluster sim) and advances simulated time so container lifecycle
//! events flow into the [`crate::experiment::monitor::ExperimentMonitor`]
//! and, via the PR-2 status observer, into the persisted, index-filtered
//! experiment status.

use super::sim_submitter::SimSubmitter;
use crate::util::clock::SimTime;
use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// How the background loop maps real time to simulated time.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Real-time sleep between scheduling passes.
    pub tick: std::time::Duration,
    /// Simulated time advanced per pass.
    pub sim_step: SimTime,
}

impl Default for EngineConfig {
    fn default() -> Self {
        // 1ms real : 250ms simulated — a 60s-container experiment
        // completes in ~a quarter second of wall time while the sim
        // clock stays fine-grained enough for Running to be observable.
        EngineConfig {
            tick: std::time::Duration::from_millis(1),
            sim_step: SimTime::from_millis(250),
        }
    }
}

/// Handle on the background scheduler loop. Owned by
/// [`crate::httpd::server::Services`]; dropping it stops the loop.
pub struct ExecutionEngine {
    submitter: Arc<SimSubmitter>,
    stop: Arc<AtomicBool>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ExecutionEngine {
    /// Spawn the loop over `submitter`.
    pub fn start(
        submitter: Arc<SimSubmitter>,
        cfg: EngineConfig,
    ) -> Arc<ExecutionEngine> {
        let stop = Arc::new(AtomicBool::new(false));
        let loop_stop = Arc::clone(&stop);
        let loop_submitter = Arc::clone(&submitter);
        let handle = std::thread::Builder::new()
            .name("submarine-engine".into())
            .spawn(move || {
                while !loop_stop.load(Ordering::Acquire) {
                    // Only pump (and so advance simulated time) when a
                    // pass could do something: an idle server must not
                    // dilute gpu_utilization with idle sim time or burn
                    // CPU on empty scheduling passes.
                    if loop_submitter.has_work() {
                        loop_submitter.pump(cfg.sim_step);
                    }
                    std::thread::sleep(cfg.tick);
                }
            })
            .expect("spawn engine thread");
        Arc::new(ExecutionEngine {
            submitter,
            stop,
            handle: Mutex::new(Some(handle)),
        })
    }

    /// The submitter the loop drives (status queries, tests).
    pub fn submitter(&self) -> &Arc<SimSubmitter> {
        &self.submitter
    }

    /// Cluster + queue snapshot for `GET /cluster`.
    pub fn cluster_status(&self) -> Json {
        self.submitter.cluster_status()
    }

    /// Stop the loop and join the thread (idempotent).
    pub fn shutdown(&self) {
        // Release pairs with the loop's Acquire: work completed before
        // shutdown is visible to whoever observes the stop.
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self
            .handle
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
        {
            let _ = h.join();
        }
    }
}

impl Drop for ExecutionEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSim, Resources};
    use crate::experiment::monitor::ExperimentMonitor;
    use crate::experiment::spec::{ExperimentSpec, ExperimentStatus};
    use crate::orchestrator::Submitter;
    use crate::scheduler::queue::QueueTree;
    use crate::scheduler::yarn::YarnScheduler;

    fn fast_submitter() -> Arc<SimSubmitter> {
        let sim =
            ClusterSim::homogeneous(2, Resources::new(16, 65536, 4), 2);
        Arc::new(
            SimSubmitter::new(
                Box::new(YarnScheduler::new(QueueTree::flat())),
                sim,
                Arc::new(ExperimentMonitor::new()),
            )
            .with_container_duration(SimTime::from_millis(100)),
        )
    }

    #[test]
    fn background_loop_completes_experiments() {
        let submitter = fast_submitter();
        let monitor = Arc::clone(submitter.monitor());
        let engine = ExecutionEngine::start(
            Arc::clone(&submitter),
            EngineConfig {
                tick: std::time::Duration::from_millis(1),
                sim_step: SimTime::from_millis(50),
            },
        );
        let spec = ExperimentSpec::parse(
            r#"{"meta":{"name":"bg"},
                "spec":{"Worker":{"replicas":2,"resources":"cpu=1"}}}"#,
        )
        .unwrap();
        monitor.watch("e-bg", spec.total_containers());
        submitter.submit("e-bg", &spec).unwrap();
        // no manual pump: the engine's thread must finish the experiment
        let deadline = std::time::Instant::now()
            + std::time::Duration::from_secs(5);
        while monitor.status("e-bg") != ExperimentStatus::Succeeded {
            assert!(
                std::time::Instant::now() < deadline,
                "experiment stuck in {:?}",
                monitor.status("e-bg")
            );
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        engine.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_and_drop_stops_loop() {
        let engine = ExecutionEngine::start(
            fast_submitter(),
            EngineConfig::default(),
        );
        engine.shutdown();
        engine.shutdown();
        drop(engine);
    }
}
