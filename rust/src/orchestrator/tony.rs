//! TonY-like distributed training driver (paper §3.2.2: "YARN submitter
//! uses TensorFlow on YARN (TonY) as the runtime"; §6.1 Ke.com speedup).
//!
//! Synchronous data-parallel SGD over `n` simulated workers:
//!
//! 1. every worker runs the AOT `grad_step` on its own batch (real PJRT
//!    execution, real numerics),
//! 2. the coordinator all-reduces (averages) the gradients in Rust,
//! 3. one `apply_update` produces the next parameter state.
//!
//! The testbed has one CPU core, so worker grad-steps execute
//! sequentially; *simulated* wall-clock assumes the workers ran in
//! parallel (max of their measured times) plus a ring all-reduce network
//! model — exactly the substitution DESIGN.md documents for the Ke.com
//! experiment (E3).  Loss/accuracy numbers are real; only the clock is
//! modeled.

use crate::data::BatchGen;
use crate::runtime::engine::{self, Engine, HostTensor};
use crate::util::clock::Stopwatch;

/// Network model for gradient synchronization.
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// Link bandwidth per node, bytes/second.
    pub bandwidth_bps: f64,
    /// Per-hop latency, seconds.
    pub latency_s: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        // 10 GbE with ~150us hop latency — a typical on-prem GPU-cluster
        // fabric of the paper's era (Ke.com §6.1).
        NetworkModel {
            bandwidth_bps: 10.0e9 / 8.0,
            latency_s: 150e-6,
        }
    }
}

impl NetworkModel {
    /// Ring all-reduce time for `bytes` over `n` workers.
    pub fn allreduce_secs(&self, n: usize, bytes: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let steps = 2 * (n - 1);
        let chunk = bytes as f64 / n as f64;
        steps as f64 * (chunk / self.bandwidth_bps + self.latency_s)
    }
}

/// Configuration for one distributed run.
#[derive(Debug, Clone)]
pub struct TonyConfig {
    pub model: String,
    pub workers: usize,
    pub steps: u32,
    pub lr: f32,
    pub seed: u64,
    pub network: NetworkModel,
}

impl Default for TonyConfig {
    fn default() -> Self {
        TonyConfig {
            model: "mnist_mlp".into(),
            workers: 1,
            steps: 50,
            lr: 0.05,
            seed: 42,
            network: NetworkModel::default(),
        }
    }
}

/// Result of a distributed run.
#[derive(Debug, Clone)]
pub struct TonyReport {
    pub losses: Vec<f32>,
    /// Measured mean per-worker grad-step compute time (seconds).
    pub compute_per_step_s: f64,
    /// Modeled all-reduce time per step (seconds).
    pub comm_per_step_s: f64,
    /// Simulated wall time per step = max-worker compute + comm + apply.
    pub sim_step_s: f64,
    /// Global samples/sec at the simulated step time.
    pub samples_per_s: f64,
    pub grad_bytes: usize,
    pub batch_per_worker: usize,
}

/// Run synchronous data-parallel training from the model's initial
/// parameters. Returns the final parameters alongside the report so
/// callers can evaluate or register the model.
pub fn run(
    engine: &Engine,
    cfg: &TonyConfig,
) -> crate::Result<(Vec<Vec<f32>>, TonyReport)> {
    let params = engine.manifest.load_params(&cfg.model)?;
    run_from(engine, cfg, params)
}

/// Like [`run`] but continuing from the given parameter state (used by
/// the local submitter to train in kill-checkable chunks).
pub fn run_from(
    engine: &Engine,
    cfg: &TonyConfig,
    initial_params: Vec<Vec<f32>>,
) -> crate::Result<(Vec<Vec<f32>>, TonyReport)> {
    let entry = engine.manifest.model(&cfg.model)?.clone();
    let n_params = entry.param_order.len();
    let single = cfg.workers <= 1;
    // PERF (EXPERIMENTS.md §Perf L3-1/L3-2): parameters live as XLA
    // `Literal`s across steps — outputs of step N feed step N+1 directly
    // with no host Vec<f32> round-trip.  Single-worker runs use the fused
    // `train_step` artifact (one PJRT call per step) instead of the
    // grad/allreduce/apply split that only multi-worker needs.
    let step_exe = if single {
        engine.executable(&cfg.model, "train_step")?
    } else {
        engine.executable(&cfg.model, "grad_step")?
    };
    let apply_exe = if single {
        None
    } else {
        Some(engine.executable(&cfg.model, "apply_update")?)
    };

    let param_shapes: Vec<Vec<usize>> = entry
        .param_order
        .iter()
        .map(|p| entry.param_shapes[p].clone())
        .collect();
    let mut params_lit: Vec<xla::Literal> = initial_params
        .iter()
        .zip(&param_shapes)
        .map(|(vals, shape)| engine::literal_f32(vals, shape))
        .collect::<crate::Result<_>>()?;
    let grad_bytes: usize =
        initial_params.iter().map(|p| p.len() * 4).sum();

    let batch_artifact = if single { "train_step" } else { "grad_step" };
    let batch_meta: Vec<_> = entry
        .batch_meta(batch_artifact)
        .unwrap_or_default()
        .to_vec();
    let batch_per_worker = batch_meta
        .first()
        .map(|t| t.shape.first().copied().unwrap_or(1))
        .unwrap_or(1);
    let lr_lit = engine::literal_f32(&[cfg.lr], &[])?;

    // One independent data stream per worker.
    let mut gens: Vec<Box<dyn BatchGen + Send>> = (0..cfg.workers)
        .map(|w| crate::data::for_model(&cfg.model, cfg.seed + w as u64))
        .collect::<crate::Result<_>>()?;

    let mut losses = Vec::with_capacity(cfg.steps as usize);
    let mut compute_time = 0.0f64;
    let mut apply_time = 0.0f64;
    let mut max_worker_time_total = 0.0f64;

    for _step in 0..cfg.steps {
        if single {
            // fused path: params', loss = train_step(params, batch, lr).
            // Inputs are *borrowed* literals — zero copies on the rust
            // side; params never leave literal form between steps.
            let batch = gens[0].next_batch();
            let mut batch_lits = Vec::with_capacity(batch.len() + 1);
            for (t, meta) in batch.iter().zip(&batch_meta) {
                if meta.name == "lr" {
                    break;
                }
                batch_lits.push(t.to_literal(meta)?);
            }
            let inputs: Vec<&xla::Literal> = params_lit
                .iter()
                .chain(batch_lits.iter())
                .chain(std::iter::once(&lr_lit))
                .collect();
            let sw = Stopwatch::start();
            let mut out = engine.run_ref(&step_exe, &inputs)?;
            let dt = sw.elapsed_secs();
            compute_time += dt;
            max_worker_time_total += dt;
            losses.push(engine::to_f32_scalar(&out[n_params])?);
            out.truncate(n_params);
            params_lit = out;
            continue;
        }
        // --- per-worker grad steps (sequential execution, parallel model)
        let mut grad_sum: Vec<Vec<f32>> = param_shapes
            .iter()
            .map(|s| vec![0.0; s.iter().product::<usize>().max(1)])
            .collect();
        let mut loss_sum = 0.0f32;
        let mut max_worker = 0.0f64;
        for gen in gens.iter_mut() {
            let batch = gen.next_batch();
            let mut batch_lits = Vec::with_capacity(batch.len());
            for (t, meta) in batch.iter().zip(&batch_meta) {
                batch_lits.push(t.to_literal(meta)?);
            }
            let inputs: Vec<&xla::Literal> = params_lit
                .iter()
                .chain(batch_lits.iter())
                .collect();
            let sw = Stopwatch::start();
            let out = engine.run_ref(&step_exe, &inputs)?;
            let dt = sw.elapsed_secs();
            compute_time += dt;
            max_worker = max_worker.max(dt);
            for (acc, lit) in grad_sum.iter_mut().zip(&out[..n_params]) {
                let g = engine::to_f32_vec(lit)?;
                for (a, b) in acc.iter_mut().zip(&g) {
                    *a += b;
                }
            }
            loss_sum += engine::to_f32_scalar(&out[n_params])?;
        }
        max_worker_time_total += max_worker;
        // --- all-reduce = average (real arithmetic, modeled clock)
        let inv = 1.0 / cfg.workers as f32;
        for g in grad_sum.iter_mut() {
            for v in g.iter_mut() {
                *v *= inv;
            }
        }
        losses.push(loss_sum * inv);
        // --- apply update once
        let mut grad_lits = Vec::with_capacity(n_params);
        for (vals, shape) in grad_sum.iter().zip(&param_shapes) {
            grad_lits.push(engine::literal_f32(vals, shape)?);
        }
        let inputs: Vec<&xla::Literal> = params_lit
            .iter()
            .chain(grad_lits.iter())
            .chain(std::iter::once(&lr_lit))
            .collect();
        let sw = Stopwatch::start();
        let mut out =
            engine.run_ref(apply_exe.as_ref().unwrap(), &inputs)?;
        apply_time += sw.elapsed_secs();
        out.truncate(n_params);
        params_lit = out;
    }
    let params: Vec<Vec<f32>> = params_lit
        .iter()
        .map(engine::to_f32_vec)
        .collect::<crate::Result<_>>()?;

    let steps = cfg.steps.max(1) as f64;
    let comm_per_step =
        cfg.network.allreduce_secs(cfg.workers, grad_bytes);
    let sim_step = max_worker_time_total / steps
        + comm_per_step
        + apply_time / steps;
    let report = TonyReport {
        losses,
        compute_per_step_s: compute_time / (steps * cfg.workers as f64),
        comm_per_step_s: comm_per_step,
        sim_step_s: sim_step,
        samples_per_s: (batch_per_worker * cfg.workers) as f64 / sim_step,
        grad_bytes,
        batch_per_worker,
    };
    Ok((params, report))
}

/// Evaluate `predict` on fresh data; returns model scores + the batch.
pub fn predict_scores(
    engine: &Engine,
    model: &str,
    params: &[Vec<f32>],
    gen: &mut dyn BatchGen,
) -> crate::Result<(Vec<f32>, Vec<HostTensor>)> {
    let entry = engine.manifest.model(model)?.clone();
    let exe = engine.executable(model, "predict")?;
    let batch = gen.next_batch();
    let n_inputs = entry
        .batch_meta("predict")
        .map(|b| b.len())
        .unwrap_or(0);
    let mut inputs = Vec::new();
    for (p, name) in params.iter().zip(&entry.param_order) {
        inputs.push(engine::literal_f32(p, &entry.param_shapes[name])?);
    }
    let metas = entry.batch_meta("predict").unwrap_or_default();
    for (t, meta) in batch.iter().take(n_inputs).zip(metas) {
        inputs.push(t.to_literal(meta)?);
    }
    let out = engine.run(&exe, &inputs)?;
    Ok((engine::to_f32_vec(&out[0])?, batch))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_model_scales() {
        let net = NetworkModel::default();
        assert_eq!(net.allreduce_secs(1, 1_000_000), 0.0);
        let t2 = net.allreduce_secs(2, 1_000_000);
        let t4 = net.allreduce_secs(4, 1_000_000);
        assert!(t2 > 0.0);
        // ring all-reduce: 2(n-1)/n * size/BW -> grows sub-linearly
        assert!(t4 > t2);
        assert!(t4 < t2 * 4.0);
    }

    fn engine() -> Option<Engine> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return None;
        }
        Some(
            Engine::new(
                crate::runtime::Manifest::load(&dir).unwrap(),
            )
            .unwrap(),
        )
    }

    #[test]
    fn single_worker_training_reduces_loss() {
        let Some(e) = engine() else { return };
        let cfg = TonyConfig {
            steps: 12,
            ..Default::default()
        };
        let (_params, rep) = run(&e, &cfg).unwrap();
        assert_eq!(rep.losses.len(), 12);
        let first = rep.losses[0];
        let last = *rep.losses.last().unwrap();
        assert!(last < first, "loss {first} -> {last}");
        assert!(rep.sim_step_s > 0.0);
    }

    #[test]
    fn two_workers_match_loss_and_model_speedup() {
        let Some(e) = engine() else { return };
        let cfg1 = TonyConfig {
            steps: 6,
            ..Default::default()
        };
        let (_p, r1) = run(&e, &cfg1).unwrap();
        let cfg2 = TonyConfig {
            workers: 2,
            steps: 6,
            ..Default::default()
        };
        let (_p, r2) = run(&e, &cfg2).unwrap();
        assert!(r2.comm_per_step_s > 0.0);
        // weak scaling: 2 workers process ~2x samples per sim step
        // (wide bounds: wall-clock timing on a shared CPU is noisy)
        let speedup = r2.samples_per_s / r1.samples_per_s;
        assert!(speedup > 1.05 && speedup < 2.5, "speedup={speedup}");
    }
}
