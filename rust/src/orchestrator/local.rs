//! Local submitter: runs the experiment's bound workload *for real* on
//! the PJRT runtime (paper Fig. 4: "experiments can be launched in YARN
//! cluster, Kubernetes cluster or locally").
//!
//! Because the `xla` wrappers are not `Send`, each submitted experiment
//! runs on a dedicated OS thread that owns its own [`Engine`].  Metrics
//! stream into the shared [`MetricStore`]; lifecycle events flow into the
//! [`ExperimentMonitor`].

use super::tony::{self, TonyConfig};
use super::Submitter;
use crate::experiment::monitor::{Event, ExperimentMonitor};
use crate::experiment::spec::ExperimentSpec;
use crate::runtime::Engine;
use crate::storage::MetricStore;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

pub struct LocalSubmitter {
    monitor: Arc<ExperimentMonitor>,
    metrics: Arc<MetricStore>,
    artifacts_dir: std::path::PathBuf,
    kill_flags: Mutex<BTreeMap<String, Arc<AtomicBool>>>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl LocalSubmitter {
    pub fn new(
        monitor: Arc<ExperimentMonitor>,
        metrics: Arc<MetricStore>,
        artifacts_dir: &std::path::Path,
    ) -> LocalSubmitter {
        LocalSubmitter {
            monitor,
            metrics,
            artifacts_dir: artifacts_dir.to_path_buf(),
            kill_flags: Mutex::new(BTreeMap::new()),
            threads: Mutex::new(Vec::new()),
        }
    }

    /// Block until every submitted experiment thread has finished
    /// (examples call this before reading final metrics).
    pub fn join_all(&self) {
        let mut g = self
            .threads
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        for t in g.drain(..) {
            let _ = t.join();
        }
    }
}

impl Submitter for LocalSubmitter {
    fn name(&self) -> &'static str {
        "local"
    }

    fn submit(&self, id: &str, spec: &ExperimentSpec) -> crate::Result<()> {
        let workload = spec.workload.clone().unwrap_or_default();
        let workers: usize = spec
            .tasks
            .iter()
            .filter(|(name, _)| name.to_lowercase().contains("worker"))
            .map(|(_, t)| t.replicas as usize)
            .sum::<usize>()
            .max(1);
        let kill = Arc::new(AtomicBool::new(false));
        self.kill_flags
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(id.to_string(), Arc::clone(&kill));

        let monitor = Arc::clone(&self.monitor);
        let metrics = Arc::clone(&self.metrics);
        let artifacts = self.artifacts_dir.clone();
        let id = id.to_string();
        let total = spec.total_containers();
        let handle = std::thread::Builder::new()
            .name(format!("local-{id}"))
            .spawn(move || {
                // Containers "start" when the runtime begins.
                for c in 0..total {
                    monitor.record(
                        &id,
                        Event::ContainerStarted {
                            container: format!("{id}-task-{c}"),
                        },
                    );
                }
                let run = || -> crate::Result<()> {
                    let manifest =
                        crate::runtime::Manifest::load(&artifacts)?;
                    let engine = Engine::new(manifest)?;
                    let cfg = TonyConfig {
                        model: workload.model.clone(),
                        workers,
                        steps: workload.steps,
                        lr: workload.lr,
                        seed: workload.seed,
                        ..Default::default()
                    };
                    // Run in chunks so kills take effect mid-training.
                    let chunk = 10u32;
                    let mut done = 0u32;
                    let mut step_base = 0u64;
                    let mut cfg_chunk = cfg.clone();
                    // carry params across chunks via a local override of
                    // the manifest initial params
                    let mut params: Option<Vec<Vec<f32>>> = None;
                    while done < cfg.steps {
                        if kill.load(Ordering::Acquire) {
                            return Ok(());
                        }
                        cfg_chunk.steps = chunk.min(cfg.steps - done);
                        cfg_chunk.seed =
                            cfg.seed.wrapping_add(done as u64);
                        let (p, rep) = match params.take() {
                            None => tony::run(&engine, &cfg_chunk)?,
                            Some(p) => tony::run_from(
                                &engine, &cfg_chunk, p,
                            )?,
                        };
                        for (i, l) in rep.losses.iter().enumerate() {
                            metrics.log(
                                &id,
                                "loss",
                                step_base + i as u64,
                                *l as f64,
                            );
                        }
                        metrics.log(
                            &id,
                            "samples_per_s",
                            step_base + rep.losses.len() as u64,
                            rep.samples_per_s,
                        );
                        step_base += rep.losses.len() as u64;
                        done += cfg_chunk.steps;
                        params = Some(p);
                    }
                    Ok(())
                };
                match run() {
                    Ok(()) => {
                        if kill.load(Ordering::Acquire) {
                            // monitor already has Killed from kill()
                        } else {
                            for c in 0..total {
                                monitor.record(
                                    &id,
                                    Event::ContainerFinished {
                                        container: format!(
                                            "{id}-task-{c}"
                                        ),
                                    },
                                );
                            }
                        }
                    }
                    Err(e) => {
                        monitor.record(
                            &id,
                            Event::ContainerFailed {
                                container: format!("{id}-task-0"),
                                reason: e.to_string(),
                            },
                        );
                    }
                }
            })
            .map_err(|e| crate::SubmarineError::Runtime(e.to_string()))?;
        self.threads
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(handle);
        Ok(())
    }

    fn kill(&self, id: &str) -> crate::Result<()> {
        if let Some(flag) = self
            .kill_flags
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(id)
        {
            // Release pairs with the runner's Acquire loads: the
            // monitor's Killed event ordering stays consistent with
            // the flag.
            flag.store(true, Ordering::Release);
        }
        self.monitor.record(id, Event::Killed);
        Ok(())
    }
}
