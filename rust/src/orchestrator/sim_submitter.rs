//! Submitter bound to a simulated orchestrator (YARN-like or K8s-like).
//!
//! This is the YARN/Kubernetes submitter of paper Fig. 4 against the
//! DESIGN.md §Substitutions cluster substrate: experiments become gang
//! jobs on the discrete-event cluster; container lifecycle events flow
//! back into the [`ExperimentMonitor`].

use super::Submitter;
use crate::cluster::ClusterSim;
use crate::experiment::monitor::{Event, ExperimentMonitor};
use crate::experiment::spec::ExperimentSpec;
use crate::scheduler::{JobRequest, Scheduler};
use crate::util::clock::SimTime;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

struct Inner {
    scheduler: Box<dyn Scheduler + Send>,
    sim: ClusterSim,
    /// job id -> (request, containers placed, containers finished)
    jobs: BTreeMap<String, (JobRequest, u32, u32)>,
    /// container id -> job id
    container_job: BTreeMap<String, String>,
}

/// Submitter over a scheduler + cluster sim pair.
pub struct SimSubmitter {
    inner: Arc<Mutex<Inner>>,
    monitor: Arc<ExperimentMonitor>,
    /// Simulated duration charged per experiment container.
    pub container_duration: SimTime,
    kind: &'static str,
}

impl SimSubmitter {
    pub fn new(
        scheduler: Box<dyn Scheduler + Send>,
        sim: ClusterSim,
        monitor: Arc<ExperimentMonitor>,
    ) -> SimSubmitter {
        let kind = scheduler.name();
        SimSubmitter {
            inner: Arc::new(Mutex::new(Inner {
                scheduler,
                sim,
                jobs: BTreeMap::new(),
                container_job: BTreeMap::new(),
            })),
            monitor,
            container_duration: SimTime::from_secs_f64(60.0),
            kind,
        }
    }

    pub fn with_container_duration(mut self, d: SimTime) -> Self {
        self.container_duration = d;
        self
    }

    /// Submit with an explicit per-experiment container duration
    /// (arrival-trace replays give every experiment its own runtime).
    pub fn submit_with_duration(
        &self,
        id: &str,
        spec: &ExperimentSpec,
        duration: SimTime,
    ) -> crate::Result<()> {
        let job = spec.to_job(id, duration);
        let mut g = self.inner.lock().unwrap();
        g.jobs.insert(id.to_string(), (job.clone(), 0, 0));
        g.scheduler.submit(job);
        Ok(())
    }

    /// Drive scheduling + simulated time forward by `dt`; emits monitor
    /// events for containers that start/finish. Returns (#placed, #done).
    pub fn pump(&self, dt: SimTime) -> (usize, usize) {
        let mut g = self.inner.lock().unwrap();
        let g = &mut *g; // split borrows across the struct's fields
        let placed = g.scheduler.schedule(&mut g.sim);
        for p in &placed {
            g.container_job
                .insert(p.container.clone(), p.job.clone());
            if let Some(e) = g.jobs.get_mut(&p.job) {
                e.1 += 1;
            }
            self.monitor.record(
                &p.job,
                Event::ContainerStarted {
                    container: p.container.clone(),
                },
            );
        }
        let target = g.sim.now() + dt;
        let done = g.sim.advance_to(target);
        for cid in &done {
            if let Some(job) = g.container_job.get(cid).cloned() {
                self.monitor.record(
                    &job,
                    Event::ContainerFinished {
                        container: cid.clone(),
                    },
                );
                if let Some(e) = g.jobs.get_mut(&job) {
                    e.2 += 1;
                    if e.2 >= e.0.total_containers() {
                        // release queue share etc.
                        let req = e.0.clone();
                        g.scheduler.job_finished(&req);
                    }
                }
            }
        }
        (placed.len(), done.len())
    }

    /// Pump until all submitted jobs have completed (or `max` sim time
    /// passes). Returns total simulated time consumed.
    pub fn drain(&self, step: SimTime, max: SimTime) -> SimTime {
        let start = self.now();
        loop {
            self.pump(step);
            let g = self.inner.lock().unwrap();
            let all_done = g
                .jobs
                .values()
                .all(|(req, _, fin)| *fin >= req.total_containers());
            let elapsed = g.sim.now().saturating_sub(start);
            if all_done || elapsed.0 >= max.0 {
                return elapsed;
            }
        }
    }

    pub fn now(&self) -> SimTime {
        self.inner.lock().unwrap().sim.now()
    }

    pub fn gpu_utilization(&self) -> f64 {
        self.inner.lock().unwrap().sim.gpu_utilization()
    }

    pub fn scheduler_busy_until(&self) -> SimTime {
        self.inner.lock().unwrap().scheduler.busy_until()
    }

    pub fn pending_jobs(&self) -> usize {
        self.inner.lock().unwrap().scheduler.pending_jobs()
    }
}

impl Submitter for SimSubmitter {
    fn name(&self) -> &'static str {
        self.kind
    }

    fn submit(&self, id: &str, spec: &ExperimentSpec) -> crate::Result<()> {
        self.submit_with_duration(id, spec, self.container_duration)
    }

    fn kill(&self, id: &str) -> crate::Result<()> {
        let mut g = self.inner.lock().unwrap();
        let running: Vec<String> = g
            .container_job
            .iter()
            .filter(|(_, j)| j.as_str() == id)
            .map(|(c, _)| c.clone())
            .collect();
        for c in running {
            let _ = g.sim.fail(&c); // already-finished containers are fine
        }
        self.monitor.record(id, Event::Killed);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Resources;
    use crate::experiment::spec::ExperimentStatus;
    use crate::scheduler::queue::QueueTree;
    use crate::scheduler::yarn::YarnScheduler;

    fn listing2_spec() -> ExperimentSpec {
        ExperimentSpec::parse(
            r#"{
          "meta": {"name": "mnist", "framework": "TensorFlow"},
          "spec": {
            "Ps":     {"replicas": 1, "resources": "cpu=2,memory=2G"},
            "Worker": {"replicas": 4, "resources": "cpu=4,gpu=1,memory=4G"}
          }
        }"#,
        )
        .unwrap()
    }

    fn submitter() -> SimSubmitter {
        let sim =
            ClusterSim::homogeneous(4, Resources::new(16, 65536, 2), 1);
        let sched = YarnScheduler::new(QueueTree::flat());
        SimSubmitter::new(
            Box::new(sched),
            sim,
            Arc::new(ExperimentMonitor::new()),
        )
        .with_container_duration(SimTime::from_millis(100))
    }

    #[test]
    fn experiment_runs_to_completion() {
        let s = submitter();
        let spec = listing2_spec();
        s.monitor.watch("exp-1", spec.total_containers());
        s.submit("exp-1", &spec).unwrap();
        assert_eq!(s.monitor.status("exp-1"), ExperimentStatus::Accepted);
        s.pump(SimTime::from_millis(10));
        assert_eq!(s.monitor.status("exp-1"), ExperimentStatus::Running);
        s.drain(SimTime::from_millis(50), SimTime::from_secs_f64(10.0));
        assert_eq!(s.monitor.status("exp-1"), ExperimentStatus::Succeeded);
    }

    #[test]
    fn kill_fails_running_containers() {
        let s = submitter();
        let spec = listing2_spec();
        s.monitor.watch("exp-1", spec.total_containers());
        s.submit("exp-1", &spec).unwrap();
        s.pump(SimTime::from_millis(10));
        s.kill("exp-1").unwrap();
        assert_eq!(s.monitor.status("exp-1"), ExperimentStatus::Killed);
    }

    #[test]
    fn utilization_accrues_during_run() {
        let s = submitter();
        let spec = listing2_spec();
        s.monitor.watch("e", spec.total_containers());
        s.submit("e", &spec).unwrap();
        s.drain(SimTime::from_millis(20), SimTime::from_secs_f64(10.0));
        assert!(s.gpu_utilization() > 0.0);
    }
}
