//! Submitter bound to a simulated orchestrator (YARN-like or K8s-like).
//!
//! This is the YARN/Kubernetes submitter of paper Fig. 4 against the
//! DESIGN.md §Substitutions cluster substrate: experiments become gang
//! jobs on the discrete-event cluster; container lifecycle events flow
//! back into the [`ExperimentMonitor`].
//!
//! Driven either manually (`pump`/`drain`, as the scheduling benches do)
//! or by the background loop in [`crate::orchestrator::engine`], which is
//! what closes the paper's submit→schedule→monitor serving path.

use super::Submitter;
use crate::cluster::ClusterSim;
use crate::experiment::monitor::{Event, ExperimentMonitor};
use crate::experiment::spec::ExperimentSpec;
use crate::scheduler::{JobRequest, Scheduler};
use crate::util::clock::SimTime;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};

/// Book-keeping for one submitted job.
struct JobEntry {
    req: JobRequest,
    placed: u32,
    finished: u32,
    /// Terminal (all containers finished, or killed): the job's queue
    /// share has been released and it no longer gates `drain`.
    done: bool,
}

struct Inner {
    scheduler: Box<dyn Scheduler + Send>,
    sim: ClusterSim,
    jobs: BTreeMap<String, JobEntry>,
    /// container id -> job id
    container_job: BTreeMap<String, String>,
}

/// Submitter over a scheduler + cluster sim pair.
pub struct SimSubmitter {
    inner: Arc<Mutex<Inner>>,
    monitor: Arc<ExperimentMonitor>,
    /// Simulated duration charged per experiment container.
    pub container_duration: SimTime,
    kind: &'static str,
}

impl SimSubmitter {
    pub fn new(
        scheduler: Box<dyn Scheduler + Send>,
        sim: ClusterSim,
        monitor: Arc<ExperimentMonitor>,
    ) -> SimSubmitter {
        let kind = scheduler.name();
        SimSubmitter {
            inner: Arc::new(Mutex::new(Inner {
                scheduler,
                sim,
                jobs: BTreeMap::new(),
                container_job: BTreeMap::new(),
            })),
            monitor,
            container_duration: SimTime::from_secs_f64(60.0),
            kind,
        }
    }

    pub fn with_container_duration(mut self, d: SimTime) -> Self {
        self.container_duration = d;
        self
    }

    /// The submitter state; recovers from poisoning so one panicked
    /// pump (e.g. a scheduler invariant trip) cannot wedge every
    /// status endpoint that reads through this lock afterwards.
    fn state(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn monitor(&self) -> &Arc<ExperimentMonitor> {
        &self.monitor
    }

    /// Submit with an explicit per-experiment container duration
    /// (arrival-trace replays give every experiment its own runtime).
    pub fn submit_with_duration(
        &self,
        id: &str,
        spec: &ExperimentSpec,
        duration: SimTime,
    ) -> crate::Result<()> {
        let job = spec.to_job(id, duration);
        let mut g = self.state();
        g.jobs.insert(
            id.to_string(),
            JobEntry {
                req: job.clone(),
                placed: 0,
                finished: 0,
                done: false,
            },
        );
        g.scheduler.submit(job);
        Ok(())
    }

    /// Drive scheduling + simulated time forward by `dt`; emits monitor
    /// events for containers that start/finish. Returns (#placed, #done).
    pub fn pump(&self, dt: SimTime) -> (usize, usize) {
        let mut g = self.state();
        let g = &mut *g; // split borrows across the struct's fields
        let placed = g.scheduler.schedule(&mut g.sim);
        for p in &placed {
            g.container_job
                .insert(p.container.clone(), p.job.clone());
            if let Some(e) = g.jobs.get_mut(&p.job) {
                e.placed += 1;
            }
            self.monitor.record(
                &p.job,
                Event::ContainerStarted {
                    container: p.container.clone(),
                },
            );
        }
        let target = g.sim.now() + dt;
        let done = g.sim.advance_to(target);
        for cid in &done {
            if let Some(job) = g.container_job.get(cid).cloned() {
                self.monitor.record(
                    &job,
                    Event::ContainerFinished {
                        container: cid.clone(),
                    },
                );
                if let Some(e) = g.jobs.get_mut(&job) {
                    e.finished += 1;
                    if !e.done && e.finished >= e.req.total_containers()
                    {
                        e.done = true;
                        // release queue share etc.
                        let req = e.req.clone();
                        g.scheduler.job_finished(&req);
                    }
                }
            }
        }
        (placed.len(), done.len())
    }

    /// Pump until all submitted jobs have completed (or `max` sim time
    /// passes). Returns total simulated time consumed.
    pub fn drain(&self, step: SimTime, max: SimTime) -> SimTime {
        let start = self.now();
        loop {
            self.pump(step);
            let g = self.state();
            let all_done = g.jobs.values().all(|e| {
                e.done || e.finished >= e.req.total_containers()
            });
            let elapsed = g.sim.now().saturating_sub(start);
            if all_done || elapsed.0 >= max.0 {
                return elapsed;
            }
        }
    }

    pub fn now(&self) -> SimTime {
        self.state().sim.now()
    }

    pub fn gpu_utilization(&self) -> f64 {
        self.state().sim.gpu_utilization()
    }

    pub fn scheduler_busy_until(&self) -> SimTime {
        self.state().scheduler.busy_until()
    }

    pub fn pending_jobs(&self) -> usize {
        self.state().scheduler.pending_jobs()
    }

    /// Whether a scheduling pass could do anything right now (pending
    /// jobs to place or containers to complete). The background engine
    /// skips pumping — and so freezes simulated time — while idle, so
    /// `gpu_utilization` is not diluted by idle wall-clock time.
    pub fn has_work(&self) -> bool {
        let g = self.state();
        g.scheduler.pending_jobs() > 0 || g.sim.running_containers() > 0
    }

    /// Snapshot of the cluster + queue state for the status endpoint:
    /// nodes with capacity/allocation, time-averaged GPU utilization,
    /// queue shares, pending jobs, and the unknown-queue warning metric.
    pub fn cluster_status(&self) -> Json {
        let g = self.state();
        let nodes: Vec<Json> = g
            .sim
            .nodes
            .iter()
            .map(|n| {
                Json::obj()
                    .set("id", Json::Str(n.id.clone()))
                    .set("capacity", n.capacity.to_json())
                    .set("allocated", n.allocated.to_json())
                    .set(
                        "free_gpus",
                        Json::Num(n.free_gpu_indices().len() as f64),
                    )
            })
            .collect();
        let queues: Vec<Json> = g
            .scheduler
            .queue_stats()
            .into_iter()
            .map(|q| {
                Json::obj()
                    .set("name", Json::Str(q.name))
                    .set("capacity", Json::Num(q.capacity))
                    .set("max_capacity", Json::Num(q.max_capacity))
                    .set("used_share", Json::Num(q.used_share))
                    .set("leaf", Json::Bool(q.is_leaf))
            })
            .collect();
        Json::obj()
            .set("scheduler", Json::Str(self.kind.to_string()))
            .set("sim_now_s", Json::Num(g.sim.now().as_secs_f64()))
            .set(
                "gpu_utilization",
                Json::Num(g.sim.gpu_utilization()),
            )
            .set(
                "running_containers",
                Json::Num(g.sim.running_containers() as f64),
            )
            .set(
                "pending_jobs",
                Json::Num(g.scheduler.pending_jobs() as f64),
            )
            .set("total_capacity", g.sim.total_capacity().to_json())
            .set("allocated", g.sim.total_allocated().to_json())
            .set("nodes", Json::Arr(nodes))
            .set("queues", Json::Arr(queues))
            .set(
                "unknown_queue_count",
                Json::Num(g.scheduler.unknown_queue_count() as f64),
            )
    }
}

impl Submitter for SimSubmitter {
    fn name(&self) -> &'static str {
        self.kind
    }

    fn submit(&self, id: &str, spec: &ExperimentSpec) -> crate::Result<()> {
        self.submit_with_duration(id, spec, self.container_duration)
    }

    /// Kill frees everything the job holds: the pending entry if it was
    /// never placed, the running sim containers, and the queue share if
    /// it was charged.
    fn kill(&self, id: &str) -> crate::Result<()> {
        {
            let mut g = self.state();
            let g = &mut *g;
            g.scheduler.cancel(id);
            let running: Vec<String> = g
                .container_job
                .iter()
                .filter(|(_, j)| j.as_str() == id)
                .map(|(c, _)| c.clone())
                .collect();
            for c in running {
                let _ = g.sim.fail(&c); // finished containers are fine
            }
            if let Some(e) = g.jobs.get_mut(id) {
                if !e.done {
                    e.done = true;
                    if e.placed > 0 {
                        // the share was charged at placement and the
                        // completion path will never run now
                        let req = e.req.clone();
                        g.scheduler.job_finished(&req);
                    }
                }
            }
        }
        self.monitor.record(id, Event::Killed);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Resources;
    use crate::experiment::spec::ExperimentStatus;
    use crate::scheduler::queue::QueueTree;
    use crate::scheduler::yarn::YarnScheduler;

    fn listing2_spec() -> ExperimentSpec {
        ExperimentSpec::parse(
            r#"{
          "meta": {"name": "mnist", "framework": "TensorFlow"},
          "spec": {
            "Ps":     {"replicas": 1, "resources": "cpu=2,memory=2G"},
            "Worker": {"replicas": 4, "resources": "cpu=4,gpu=1,memory=4G"}
          }
        }"#,
        )
        .unwrap()
    }

    fn submitter() -> SimSubmitter {
        let sim =
            ClusterSim::homogeneous(4, Resources::new(16, 65536, 2), 1);
        let sched = YarnScheduler::new(QueueTree::flat());
        SimSubmitter::new(
            Box::new(sched),
            sim,
            Arc::new(ExperimentMonitor::new()),
        )
        .with_container_duration(SimTime::from_millis(100))
    }

    #[test]
    fn experiment_runs_to_completion() {
        let s = submitter();
        let spec = listing2_spec();
        s.monitor.watch("exp-1", spec.total_containers());
        s.submit("exp-1", &spec).unwrap();
        assert_eq!(s.monitor.status("exp-1"), ExperimentStatus::Accepted);
        s.pump(SimTime::from_millis(10));
        assert_eq!(s.monitor.status("exp-1"), ExperimentStatus::Running);
        s.drain(SimTime::from_millis(50), SimTime::from_secs_f64(10.0));
        assert_eq!(s.monitor.status("exp-1"), ExperimentStatus::Succeeded);
    }

    #[test]
    fn kill_fails_running_containers_and_frees_resources() {
        let s = submitter();
        let spec = listing2_spec();
        s.monitor.watch("exp-1", spec.total_containers());
        s.submit("exp-1", &spec).unwrap();
        s.pump(SimTime::from_millis(10));
        s.kill("exp-1").unwrap();
        assert_eq!(s.monitor.status("exp-1"), ExperimentStatus::Killed);
        let st = s.cluster_status();
        assert_eq!(st.num_field("running_containers"), Some(0.0));
        // queue share released on kill: root's used_share back to ~0
        let queues = st.get("queues").unwrap().as_arr().unwrap();
        let root = queues
            .iter()
            .find(|q| q.str_field("name") == Some("root"))
            .unwrap();
        assert!(root.num_field("used_share").unwrap() < 1e-6);
    }

    #[test]
    fn kill_of_pending_job_cancels_it() {
        // cluster too small for the gang: job stays pending
        let sim =
            ClusterSim::homogeneous(1, Resources::new(2, 4096, 0), 1);
        let s = SimSubmitter::new(
            Box::new(YarnScheduler::new(QueueTree::flat())),
            sim,
            Arc::new(ExperimentMonitor::new()),
        );
        let spec = listing2_spec();
        s.monitor.watch("e", spec.total_containers());
        s.submit("e", &spec).unwrap();
        s.pump(SimTime::from_millis(1));
        assert_eq!(s.pending_jobs(), 1);
        s.kill("e").unwrap();
        assert_eq!(s.pending_jobs(), 0);
        assert_eq!(s.monitor.status("e"), ExperimentStatus::Killed);
        // a killed job no longer gates drain
        s.drain(SimTime::from_millis(1), SimTime::from_millis(10));
    }

    #[test]
    fn utilization_accrues_during_run() {
        let s = submitter();
        let spec = listing2_spec();
        s.monitor.watch("e", spec.total_containers());
        s.submit("e", &spec).unwrap();
        s.drain(SimTime::from_millis(20), SimTime::from_secs_f64(10.0));
        assert!(s.gpu_utilization() > 0.0);
    }

    #[test]
    fn cluster_status_reports_nodes_and_queues() {
        let s = submitter();
        let st = s.cluster_status();
        assert_eq!(st.str_field("scheduler"), Some("yarn-capacity"));
        assert_eq!(
            st.get("nodes").unwrap().as_arr().unwrap().len(),
            4
        );
        assert!(st.get("queues").unwrap().as_arr().unwrap().len() >= 1);
        assert_eq!(st.num_field("unknown_queue_count"), Some(0.0));
        assert_eq!(
            st.at(&["total_capacity", "gpus"]).and_then(Json::as_f64),
            Some(8.0)
        );
    }
}
