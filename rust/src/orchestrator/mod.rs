//! Experiment submitters (paper Fig. 4): "Submarine provides two types of
//! submitters, YARN submitter and Kubernetes submitter ... To ensure
//! extensibility, Submarine provides a submitter abstraction, and thus
//! users can implement tailor-made submitters."
//!
//! - [`sim_submitter::SimSubmitter`] binds a scheduler model
//!   (YARN-capacity or K8s-default) to the discrete-event cluster — used
//!   for the scheduling experiments (E2, E4–E6).
//! - [`local::LocalSubmitter`] runs the experiment's bound workload for
//!   real through the PJRT runtime (quickstart, E8/E9).
//! - [`tony`] is the TonY-like distributed runner (paper §3.2.2/§6.1):
//!   worker grad steps, rust-side all-reduce, network model (E3).
//! - [`engine`] is the background scheduler loop that drives
//!   [`sim_submitter::SimSubmitter`] so experiments POSTed over REST run
//!   to completion without any manual pumping.

pub mod engine;
pub mod local;
pub mod sim_submitter;
pub mod tony;

use crate::experiment::spec::ExperimentSpec;

/// The submitter abstraction of Fig. 4.
pub trait Submitter: Send + Sync {
    fn name(&self) -> &'static str;

    /// Launch the experiment. Implementations emit events to the monitor
    /// they were constructed with.
    fn submit(&self, id: &str, spec: &ExperimentSpec) -> crate::Result<()>;

    /// Best-effort kill.
    fn kill(&self, id: &str) -> crate::Result<()>;
}
