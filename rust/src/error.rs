//! Unified error type for the Submarine-RS platform.

/// Platform-level errors surfaced through the REST API and CLI.
#[derive(Debug, thiserror::Error)]
pub enum SubmarineError {
    #[error("not found: {0}")]
    NotFound(String),
    #[error("already exists: {0}")]
    AlreadyExists(String),
    #[error("invalid spec: {0}")]
    InvalidSpec(String),
    #[error("resources unavailable: {0}")]
    ResourcesUnavailable(String),
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("storage error: {0}")]
    Storage(String),
    #[error("json error: {0}")]
    Json(#[from] crate::util::json::JsonError),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("xla error: {0}")]
    Xla(String),
    #[error("unauthorized: {0}")]
    Unauthorized(String),
    #[error("rate limited: {0}")]
    RateLimited(String),
}

impl From<xla::Error> for SubmarineError {
    fn from(e: xla::Error) -> Self {
        SubmarineError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, SubmarineError>;

impl SubmarineError {
    /// HTTP status code this error maps to on the REST surface.
    pub fn http_status(&self) -> u16 {
        match self {
            SubmarineError::NotFound(_) => 404,
            SubmarineError::AlreadyExists(_) => 409,
            SubmarineError::InvalidSpec(_) | SubmarineError::Json(_) => 400,
            SubmarineError::ResourcesUnavailable(_) => 503,
            SubmarineError::Unauthorized(_) => 401,
            SubmarineError::RateLimited(_) => 429,
            _ => 500,
        }
    }

    /// Stable machine-readable error type for the v2 envelope's
    /// `error.type` field.
    pub fn kind(&self) -> &'static str {
        match self {
            SubmarineError::NotFound(_) => "NotFound",
            SubmarineError::AlreadyExists(_) => "AlreadyExists",
            SubmarineError::InvalidSpec(_) => "InvalidSpec",
            SubmarineError::ResourcesUnavailable(_) => {
                "ResourcesUnavailable"
            }
            SubmarineError::Runtime(_) => "Runtime",
            SubmarineError::Storage(_) => "Storage",
            SubmarineError::Json(_) => "Json",
            SubmarineError::Io(_) => "Io",
            SubmarineError::Xla(_) => "Xla",
            SubmarineError::Unauthorized(_) => "Unauthorized",
            SubmarineError::RateLimited(_) => "RateLimited",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_mapping() {
        assert_eq!(SubmarineError::NotFound("x".into()).http_status(), 404);
        assert_eq!(
            SubmarineError::InvalidSpec("x".into()).http_status(),
            400
        );
        assert_eq!(
            SubmarineError::Runtime("x".into()).http_status(),
            500
        );
        assert_eq!(
            SubmarineError::Unauthorized("x".into()).http_status(),
            401
        );
        assert_eq!(
            SubmarineError::RateLimited("x".into()).http_status(),
            429
        );
    }

    #[test]
    fn kind_is_stable() {
        assert_eq!(SubmarineError::NotFound("x".into()).kind(), "NotFound");
        assert_eq!(
            SubmarineError::RateLimited("x".into()).kind(),
            "RateLimited"
        );
    }

    #[test]
    fn display_includes_cause() {
        let e = SubmarineError::NotFound("experiment-1".into());
        assert_eq!(e.to_string(), "not found: experiment-1");
    }
}
