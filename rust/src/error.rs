//! Unified error type for the Submarine-RS platform.
//!
//! Hand-rolled `Display`/`Error`/`From` impls: the offline registry has
//! no `thiserror`, and the surface is small enough to write by hand.

use std::fmt;

/// Platform-level errors surfaced through the REST API and CLI.
#[derive(Debug)]
pub enum SubmarineError {
    NotFound(String),
    AlreadyExists(String),
    InvalidSpec(String),
    ResourcesUnavailable(String),
    Runtime(String),
    Storage(String),
    Json(crate::util::json::JsonError),
    Io(std::io::Error),
    Xla(String),
    Unauthorized(String),
    RateLimited(String),
    /// Optimistic-concurrency failure: the caller's `If-Match`
    /// resource_version no longer matches the stored document (HTTP 412).
    PreconditionFailed(String),
    /// A watch `since` revision that has been compacted out of the
    /// change feed (HTTP 410): relist and watch from the fresh bookmark.
    Gone(String),
}

impl fmt::Display for SubmarineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmarineError::NotFound(m) => write!(f, "not found: {m}"),
            SubmarineError::AlreadyExists(m) => {
                write!(f, "already exists: {m}")
            }
            SubmarineError::InvalidSpec(m) => {
                write!(f, "invalid spec: {m}")
            }
            SubmarineError::ResourcesUnavailable(m) => {
                write!(f, "resources unavailable: {m}")
            }
            SubmarineError::Runtime(m) => write!(f, "runtime error: {m}"),
            SubmarineError::Storage(m) => write!(f, "storage error: {m}"),
            SubmarineError::Json(e) => write!(f, "json error: {e}"),
            SubmarineError::Io(e) => write!(f, "io error: {e}"),
            SubmarineError::Xla(m) => write!(f, "xla error: {m}"),
            SubmarineError::Unauthorized(m) => {
                write!(f, "unauthorized: {m}")
            }
            SubmarineError::RateLimited(m) => {
                write!(f, "rate limited: {m}")
            }
            SubmarineError::PreconditionFailed(m) => {
                write!(f, "precondition failed: {m}")
            }
            SubmarineError::Gone(m) => write!(f, "gone: {m}"),
        }
    }
}

impl std::error::Error for SubmarineError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SubmarineError::Json(e) => Some(e),
            SubmarineError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::util::json::JsonError> for SubmarineError {
    fn from(e: crate::util::json::JsonError) -> Self {
        SubmarineError::Json(e)
    }
}

impl From<std::io::Error> for SubmarineError {
    fn from(e: std::io::Error) -> Self {
        SubmarineError::Io(e)
    }
}

impl From<xla::Error> for SubmarineError {
    fn from(e: xla::Error) -> Self {
        SubmarineError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, SubmarineError>;

impl SubmarineError {
    /// HTTP status code this error maps to on the REST surface.
    pub fn http_status(&self) -> u16 {
        match self {
            SubmarineError::NotFound(_) => 404,
            SubmarineError::AlreadyExists(_) => 409,
            SubmarineError::InvalidSpec(_) | SubmarineError::Json(_) => 400,
            SubmarineError::ResourcesUnavailable(_) => 503,
            SubmarineError::Unauthorized(_) => 401,
            SubmarineError::RateLimited(_) => 429,
            SubmarineError::PreconditionFailed(_) => 412,
            SubmarineError::Gone(_) => 410,
            _ => 500,
        }
    }

    /// Stable machine-readable error type for the v2 envelope's
    /// `error.type` field.
    pub fn kind(&self) -> &'static str {
        match self {
            SubmarineError::NotFound(_) => "NotFound",
            SubmarineError::AlreadyExists(_) => "AlreadyExists",
            SubmarineError::InvalidSpec(_) => "InvalidSpec",
            SubmarineError::ResourcesUnavailable(_) => {
                "ResourcesUnavailable"
            }
            SubmarineError::Runtime(_) => "Runtime",
            SubmarineError::Storage(_) => "Storage",
            SubmarineError::Json(_) => "Json",
            SubmarineError::Io(_) => "Io",
            SubmarineError::Xla(_) => "Xla",
            SubmarineError::Unauthorized(_) => "Unauthorized",
            SubmarineError::RateLimited(_) => "RateLimited",
            SubmarineError::PreconditionFailed(_) => {
                "PreconditionFailed"
            }
            SubmarineError::Gone(_) => "Gone",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_mapping() {
        assert_eq!(SubmarineError::NotFound("x".into()).http_status(), 404);
        assert_eq!(
            SubmarineError::InvalidSpec("x".into()).http_status(),
            400
        );
        assert_eq!(
            SubmarineError::Runtime("x".into()).http_status(),
            500
        );
        assert_eq!(
            SubmarineError::Unauthorized("x".into()).http_status(),
            401
        );
        assert_eq!(
            SubmarineError::RateLimited("x".into()).http_status(),
            429
        );
    }

    #[test]
    fn kind_is_stable() {
        assert_eq!(SubmarineError::NotFound("x".into()).kind(), "NotFound");
        assert_eq!(
            SubmarineError::RateLimited("x".into()).kind(),
            "RateLimited"
        );
    }

    #[test]
    fn display_includes_cause() {
        let e = SubmarineError::NotFound("experiment-1".into());
        assert_eq!(e.to_string(), "not found: experiment-1");
    }
}
