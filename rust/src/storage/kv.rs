//! Embedded metadata store, engine v2: sharded in-memory maps, a
//! group-committed JSON-lines WAL, periodic snapshots with atomic
//! rename-swap compaction, and secondary indexes.
//!
//! The seed engine was one global `Mutex<BTreeMap>` over an unbounded
//! append-only log whose recovery hard-failed on a torn final record.
//! v2 keeps the paper's role for this store — durable experiment
//! metadata "so that experiments become easy to compare and
//! reproducible" (§3.2.2) — and rebuilds the mechanics for heavy
//! traffic:
//!
//! - **Concurrency:** namespaces hash onto [`SHARD_COUNT`] shards, each
//!   behind its own `RwLock`, so v2 handlers on different namespaces
//!   never contend; WAL appends are batched by a leader/follower group
//!   commit so one `write`(+optional fsync) covers many writers.
//! - **Durability:** memory is applied first, then the record is queued
//!   for the WAL; `put`/`delete` return once the record (or a snapshot
//!   covering it) is on disk. Compaction dumps the full state as
//!   `snapshot-<gen>.json` (tmp + fsync + rename) and rotates to
//!   `wal-<gen>.jsonl`, bounding the log. Recovery = latest snapshot +
//!   replay of remaining WAL files; a torn final record is skipped with
//!   a warning (crash artifact), a torn *interior* record is an error
//!   (real corruption).
//! - **Query:** [`crate::storage::index::FieldIndex`] postings are
//!   maintained under the same shard lock as the documents, giving the
//!   v2 list endpoints O(log n + page) filtered reads instead of
//!   namespace scans.
//! - **Observe:** every write is assigned a monotonically increasing
//!   global revision (an `AtomicU64` — no lock) and published to a
//!   bounded in-memory change feed ([`Change`]), so
//!   `?watch=1&since=REV` streams deliver updates without polling; a
//!   `since` that has fallen off the ring answers `410 Gone` and the
//!   client relists. The caller's doc builder runs *outside* the feed
//!   mutex (it used to run inside, serializing every cross-shard write
//!   on one lock); a small sequencer re-orders completions so the feed
//!   still publishes strictly rev-ordered.
//! - **Zero-clone reads (ISSUE 5):** documents are stored as
//!   [`Arc<Doc>`]; `get`, list pages, and feed entries hand out
//!   refcount bumps instead of deep clones, and each `Doc` lazily
//!   caches its compact serialization (`Arc<[u8]>`) so repeat GETs and
//!   watch fan-out write cached bytes straight to the socket. The cache
//!   is revision-keyed implicitly: every write installs a fresh `Doc`,
//!   so a cached body can never outlive its revision.

use crate::analysis::lock_order::LockRank;
use crate::analysis::tracker;
use crate::storage::index::{FieldIndex, IndexDef};
use crate::storage::snapshot;
use crate::util::json::{write_json_string, write_json_u64, Json};
use std::collections::{BTreeMap, VecDeque};
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{
    Arc, Condvar, Mutex, MutexGuard, OnceLock, RwLock,
    RwLockReadGuard, RwLockWriteGuard,
};
use std::time::{Duration, Instant};

/// A stored document: the parsed JSON plus a lazily-filled,
/// revision-keyed cache of its compact serialization. Readers share the
/// same allocation via `Arc<Doc>`; writers always install a *new* `Doc`
/// (fresh empty cache), which is what makes the cache safe — the
/// revision bump that already invalidates ETags also invalidates this.
#[derive(Debug)]
pub struct Doc {
    json: Json,
    encoded: OnceLock<Arc<[u8]>>,
}

impl Doc {
    pub fn new(json: Json) -> Doc {
        Doc {
            json,
            encoded: OnceLock::new(),
        }
    }

    /// The parsed document.
    pub fn json(&self) -> &Json {
        &self.json
    }

    /// Compact serialization of the document, computed once per
    /// revision and shared by every reader from then on (repeat GETs,
    /// watch fan-out, WAL appends).
    pub fn encoded(&self) -> Arc<[u8]> {
        Arc::clone(self.encoded.get_or_init(|| {
            let mut buf = Vec::with_capacity(128);
            self.json.dump_into(&mut buf);
            Arc::from(buf)
        }))
    }

    /// The cached serialization only if someone already paid for it —
    /// lets cache-opportunistic consumers (snapshot writes splice
    /// warm docs and serialize cold ones) avoid *forcing* a fill,
    /// which would pin encoded bytes for documents nobody reads.
    pub fn encoded_if_cached(&self) -> Option<Arc<[u8]>> {
        self.encoded.get().map(Arc::clone)
    }
}

impl std::ops::Deref for Doc {
    type Target = Json;
    fn deref(&self) -> &Json {
        &self.json
    }
}

/// Namespaces hash onto this many independently locked shards.
pub const SHARD_COUNT: usize = 16;

/// Tuning knobs for a durable store.
#[derive(Debug, Clone)]
pub struct StoreOptions {
    /// `fsync` the WAL on every group flush (and every direct write).
    pub sync: bool,
    /// Batch concurrent appends into one write/fsync (leader-follower).
    /// `false` serializes every append through its own write+fsync —
    /// kept as the measurable baseline for `benches/storage.rs`.
    pub group_commit: bool,
    /// Auto-compact once this many WAL records accumulate since the
    /// last snapshot. `0` disables auto-compaction (manual only).
    pub compact_threshold: u64,
    /// Change-feed ring size: how many recent writes stay available to
    /// `?watch=1&since=REV` resumers before they must relist (`410`).
    /// `0` disables the feed (watchers always get `Gone`).
    pub feed_capacity: usize,
}

impl Default for StoreOptions {
    fn default() -> StoreOptions {
        StoreOptions {
            sync: false,
            group_commit: true,
            compact_threshold: 4096,
            feed_capacity: 1024,
        }
    }
}

/// Point-in-time counters for `submarine storage stats`.
#[derive(Debug, Clone)]
pub struct StorageStats {
    pub durable: bool,
    pub namespaces: usize,
    pub docs: usize,
    pub indexes: usize,
    pub snapshot_gen: u64,
    /// WAL records since the last snapshot.
    pub wal_records: u64,
    pub wal_bytes: u64,
    /// Invalid/blank WAL records skipped during recovery (torn tails,
    /// blank lines).
    pub skipped_records: u64,
    pub compactions: u64,
}

/// Result of one compaction pass.
#[derive(Debug, Clone)]
pub struct CompactReport {
    /// Generation of the snapshot that was written.
    pub gen: u64,
    /// Documents captured in the snapshot.
    pub docs: usize,
    /// Stale snapshot/WAL files removed.
    pub removed_files: usize,
}

// ------------------------------------------------------------ change feed

/// One record in the bounded in-memory change feed (ISSUE 4): every
/// write is assigned a monotonically increasing global revision and
/// published here so `?watch=1&since=REV` streams see it without
/// polling. The document rides as an [`Arc<Doc>`], so fanning one
/// change out to N watchers is N refcount bumps, not N deep clones.
#[derive(Debug, Clone)]
pub struct Change {
    /// Global revision assigned to this write.
    pub rev: u64,
    pub ns: String,
    pub key: String,
    /// `Some(doc)` for puts, `None` for deletes.
    pub doc: Option<Arc<Doc>>,
}

/// Outcome of a conditional [`MetaStore::update_rev`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdateRev {
    /// The key does not exist.
    Missing,
    /// The closure declined to write; nothing changed.
    Unchanged,
    /// Written at this revision.
    Written(u64),
}

/// The feed guard plus its lock-order token, so every holder of the
/// feed mutex is visible to the debug-build tracker
/// ([`crate::analysis::tracker`]). Derefs to [`Feed`]; the long-poll
/// path reaches `guard` directly to park on the feed condvar.
struct TrackedFeed<'a> {
    guard: MutexGuard<'a, Feed>,
    _held: tracker::Held,
}

impl std::ops::Deref for TrackedFeed<'_> {
    type Target = Feed;
    fn deref(&self) -> &Feed {
        &self.guard
    }
}

impl std::ops::DerefMut for TrackedFeed<'_> {
    fn deref_mut(&mut self) -> &mut Feed {
        &mut self.guard
    }
}

struct Feed {
    /// Highest revision published to the ring, in order. Revisions are
    /// *assigned* lock-free from [`MetaStore::next_rev`]; completions
    /// arrive here possibly out of order and [`Feed::sequence`] holds
    /// them back until every predecessor has landed, so the ring stays
    /// strictly rev-ordered without running doc builders under this
    /// mutex.
    published: u64,
    /// Completions waiting for a predecessor (`None` = the revision
    /// was allocated but the write was declined/aborted — a gap the
    /// sequencer must still step over).
    pending: BTreeMap<u64, Option<Change>>,
    /// Global floor set at open: the whole pre-restart history counts
    /// as compacted (the feed is volatile).
    floor: u64,
    /// Highest revision evicted from the ring *per namespace*: a
    /// watcher has truly missed events only when its own namespace
    /// lost records — churn elsewhere must not force spurious relists.
    dropped: BTreeMap<String, u64>,
    entries: VecDeque<Change>,
    capacity: usize,
}

impl Feed {
    fn drop_mark(&mut self, ns: String, rev: u64) {
        let slot = self.dropped.entry(ns).or_insert(0);
        *slot = (*slot).max(rev);
    }

    fn push(&mut self, c: Change) {
        if self.capacity == 0 {
            self.drop_mark(c.ns, c.rev);
            return;
        }
        if self.entries.len() >= self.capacity {
            if let Some(old) = self.entries.pop_front() {
                self.drop_mark(old.ns, old.rev);
            }
        }
        self.entries.push_back(c);
    }

    /// The publish-in-order sequencer: record `rev`'s completion and
    /// flush the now-contiguous run onto the ring. Returns whether any
    /// entry became visible (the caller notifies watchers then).
    fn sequence(&mut self, rev: u64, change: Option<Change>) -> bool {
        debug_assert!(rev > self.published, "revision published twice");
        self.pending.insert(rev, change);
        let mut advanced = false;
        loop {
            let next = self.published + 1;
            match self.pending.remove(&next) {
                None => break,
                Some(entry) => {
                    self.published = next;
                    if let Some(c) = entry {
                        self.push(c);
                        advanced = true;
                    }
                }
            }
        }
        advanced
    }

    /// `next_rev` is the assigned-revision counter (loaded from the
    /// store's atomic) — it bounds what a legitimate bookmark can be.
    fn gone(
        &self,
        ns: &str,
        since: u64,
        next_rev: u64,
    ) -> Option<crate::SubmarineError> {
        let dropped = self
            .dropped
            .get(ns)
            .copied()
            .unwrap_or(0)
            .max(self.floor);
        if since < dropped {
            return Some(crate::SubmarineError::Gone(format!(
                "watch revision {since} has been compacted out of the \
                 change feed (oldest retained for {ns}: {}); relist \
                 and resume from the fresh resource_version",
                dropped + 1
            )));
        }
        // A bookmark past the newest assigned revision is from another
        // timeline (another server, or a counter that could not be
        // fully restored). Waiting on it would hang forever — force
        // the relist instead.
        if since >= next_rev {
            return Some(crate::SubmarineError::Gone(format!(
                "watch revision {since} is ahead of the server's \
                 current revision {} (server restarted?); relist and \
                 resume from the fresh resource_version",
                next_rev - 1
            )));
        }
        None
    }

    fn collect(&self, ns: &str, since: u64, limit: usize) -> Vec<Change> {
        self.entries
            .iter()
            .filter(|c| c.rev > since && c.ns == ns)
            .take(limit.max(1))
            .cloned()
            .collect()
    }
}

// ---------------------------------------------------------------- shards

#[derive(Default)]
struct Namespace {
    docs: BTreeMap<String, Arc<Doc>>,
    indexes: Vec<FieldIndex>,
}

impl Namespace {
    fn put(&mut self, key: &str, doc: Arc<Doc>) {
        if let Some(old) = self.docs.get(key) {
            for idx in &mut self.indexes {
                idx.remove(key, old.json());
            }
        }
        for idx in &mut self.indexes {
            idx.add(key, doc.json());
        }
        self.docs.insert(key.to_string(), doc);
    }

    fn delete(&mut self, key: &str) -> bool {
        match self.docs.remove(key) {
            Some(old) => {
                for idx in &mut self.indexes {
                    idx.remove(key, old.json());
                }
                true
            }
            None => false,
        }
    }

    fn index(&self, field: &str) -> Option<&FieldIndex> {
        self.indexes.iter().find(|i| i.field() == field)
    }
}

#[derive(Default)]
struct Shard {
    spaces: BTreeMap<String, Namespace>,
}

fn shard_of(ns: &str) -> usize {
    // FNV-1a; namespaces are few and short, this is off the hot path
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in ns.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    (h % SHARD_COUNT as u64) as usize
}

// ------------------------------------------------------------ durability

struct Writer {
    file: fs::File,
    gen: u64,
    records_since_snapshot: u64,
    wal_bytes: u64,
}

#[derive(Default)]
struct Pending {
    buf: Vec<u8>,
    records: u64,
    /// Tickets: the sequence number of the newest enqueued record.
    seq: u64,
}

#[derive(Default)]
struct FlushState {
    /// Highest ticket known durable (flushed to the WAL, or captured by
    /// a snapshot during rotation).
    seq: u64,
    /// Sticky write failure: the disk is gone, fail all waiters.
    error: Option<String>,
}

struct Durability {
    dir: PathBuf,
    writer: Mutex<Writer>,
    pending: Mutex<Pending>,
    flush: Mutex<FlushState>,
    flushed_cv: Condvar,
    compacting: Mutex<()>,
    /// Mirror of `records_since_snapshot` for lock-free auto-compaction
    /// checks.
    wal_pressure: AtomicU64,
    /// After a failed auto-compaction: don't retry until pressure
    /// reaches this (prevents an O(total docs) snapshot attempt on
    /// every write while e.g. the disk stays full). 0 = no backoff.
    compact_retry_at: AtomicU64,
    compactions: AtomicU64,
}

fn storage_err(msg: impl Into<String>) -> crate::SubmarineError {
    crate::SubmarineError::Storage(msg.into())
}

/// Guard tying an allocated revision to its mandatory sequencer
/// hand-off: [`RevGuard::publish`] delivers the change, and plain drop
/// (a declined conditional write, an `Err`, or a panicking doc builder)
/// delivers an explicit gap — without one or the other the sequencer
/// would stall behind the missing revision forever.
struct RevGuard<'a> {
    store: &'a MetaStore,
    rev: u64,
    done: bool,
}

impl RevGuard<'_> {
    fn publish(mut self, change: Change) {
        self.done = true;
        self.store.sequence(self.rev, Some(change));
    }
}

impl Drop for RevGuard<'_> {
    fn drop(&mut self) {
        if !self.done {
            self.store.sequence(self.rev, None);
        }
    }
}

/// Build one WAL line without cloning the document: the record shell is
/// written field-by-field into one buffer and the payload is spliced in
/// from the doc's cached serialization (also warming the cache the
/// first GET would otherwise pay for).
fn wal_record(
    op: &str,
    ns: &str,
    key: &str,
    doc: Option<&Doc>,
    rev: u64,
) -> Vec<u8> {
    let enc = doc.map(|d| d.encoded());
    let payload = enc.as_ref().map(|e| e.len()).unwrap_or(0);
    let mut line =
        Vec::with_capacity(48 + ns.len() + key.len() + payload);
    line.extend_from_slice(b"{\"op\":");
    write_json_string(&mut line, op);
    line.extend_from_slice(b",\"ns\":");
    write_json_string(&mut line, ns);
    line.extend_from_slice(b",\"key\":");
    write_json_string(&mut line, key);
    if rev > 0 {
        line.extend_from_slice(b",\"rev\":");
        write_json_u64(&mut line, rev);
    }
    if let Some(e) = &enc {
        line.extend_from_slice(b",\"doc\":");
        line.extend_from_slice(e);
    }
    line.extend_from_slice(b"}\n");
    line
}

/// A standalone revision high-water marker (written at WAL rotation):
/// deletes consume revisions but leave no doc behind, so without this
/// a compaction could lose the counter's high-water mark and a restart
/// would re-assign revisions — silently skipping watch events for
/// clients holding pre-restart bookmarks.
fn rev_marker(rev: u64) -> Vec<u8> {
    let mut line = Vec::with_capacity(32);
    line.extend_from_slice(b"{\"op\":\"rev\",\"rev\":");
    write_json_u64(&mut line, rev);
    line.extend_from_slice(b"}\n");
    line
}

/// Outcome of validating one WAL line.
enum WalLine {
    Blank,
    Put { ns: String, key: String, doc: Json, rev: u64 },
    Del { ns: String, key: String, rev: u64 },
    /// Revision high-water marker (no document payload).
    Rev(u64),
    Invalid(String),
}

/// Unified WAL record validation (the seed treated blank and corrupt
/// lines inconsistently): blank lines and parse/shape failures are both
/// classified here, and the caller decides tolerance by position.
fn parse_wal_line(raw: &[u8]) -> WalLine {
    let Ok(text) = std::str::from_utf8(raw) else {
        return WalLine::Invalid("not utf-8".into());
    };
    if text.trim().is_empty() {
        return WalLine::Blank;
    }
    let rec = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => return WalLine::Invalid(format!("unparseable: {e}")),
    };
    // pre-redesign records carry no rev; treat it as 0 (unknown)
    let rev = rec.get("rev").and_then(Json::as_u64).unwrap_or(0);
    if rec.str_field("op") == Some("rev") {
        return if rev > 0 {
            WalLine::Rev(rev)
        } else {
            WalLine::Invalid("rev marker without rev".into())
        };
    }
    let ns = match rec.str_field("ns") {
        Some(ns) => ns.to_string(),
        None => return WalLine::Invalid("missing ns".into()),
    };
    let key = match rec.str_field("key") {
        Some(k) => k.to_string(),
        None => return WalLine::Invalid("missing key".into()),
    };
    match rec.str_field("op") {
        Some("put") => {
            let doc = rec.get("doc").cloned().unwrap_or(Json::Null);
            WalLine::Put { ns, key, doc, rev }
        }
        Some("del") => WalLine::Del { ns, key, rev },
        other => WalLine::Invalid(format!("unknown op {other:?}")),
    }
}

/// Result of replaying one WAL file.
struct Replay {
    /// Records applied.
    applied: u64,
    /// Length of the clean prefix — the bytes a future append may
    /// safely follow. A torn/blank unterminated tail is excluded, so
    /// the caller can truncate before reusing the file.
    valid_len: u64,
    /// The final record was valid but missing its newline (crash after
    /// the payload, before the terminator): it is applied and included
    /// in `valid_len`, but needs a `\n` before the next append.
    needs_newline: bool,
    /// Highest revision seen on any record or marker — restores the
    /// global revision counter across restarts even when the writes
    /// carrying the top revisions were deletes.
    max_rev: u64,
}

/// Replay one WAL file into `data`. Only the final, *unterminated*
/// line can be a crash artifact: it is skipped (counted) with a
/// warning, or applied when it parses cleanly. An invalid terminated
/// line is real corruption and errors out.
fn replay_wal(
    path: &Path,
    data: &mut BTreeMap<String, BTreeMap<String, Json>>,
    skipped: &mut u64,
) -> crate::Result<Replay> {
    let mut out = Replay {
        applied: 0,
        valid_len: 0,
        needs_newline: false,
        max_rev: 0,
    };
    let bytes = match fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(out)
        }
        Err(e) => return Err(e.into()),
    };
    let n = bytes.len();
    let mut pos = 0usize;
    let mut line_no = 0usize;
    let mut apply = |line: WalLine, out: &mut Replay| match line {
        WalLine::Put { ns, key, doc, rev } => {
            data.entry(ns).or_default().insert(key, doc);
            out.applied += 1;
            out.max_rev = out.max_rev.max(rev);
        }
        WalLine::Del { ns, key, rev } => {
            data.entry(ns).or_default().remove(&key);
            out.applied += 1;
            out.max_rev = out.max_rev.max(rev);
        }
        WalLine::Rev(rev) => {
            out.max_rev = out.max_rev.max(rev);
        }
        WalLine::Blank | WalLine::Invalid(_) => unreachable!(),
    };
    while pos < n {
        line_no += 1;
        let nl = bytes[pos..].iter().position(|&b| b == b'\n');
        match nl {
            Some(i) => {
                let raw = &bytes[pos..pos + i];
                match parse_wal_line(raw) {
                    WalLine::Blank => *skipped += 1,
                    WalLine::Invalid(why) => {
                        return Err(storage_err(format!(
                            "corrupt WAL record at {} line {line_no} \
                             ({why})",
                            path.display()
                        )));
                    }
                    line => apply(line, &mut out),
                }
                pos += i + 1;
                out.valid_len = pos as u64;
            }
            None => {
                // unterminated tail: the only place a crash mid-append
                // can tear a record
                let raw = &bytes[pos..n];
                match parse_wal_line(raw) {
                    WalLine::Blank | WalLine::Invalid(_) => {
                        *skipped += 1;
                        crate::warnlog!(
                            "storage",
                            "skipping torn final WAL record in {}",
                            path.display()
                        );
                    }
                    line => {
                        // complete record, missing only its newline
                        apply(line, &mut out);
                        out.valid_len = n as u64;
                        out.needs_newline = true;
                    }
                }
                break;
            }
        }
    }
    Ok(out)
}

// ------------------------------------------------------------- MetaStore

/// Thread-safe namespaced document store (see module docs).
pub struct MetaStore {
    shards: Vec<RwLock<Shard>>,
    /// Declared secondary indexes per namespace.
    defs: RwLock<BTreeMap<String, Vec<IndexDef>>>,
    /// Next revision to assign (revisions start at 1). Lock-free: a
    /// writer grabs its revision with one `fetch_add` while holding
    /// only its shard lock, builds the document, and hands the result
    /// to the feed sequencer — cross-shard writes no longer serialize
    /// on the feed mutex for the duration of the doc builder.
    next_rev: AtomicU64,
    /// Bounded change feed + publish sequencer; writers take it only
    /// for the (short) publish step while already holding their shard
    /// write lock (shard → feed, never the reverse).
    feed: Mutex<Feed>,
    feed_cv: Condvar,
    opts: StoreOptions,
    dur: Option<Durability>,
    path: Option<PathBuf>,
    skipped_at_open: u64,
}

impl MetaStore {
    fn empty(opts: StoreOptions) -> MetaStore {
        MetaStore {
            shards: (0..SHARD_COUNT)
                .map(|_| RwLock::new(Shard::default()))
                .collect(),
            defs: RwLock::new(BTreeMap::new()),
            next_rev: AtomicU64::new(1),
            feed: Mutex::new(Feed {
                published: 0,
                pending: BTreeMap::new(),
                floor: 0,
                dropped: BTreeMap::new(),
                entries: VecDeque::new(),
                capacity: opts.feed_capacity,
            }),
            feed_cv: Condvar::new(),
            opts,
            dur: None,
            path: None,
            skipped_at_open: 0,
        }
    }

    /// Volatile store (tests, benches).
    pub fn in_memory() -> MetaStore {
        MetaStore::empty(StoreOptions::default())
    }

    /// Volatile store with explicit [`StoreOptions`] (e.g. a small
    /// `feed_capacity` to exercise watch-resume-after-compaction).
    pub fn in_memory_with(opts: StoreOptions) -> MetaStore {
        MetaStore::empty(opts)
    }

    /// Durable store over a data directory (created if absent), default
    /// options. A pre-v2 single-file WAL at `path` is migrated in place
    /// into the directory layout.
    pub fn open(path: &Path) -> crate::Result<MetaStore> {
        MetaStore::open_with(path, StoreOptions::default())
    }

    /// Durable store with explicit [`StoreOptions`].
    pub fn open_with(
        path: &Path,
        opts: StoreOptions,
    ) -> crate::Result<MetaStore> {
        let mut skipped = 0u64;
        recover_interrupted_migration(path)?;
        if path.is_file() {
            migrate_legacy_file(path, &mut skipped)?;
        }
        fs::create_dir_all(path)?;
        let scan = snapshot::scan_dir(path, true)?;

        let mut data: BTreeMap<String, BTreeMap<String, Json>> =
            BTreeMap::new();
        if let Some(&g) = scan.snapshots.last() {
            data = snapshot::load_snapshot(&snapshot::snapshot_path(
                path, g,
            ))?;
        }
        // Current generation = max of everything on disk, so appends
        // always land in the newest file regardless of where a crash
        // fell between snapshot rename and WAL rotation.
        let gen = scan
            .snapshots
            .last()
            .copied()
            .unwrap_or(1)
            .max(scan.wals.last().copied().unwrap_or(1));
        // Replay every WAL generation in order. Records already covered
        // by the snapshot replay idempotently (full-doc puts, deletes);
        // a WAL older than the snapshot only survives a crash between
        // snapshot rename and rotation, and replaying it in full
        // converges on the crash-time state.
        let mut replayed = 0u64;
        let mut wal_max_rev = 0u64;
        let mut current_tail = Replay {
            applied: 0,
            valid_len: 0,
            needs_newline: false,
            max_rev: 0,
        };
        for &wg in &scan.wals {
            let rep = replay_wal(
                &snapshot::wal_path(path, wg),
                &mut data,
                &mut skipped,
            )?;
            replayed += rep.applied;
            wal_max_rev = wal_max_rev.max(rep.max_rev);
            if wg == gen {
                current_tail = rep;
            }
        }
        // stale snapshots are superseded; stale WALs stay until the
        // next compaction writes a snapshot that covers them
        if let Some(&g) = scan.snapshots.last() {
            snapshot::remove_stale(path, g, false);
        }

        let wal_file = snapshot::wal_path(path, gen);
        let mut file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&wal_file)?;
        let mut wal_bytes =
            fs::metadata(&wal_file).map(|m| m.len()).unwrap_or(0);
        // Make the current WAL safe to append to: a tolerated torn
        // tail must not have new records concatenated onto it (that
        // would corrupt the *next* recovery), so drop it; and complete
        // the newline of a record whose terminator the crash ate.
        if wal_bytes > current_tail.valid_len {
            file.set_len(current_tail.valid_len)?;
            wal_bytes = current_tail.valid_len;
        }
        if current_tail.needs_newline {
            file.write_all(b"\n")?;
            wal_bytes += 1;
        }

        let mut store = MetaStore::empty(opts);
        // The global revision counter must never regress across a
        // restart: resume from the max of (a) every WAL record's rev
        // (deletes consume revs but leave no doc), (b) the rotation
        // marker a compaction stamps into the fresh WAL, and (c) every
        // surviving doc's meta.resource_version (covers pre-rev WALs).
        // The feed itself is volatile: everything before the restart
        // counts as compacted, so a watcher resuming across it gets
        // `410 Gone` and relists.
        let mut max_rev = wal_max_rev;
        for docs in data.values() {
            for doc in docs.values() {
                if let Some(rv) = doc
                    .at(&["meta", "resource_version"])
                    .and_then(Json::as_u64)
                {
                    max_rev = max_rev.max(rv);
                }
            }
        }
        store.next_rev = AtomicU64::new(max_rev + 1);
        {
            let feed = store
                .feed
                .get_mut()
                .unwrap_or_else(|e| e.into_inner());
            feed.published = max_rev;
            feed.floor = max_rev;
        }
        for (ns, docs) in data {
            let shard = &mut store.shards[shard_of(&ns)];
            let space = shard.get_mut().unwrap().spaces.entry(ns);
            let space = space.or_default();
            for (k, v) in docs {
                space.docs.insert(k, Arc::new(Doc::new(v)));
            }
        }
        store.dur = Some(Durability {
            dir: path.to_path_buf(),
            writer: Mutex::new(Writer {
                file,
                gen,
                records_since_snapshot: replayed,
                wal_bytes,
            }),
            pending: Mutex::new(Pending::default()),
            flush: Mutex::new(FlushState::default()),
            flushed_cv: Condvar::new(),
            compacting: Mutex::new(()),
            wal_pressure: AtomicU64::new(replayed),
            compact_retry_at: AtomicU64::new(0),
            compactions: AtomicU64::new(0),
        });
        store.path = Some(path.to_path_buf());
        store.skipped_at_open = skipped;
        Ok(store)
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Read-only stats over a data directory (or legacy WAL file)
    /// **without opening it**: no tmp cleanup, no truncation repair, no
    /// append handle. Safe to run against a directory a live server
    /// owns — `submarine storage stats` uses this. (`indexes` is
    /// always 0: index declarations are runtime state.)
    pub fn inspect(path: &Path) -> crate::Result<StorageStats> {
        let mut data: BTreeMap<String, BTreeMap<String, Json>> =
            BTreeMap::new();
        let mut skipped = 0u64;
        let mut replayed = 0u64;
        let mut snapshot_gen = 0u64;
        let mut wal_bytes = 0u64;
        if path.is_file() {
            // legacy single-file WAL, not yet migrated
            let rep = replay_wal(path, &mut data, &mut skipped)?;
            replayed = rep.applied;
            wal_bytes = fs::metadata(path).map(|m| m.len()).unwrap_or(0);
        } else {
            let scan = snapshot::scan_dir(path, false)?;
            if let Some(&g) = scan.snapshots.last() {
                data = snapshot::load_snapshot(
                    &snapshot::snapshot_path(path, g),
                )?;
                snapshot_gen = g;
            }
            for &wg in &scan.wals {
                let p = snapshot::wal_path(path, wg);
                let rep = replay_wal(&p, &mut data, &mut skipped)?;
                replayed += rep.applied;
                wal_bytes +=
                    fs::metadata(&p).map(|m| m.len()).unwrap_or(0);
            }
        }
        Ok(StorageStats {
            durable: true,
            namespaces: data.len(),
            docs: data.values().map(BTreeMap::len).sum(),
            indexes: 0,
            snapshot_gen,
            wal_records: replayed,
            wal_bytes,
            skipped_records: skipped,
            compactions: 0,
        })
    }

    // ------------------------------------------------------------ writes

    pub fn put(&self, ns: &str, key: &str, doc: Json) -> crate::Result<()> {
        self.put_rev(ns, key, |_| doc).map(|_| ())
    }

    /// Put where the new document may embed its assigned revision:
    /// `make` receives the global revision this write will carry (the
    /// resource layer stamps it into `meta.resource_version`). The
    /// record is published to the change feed in the same critical
    /// section that assigns the revision, so the feed is rev-ordered.
    pub fn put_rev(
        &self,
        ns: &str,
        key: &str,
        make: impl FnOnce(u64) -> Json,
    ) -> crate::Result<u64> {
        self.publish_put(ns, key, make, false)
    }

    /// Create-only put: fails with `AlreadyExists` when the key is
    /// present (checked atomically under the shard write lock) — the
    /// REST layer's `409` on POST of an existing resource.
    pub fn create_rev(
        &self,
        ns: &str,
        key: &str,
        make: impl FnOnce(u64) -> Json,
    ) -> crate::Result<u64> {
        self.publish_put(ns, key, make, true)
    }

    /// The one write protocol behind [`Self::put_rev`] /
    /// [`Self::create_rev`]: shard write lock -> lock-free rev
    /// assignment -> doc build (no feed mutex) -> memory apply ->
    /// in-order feed publish -> WAL.
    fn publish_put(
        &self,
        ns: &str,
        key: &str,
        make: impl FnOnce(u64) -> Json,
        must_create: bool,
    ) -> crate::Result<u64> {
        let (ticket, rev) = {
            let (mut shard, _held) = self.shard_write(ns);
            let space = self.space_mut(&mut shard, ns);
            if must_create && space.docs.contains_key(key) {
                return Err(crate::SubmarineError::AlreadyExists(
                    format!("{ns} {key}"),
                ));
            }
            let guard = self.alloc_rev();
            let rev = guard.rev;
            let doc = Arc::new(Doc::new(make(rev)));
            let line = self.dur.is_some().then(|| {
                wal_record("put", ns, key, Some(&doc), rev)
            });
            space.put(key, Arc::clone(&doc));
            guard.publish(Change {
                rev,
                ns: ns.to_string(),
                key: key.to_string(),
                doc: Some(doc),
            });
            (self.log_write(line)?, rev)
        };
        self.finish_write(ticket)?;
        Ok(rev)
    }

    pub fn delete(&self, ns: &str, key: &str) -> crate::Result<bool> {
        self.delete_if(ns, key, |_| Ok(()))
    }

    /// Conditional delete: `pred` sees the current doc under the shard
    /// write lock and may veto (e.g. a stale `If-Match` → a
    /// `PreconditionFailed` error). Returns `false` when the key does
    /// not exist. Deletes publish a tombstone to the change feed.
    pub fn delete_if(
        &self,
        ns: &str,
        key: &str,
        pred: impl FnOnce(&Json) -> crate::Result<()>,
    ) -> crate::Result<bool> {
        let ticket = {
            let (mut shard, _held) = self.shard_write(ns);
            let Some(space) = shard.spaces.get_mut(ns) else {
                return Ok(false);
            };
            let Some(old) = space.docs.get(key) else {
                return Ok(false);
            };
            pred(old.json())?;
            space.delete(key);
            let guard = self.alloc_rev();
            let rev = guard.rev;
            guard.publish(Change {
                rev,
                ns: ns.to_string(),
                key: key.to_string(),
                doc: None,
            });
            let line = self
                .dur
                .is_some()
                .then(|| wal_record("del", ns, key, None, rev));
            self.log_write(line)?
        };
        self.finish_write(ticket)?;
        Ok(true)
    }

    /// Atomic read-modify-write: `f` sees the current doc under the
    /// shard write lock and returns the replacement (or `None` to leave
    /// it untouched). Returns `false` when the key does not exist —
    /// unlike get-then-put, a concurrent `delete` can never be undone
    /// by a stale writer.
    pub fn update(
        &self,
        ns: &str,
        key: &str,
        f: impl FnOnce(&Json) -> Option<Json>,
    ) -> crate::Result<bool> {
        let outcome = self.update_rev(ns, key, |old, _| Ok(f(old)))?;
        Ok(outcome != UpdateRev::Missing)
    }

    /// Revision-aware atomic read-modify-write, the substrate of
    /// optimistic concurrency: `f` sees `(current doc, revision the
    /// write would carry)` under the shard write lock and returns
    /// `Ok(Some(new_doc))` to write, `Ok(None)` to leave the doc
    /// untouched, or `Err` to abort (a stale `If-Match` maps to
    /// `PreconditionFailed` here — exactly one of two racing
    /// conditional writers can win).
    pub fn update_rev(
        &self,
        ns: &str,
        key: &str,
        f: impl FnOnce(&Json, u64) -> crate::Result<Option<Json>>,
    ) -> crate::Result<UpdateRev> {
        let (ticket, rev) = {
            let (mut shard, _held) = self.shard_write(ns);
            let Some(space) = shard.spaces.get_mut(ns) else {
                return Ok(UpdateRev::Missing);
            };
            let Some(old) = space.docs.get(key).cloned() else {
                return Ok(UpdateRev::Missing);
            };
            // The revision is allocated up front so `f` can stamp it
            // into the document; a declined/aborted write abandons it
            // (the guard publishes a gap for the sequencer to step
            // over — watchers never see abandoned revisions).
            let guard = self.alloc_rev();
            let rev = guard.rev;
            let new_doc = match f(old.json(), rev)? {
                None => return Ok(UpdateRev::Unchanged),
                Some(nd) => Arc::new(Doc::new(nd)),
            };
            let line = self.dur.is_some().then(|| {
                wal_record("put", ns, key, Some(&new_doc), rev)
            });
            space.put(key, Arc::clone(&new_doc));
            guard.publish(Change {
                rev,
                ns: ns.to_string(),
                key: key.to_string(),
                doc: Some(new_doc),
            });
            (self.log_write(line)?, rev)
        };
        self.finish_write(ticket)?;
        Ok(UpdateRev::Written(rev))
    }

    // -------------------------------------------------------- change feed

    /// The feed mutex can see panics unwind past it (watch closures on
    /// the waiter side); recover the guard from a poisoned lock instead
    /// of bricking every subsequent write.
    fn feed_lock(&self) -> TrackedFeed<'_> {
        let _held = tracker::acquired(LockRank::Feed, 0);
        TrackedFeed {
            guard: self.feed.lock().unwrap_or_else(|e| e.into_inner()),
            _held,
        }
    }

    /// Shard read lock + its lock-order token (ordinal = shard index).
    fn shard_read(
        &self,
        ns: &str,
    ) -> (RwLockReadGuard<'_, Shard>, tracker::Held) {
        let i = shard_of(ns);
        let held = tracker::acquired(LockRank::Shard, i as u32);
        (self.shards[i].read().unwrap(), held)
    }

    /// Shard write lock + its lock-order token (ordinal = shard index).
    fn shard_write(
        &self,
        ns: &str,
    ) -> (RwLockWriteGuard<'_, Shard>, tracker::Held) {
        let i = shard_of(ns);
        let held = tracker::acquired(LockRank::Shard, i as u32);
        (self.shards[i].write().unwrap(), held)
    }

    /// Allocate the next revision lock-free. The returned guard *must*
    /// reach the sequencer exactly once: `publish` hands it a change,
    /// dropping it (decline, error, panic in a doc builder) publishes
    /// an explicit gap — either way the sequencer can keep advancing.
    fn alloc_rev(&self) -> RevGuard<'_> {
        RevGuard {
            store: self,
            rev: self.next_rev.fetch_add(1, Ordering::Relaxed),
            done: false,
        }
    }

    /// Hand a completed (or abandoned) revision to the feed sequencer
    /// and wake watchers if entries became visible.
    fn sequence(&self, rev: u64, change: Option<Change>) {
        let advanced = {
            let mut feed = self.feed_lock();
            feed.sequence(rev, change)
        };
        if advanced {
            self.feed_cv.notify_all();
        }
    }

    /// The latest published revision (0 before any write) — the list
    /// bookmark clients resume watches from.
    pub fn current_rev(&self) -> u64 {
        self.feed_lock().published
    }

    /// Feed records for `ns` with revision > `since`, oldest first.
    /// `Err(Gone)` when `since` predates the oldest retained record —
    /// the caller must relist and resume from a fresh bookmark.
    pub fn changes_since(
        &self,
        ns: &str,
        since: u64,
        limit: usize,
    ) -> crate::Result<Vec<Change>> {
        let next = self.next_rev.load(Ordering::Relaxed);
        let feed = self.feed_lock();
        if let Some(gone) = feed.gone(ns, since, next) {
            return Err(gone);
        }
        Ok(feed.collect(ns, since, limit))
    }

    /// Blocking [`Self::changes_since`]: waits up to `wait` for at
    /// least one record past `since`, returning an empty batch on
    /// timeout. This is the long-poll primitive behind `?watch=1`.
    pub fn wait_changes(
        &self,
        ns: &str,
        since: u64,
        wait: Duration,
        limit: usize,
    ) -> crate::Result<Vec<Change>> {
        let deadline = Instant::now() + wait;
        let mut feed = self.feed_lock();
        loop {
            let next = self.next_rev.load(Ordering::Relaxed);
            if let Some(gone) = feed.gone(ns, since, next) {
                return Err(gone);
            }
            let hits = feed.collect(ns, since, limit);
            if !hits.is_empty() {
                return Ok(hits);
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(Vec::new());
            }
            let (g, _) = self
                .feed_cv
                .wait_timeout(feed.guard, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            feed.guard = g;
        }
    }

    /// Block until the published revision rises above `rev` or `wait`
    /// elapses; returns the current published revision either way.
    /// This is the reactor's wakeup primitive: its feed pump sleeps
    /// here and nudges the event loop whenever *any* namespace
    /// publishes, instead of one blocked thread per parked watcher.
    pub fn wait_rev_above(&self, rev: u64, wait: Duration) -> u64 {
        let deadline = Instant::now() + wait;
        let mut feed = self.feed_lock();
        loop {
            if feed.published > rev {
                return feed.published;
            }
            let now = Instant::now();
            if now >= deadline {
                return feed.published;
            }
            let (g, _) = self
                .feed_cv
                .wait_timeout(feed.guard, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            feed.guard = g;
        }
    }

    /// Record the WAL line while the shard lock is held (so per-key WAL
    /// order matches memory order). `None` means the store is volatile
    /// (the caller skipped serializing a record nobody would read).
    /// Group mode only buffers the record and returns a ticket to
    /// await; direct mode writes through.
    fn log_write(
        &self,
        line: Option<Vec<u8>>,
    ) -> crate::Result<Option<u64>> {
        let (Some(d), Some(line)) = (&self.dur, line) else {
            return Ok(None);
        };
        if self.opts.group_commit {
            let _held = tracker::acquired(LockRank::WalPending, 0);
            let mut p = d.pending.lock().unwrap();
            p.buf.extend_from_slice(&line);
            p.records += 1;
            p.seq += 1;
            Ok(Some(p.seq))
        } else {
            let _held = tracker::acquired(LockRank::WalWriter, 0);
            let mut w = d.writer.lock().unwrap();
            w.file.write_all(&line)?;
            if self.opts.sync {
                w.file.sync_data()?;
            }
            w.records_since_snapshot += 1;
            w.wal_bytes += line.len() as u64;
            d.wal_pressure.fetch_add(1, Ordering::Relaxed);
            Ok(None)
        }
    }

    /// After the shard lock is released: wait for the ticket to become
    /// durable (possibly flushing the batch ourselves as leader), then
    /// opportunistically compact if the WAL has grown past threshold.
    fn finish_write(&self, ticket: Option<u64>) -> crate::Result<()> {
        let Some(d) = &self.dur else { return Ok(()) };
        if let Some(t) = ticket {
            self.wait_durable(d, t)?;
        }
        let threshold = self.opts.compact_threshold;
        let pressure = d.wal_pressure.load(Ordering::Relaxed);
        if threshold > 0
            && pressure >= threshold
            && pressure >= d.compact_retry_at.load(Ordering::Relaxed)
        {
            if let Ok(guard) = d.compacting.try_lock() {
                let _held =
                    tracker::try_acquired(LockRank::CompactGate, 0);
                match self.compact_locked(d, guard) {
                    Ok(_) => {
                        d.compact_retry_at.store(0, Ordering::Relaxed)
                    }
                    Err(e) => {
                        // back off: retry only once another
                        // threshold's worth of records accumulates
                        d.compact_retry_at.store(
                            pressure.saturating_add(threshold),
                            Ordering::Relaxed,
                        );
                        crate::warnlog!(
                            "storage",
                            "auto-compaction failed (backing off \
                             until wal pressure {}): {e}",
                            pressure.saturating_add(threshold)
                        );
                    }
                }
            }
        }
        Ok(())
    }

    fn wait_durable(&self, d: &Durability, ticket: u64) -> crate::Result<()> {
        loop {
            {
                let _held = tracker::acquired(LockRank::WalFlush, 0);
                let fs_ = d.flush.lock().unwrap();
                if let Some(e) = &fs_.error {
                    return Err(storage_err(e.clone()));
                }
                if fs_.seq >= ticket {
                    return Ok(());
                }
            }
            if let Ok(mut w) = d.writer.try_lock() {
                let _held =
                    tracker::try_acquired(LockRank::WalWriter, 0);
                // leader: flush everything pending (including ours)
                self.flush_batch(d, &mut w)?;
            } else {
                // follower: wait for the current leader's notify; the
                // timeout guards against a leader that errored between
                // our check and its notify
                let _held = tracker::acquired(LockRank::WalFlush, 0);
                let g = d.flush.lock().unwrap();
                if g.seq >= ticket || g.error.is_some() {
                    continue;
                }
                let _ = d
                    .flushed_cv
                    .wait_timeout(g, Duration::from_millis(20))
                    .unwrap();
            }
        }
    }

    /// Group commit: drain the pending buffer with one write (+ one
    /// fsync when configured) and wake all waiters. Caller holds the
    /// writer lock.
    fn flush_batch(
        &self,
        d: &Durability,
        w: &mut Writer,
    ) -> crate::Result<()> {
        let (buf, seq, recs) = {
            let _held = tracker::acquired(LockRank::WalPending, 0);
            let mut p = d.pending.lock().unwrap();
            let buf = std::mem::take(&mut p.buf);
            let recs = std::mem::take(&mut p.records);
            (buf, p.seq, recs)
        };
        if !buf.is_empty() {
            let res = w.file.write_all(&buf).and_then(|_| {
                if self.opts.sync {
                    w.file.sync_data()
                } else {
                    Ok(())
                }
            });
            if let Err(e) = res {
                let msg = format!("wal append failed: {e}");
                let _held = tracker::acquired(LockRank::WalFlush, 0);
                let mut fs_ = d.flush.lock().unwrap();
                fs_.error = Some(msg.clone());
                drop(fs_);
                d.flushed_cv.notify_all();
                return Err(storage_err(msg));
            }
            w.records_since_snapshot += recs;
            w.wal_bytes += buf.len() as u64;
            d.wal_pressure.fetch_add(recs, Ordering::Relaxed);
        }
        {
            let _held = tracker::acquired(LockRank::WalFlush, 0);
            let mut fs_ = d.flush.lock().unwrap();
            if fs_.seq < seq {
                fs_.seq = seq;
            }
        }
        d.flushed_cv.notify_all();
        Ok(())
    }

    // ------------------------------------------------------------- reads

    /// Zero-clone point read: the returned [`Arc<Doc>`] is a refcount
    /// bump on the stored document (`Doc` derefs to [`Json`], so read
    /// call sites use it like the document itself).
    pub fn get(&self, ns: &str, key: &str) -> Option<Arc<Doc>> {
        let (shard, _held) = self.shard_read(ns);
        shard
            .spaces
            .get(ns)
            .and_then(|space| space.docs.get(key))
            .cloned()
    }

    /// All `(key, doc)` pairs in a namespace, key-ordered. Documents
    /// are shared, not cloned.
    pub fn list(&self, ns: &str) -> Vec<(String, Arc<Doc>)> {
        let (shard, _held) = self.shard_read(ns);
        shard
            .spaces
            .get(ns)
            .map(|space| {
                space
                    .docs
                    .iter()
                    // keys must leave the lock as owned strings
                    .map(|(k, v)| (k.clone(), Arc::clone(v))) // lint: allow(hot)
                    .collect()
            })
            .unwrap_or_default()
    }

    pub fn count(&self, ns: &str) -> usize {
        let (shard, _held) = self.shard_read(ns);
        shard.spaces.get(ns).map(|s| s.docs.len()).unwrap_or(0)
    }

    /// One key-ordered page of a namespace plus the pre-pagination
    /// total — shares only the page's documents, deep-clones nothing.
    pub fn page(
        &self,
        ns: &str,
        offset: usize,
        limit: Option<usize>,
    ) -> (Vec<(String, Arc<Doc>)>, usize) {
        let (shard, _held) = self.shard_read(ns);
        match shard.spaces.get(ns) {
            None => (Vec::new(), 0), // lint: allow(hot)
            Some(space) => {
                let total = space.docs.len();
                let page = space
                    .docs
                    .iter()
                    .skip(offset)
                    .take(limit.unwrap_or(usize::MAX))
                    .map(|(k, v)| (k.clone(), Arc::clone(v))) // lint: allow(hot)
                    .collect();
                (page, total)
            }
        }
    }

    /// One key-ordered page of namespace keys plus the total.
    pub fn keys_page(
        &self,
        ns: &str,
        offset: usize,
        limit: Option<usize>,
    ) -> (Vec<String>, usize) {
        let (shard, _held) = self.shard_read(ns);
        match shard.spaces.get(ns) {
            None => (Vec::new(), 0), // lint: allow(hot)
            Some(space) => {
                let total = space.docs.len();
                let page = space
                    .docs
                    .keys()
                    .skip(offset)
                    .take(limit.unwrap_or(usize::MAX))
                    .cloned()
                    .collect();
                (page, total)
            }
        }
    }

    /// The seek bound for a cursor continuation: strictly after the
    /// last key a previous page delivered, or from the start.
    fn after_bound(
        after: Option<&str>,
    ) -> (std::ops::Bound<&str>, std::ops::Bound<&str>) {
        use std::ops::Bound;
        let lo = match after {
            Some(a) => Bound::Excluded(a),
            None => Bound::Unbounded,
        };
        (lo, Bound::Unbounded)
    }

    /// Cursor continuation of [`page`](Self::page): up to `limit`
    /// key-ordered `(key, doc)` pairs strictly after `after`, plus the
    /// live total. The `BTreeMap::range` seek makes every page
    /// O(log n + limit) regardless of how deep into the namespace the
    /// cursor is — offset paging re-walks all skipped entries.
    pub fn page_after(
        &self,
        ns: &str,
        after: Option<&str>,
        limit: usize,
    ) -> (Vec<(String, Arc<Doc>)>, usize) {
        let (shard, _held) = self.shard_read(ns);
        match shard.spaces.get(ns) {
            None => (Vec::new(), 0), // lint: allow(hot)
            Some(space) => {
                let total = space.docs.len();
                let page = space
                    .docs
                    .range::<str, _>(Self::after_bound(after))
                    .take(limit)
                    // keys must leave the lock as owned strings
                    .map(|(k, v)| (k.clone(), Arc::clone(v))) // lint: allow(hot)
                    .collect();
                (page, total)
            }
        }
    }

    /// Cursor continuation of [`keys_page`](Self::keys_page).
    pub fn keys_page_after(
        &self,
        ns: &str,
        after: Option<&str>,
        limit: usize,
    ) -> (Vec<String>, usize) {
        let (shard, _held) = self.shard_read(ns);
        match shard.spaces.get(ns) {
            None => (Vec::new(), 0), // lint: allow(hot)
            Some(space) => {
                let total = space.docs.len();
                let page = space
                    .docs
                    .range::<str, _>(Self::after_bound(after))
                    .take(limit)
                    .map(|(k, _)| k.clone()) // lint: allow(hot)
                    .collect();
                (page, total)
            }
        }
    }

    /// One bounded chunk of a namespace drain: visit `(key, doc)`
    /// pairs strictly after `after` in key order, calling `emit` for
    /// each, under a single shard read lock. Visiting stops after
    /// `max` documents or when `emit` returns `false`; the return
    /// value is `Some(last_visited_key)` when the walk stopped early
    /// (the caller's resume point) and `None` when the namespace is
    /// exhausted. Re-seeking from the returned key costs O(log n), so
    /// a full drain never re-walks delivered entries and never holds
    /// the lock longer than one chunk.
    pub fn scan_chunk(
        &self,
        ns: &str,
        after: Option<&str>,
        max: usize,
        emit: &mut dyn FnMut(&str, &Arc<Doc>) -> bool,
    ) -> Option<String> {
        let (shard, _held) = self.shard_read(ns);
        let space = shard.spaces.get(ns)?;
        let mut visited = 0usize;
        let mut last: Option<&str> = None;
        for (k, doc) in space.docs.range::<str, _>(Self::after_bound(after))
        {
            visited += 1;
            last = Some(k.as_str());
            if !emit(k, doc) || visited >= max {
                // stopped early: only a resume point if anything
                // actually remains past this key
                return if space
                    .docs
                    .range::<str, _>(Self::after_bound(last))
                    .next()
                    .is_some()
                {
                    last.map(str::to_string) // lint: allow(hot)
                } else {
                    None
                };
            }
        }
        None
    }

    // ----------------------------------------------------------- indexes

    /// Declare a secondary index on a top-level document field. Existing
    /// documents are backfilled; the declaration is idempotent and
    /// memory-only (managers re-declare on construction).
    pub fn define_index(&self, ns: &str, field: &str, case_insensitive: bool) {
        let def = IndexDef::new(field, case_insensitive);
        {
            let _held = tracker::acquired(LockRank::Index, 0);
            let mut defs = self.defs.write().unwrap();
            let list = defs.entry(ns.to_string()).or_default();
            if list.contains(&def) {
                return;
            }
            list.push(def.clone());
        }
        // backfill the live namespace, if it exists yet
        let (mut shard, _held) = self.shard_write(ns);
        if let Some(space) = shard.spaces.get_mut(ns) {
            if space.index(field).is_none() {
                let mut idx = FieldIndex::new(def);
                for (k, doc) in &space.docs {
                    idx.add(k, doc);
                }
                space.indexes.push(idx);
            }
        }
    }

    fn no_index(ns: &str, field: &str) -> crate::SubmarineError {
        storage_err(format!("no index on {ns}.{field}; define_index first"))
    }

    /// Keys whose documents carry `value` in the indexed `field`.
    pub fn index_lookup(
        &self,
        ns: &str,
        field: &str,
        value: &str,
    ) -> crate::Result<Vec<String>> {
        if !self.index_defined(ns, field) {
            return Err(Self::no_index(ns, field));
        }
        let (shard, _held) = self.shard_read(ns);
        Ok(shard
            .spaces
            .get(ns)
            .and_then(|space| space.index(field))
            .map(|idx| idx.lookup(value))
            .unwrap_or_default())
    }

    /// One page of `(key, doc)` whose indexed `field` equals `value`,
    /// plus the total match count — the index walk replaces the seed's
    /// scan-and-filter, and the page shares documents instead of
    /// cloning them.
    pub fn index_page(
        &self,
        ns: &str,
        field: &str,
        value: &str,
        offset: usize,
        limit: Option<usize>,
    ) -> crate::Result<(Vec<(String, Arc<Doc>)>, usize)> {
        if !self.index_defined(ns, field) {
            return Err(Self::no_index(ns, field));
        }
        let (shard, _held) = self.shard_read(ns);
        let Some(space) = shard.spaces.get(ns) else {
            return Ok((Vec::new(), 0)); // lint: allow(hot)
        };
        let Some(idx) = space.index(field) else {
            return Ok((Vec::new(), 0)); // lint: allow(hot)
        };
        let total = idx.cardinality(value);
        let page = idx
            .lookup(value)
            .into_iter()
            .skip(offset)
            .take(limit.unwrap_or(usize::MAX))
            .filter_map(|k| {
                // `lookup` already materialized the key as an owned
                // String; move it into the row instead of cloning it
                // a second time
                let d = Arc::clone(space.docs.get(&k)?);
                Some((k, d))
            })
            .collect();
        Ok((page, total))
    }

    /// Cursor continuation of [`index_page`](Self::index_page): up to
    /// `limit` matches whose keys sort strictly after `after`. The
    /// posting set is ordered, so the continuation seeks instead of
    /// re-walking delivered postings.
    pub fn index_page_after(
        &self,
        ns: &str,
        field: &str,
        value: &str,
        after: Option<&str>,
        limit: usize,
    ) -> crate::Result<(Vec<(String, Arc<Doc>)>, usize)> {
        if !self.index_defined(ns, field) {
            return Err(Self::no_index(ns, field));
        }
        let (shard, _held) = self.shard_read(ns);
        let Some(space) = shard.spaces.get(ns) else {
            return Ok((Vec::new(), 0)); // lint: allow(hot)
        };
        let Some(idx) = space.index(field) else {
            return Ok((Vec::new(), 0)); // lint: allow(hot)
        };
        let total = idx.cardinality(value);
        let page = idx
            .lookup_after(value, after, limit)
            .into_iter()
            .filter_map(|k| {
                let d = Arc::clone(space.docs.get(&k)?);
                Some((k, d))
            })
            .collect();
        Ok((page, total))
    }

    fn index_defined(&self, ns: &str, field: &str) -> bool {
        let _held = tracker::acquired(LockRank::Index, 0);
        let defs = self.defs.read().unwrap();
        defs.get(ns)
            .map(|list| list.iter().any(|d| d.field == field))
            .unwrap_or(false)
    }

    // -------------------------------------------------------- compaction

    /// Write a snapshot of the current state and rotate the WAL,
    /// bounding the log. Safe under concurrent writes (see module docs).
    pub fn compact(&self) -> crate::Result<CompactReport> {
        let Some(d) = &self.dur else {
            return Ok(CompactReport {
                gen: 0,
                docs: 0,
                removed_files: 0,
            });
        };
        let _held = tracker::acquired(LockRank::CompactGate, 0);
        let guard = d.compacting.lock().unwrap();
        self.compact_locked(d, guard)
    }

    fn compact_locked(
        &self,
        d: &Durability,
        _compacting: MutexGuard<'_, ()>,
    ) -> crate::Result<CompactReport> {
        let new_gen = {
            let _held = tracker::acquired(LockRank::WalWriter, 0);
            d.writer.lock().unwrap().gen + 1
        };

        // 1. Take every shard's *read* lock and hold them through the
        //    rotation. Writers (which need write locks to apply + enqueue)
        //    pause for the duration, reads stay live — so the snapshot is
        //    a consistent cut: every record that could ever reach the old
        //    WAL is applied to memory before the copy, and nothing new
        //    can slip into the old WAL afterwards. Without this, a write
        //    flushed to the old WAL after the copy would be lost when
        //    step 4 deletes it.
        let mut held = Vec::with_capacity(self.shards.len());
        let guards: Vec<_> = self
            .shards
            .iter()
            .enumerate()
            .map(|(i, sh)| {
                held.push(tracker::acquired(LockRank::Shard, i as u32));
                sh.read().unwrap()
            })
            .collect();
        let mut dump: Vec<(String, Vec<(String, Arc<Doc>)>)> = Vec::new();
        let mut docs = 0usize;
        for g in &guards {
            for (ns, space) in &g.spaces {
                if space.docs.is_empty() {
                    continue;
                }
                docs += space.docs.len();
                dump.push((
                    ns.clone(),
                    space
                        .docs
                        .iter()
                        .map(|(k, v)| (k.clone(), Arc::clone(v)))
                        .collect(),
                ));
            }
        }
        dump.sort_by(|a, b| a.0.cmp(&b.0));

        // 2. Durable snapshot (tmp + fsync + atomic rename).
        snapshot::write_snapshot(&d.dir, new_gen, &dump)?;

        // 3. Rotate: move any still-pending records onto the new WAL and
        //    swap the writer. Pending records were applied before the
        //    copy (so they're also in the snapshot — the duplicate
        //    replays idempotently); in-flight group flushes that beat us
        //    to the old WAL are in the snapshot for the same reason.
        //    Failure here is sticky — waiters whose records we drained
        //    must not report durability.
        {
            let _hw = tracker::acquired(LockRank::WalWriter, 0);
            let mut w = d.writer.lock().unwrap();
            let _hp = tracker::acquired(LockRank::WalPending, 0);
            let mut p = d.pending.lock().unwrap();
            let buf = std::mem::take(&mut p.buf);
            let recs = std::mem::take(&mut p.records);
            let seq = p.seq;
            drop(p);
            drop(_hp);
            // The fresh WAL opens with a revision high-water marker:
            // the deleted generations may have held the only records
            // carrying the top revisions (tombstones), and losing the
            // mark would make a restarted server re-assign them.
            let marker = rev_marker(self.current_rev().max(1));
            let rotate = || -> std::io::Result<(fs::File, u64)> {
                let mut file = fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(snapshot::wal_path(&d.dir, new_gen))?;
                file.write_all(&marker)?;
                if !buf.is_empty() {
                    file.write_all(&buf)?;
                }
                if self.opts.sync {
                    file.sync_data()?;
                }
                Ok((file, (marker.len() + buf.len()) as u64))
            };
            match rotate() {
                Ok((file, bytes)) => {
                    w.file = file;
                    w.gen = new_gen;
                    w.records_since_snapshot = recs;
                    w.wal_bytes = bytes;
                    d.wal_pressure.store(recs, Ordering::Relaxed);
                    let _hf =
                        tracker::acquired(LockRank::WalFlush, 0);
                    let mut fs_ = d.flush.lock().unwrap();
                    if fs_.seq < seq {
                        fs_.seq = seq;
                    }
                    drop(fs_);
                    d.flushed_cv.notify_all();
                }
                Err(e) => {
                    let msg = format!("wal rotation failed: {e}");
                    let _hf =
                        tracker::acquired(LockRank::WalFlush, 0);
                    let mut fs_ = d.flush.lock().unwrap();
                    fs_.error = Some(msg.clone());
                    drop(fs_);
                    d.flushed_cv.notify_all();
                    return Err(storage_err(msg));
                }
            }
        }

        drop(guards); // release writers before file cleanup
        drop(held);

        // 4. Everything older than the new snapshot is now redundant.
        let removed = snapshot::remove_stale(&d.dir, new_gen, true);
        d.compactions.fetch_add(1, Ordering::Relaxed);
        crate::info!(
            "storage",
            "compacted to gen {new_gen} ({docs} docs, {removed} stale \
             files removed)"
        );
        Ok(CompactReport {
            gen: new_gen,
            docs,
            removed_files: removed,
        })
    }

    // ------------------------------------------------------------- stats

    pub fn stats(&self) -> StorageStats {
        let mut namespaces = 0;
        let mut docs = 0;
        let mut indexes = 0;
        for (i, sh) in self.shards.iter().enumerate() {
            let _held = tracker::acquired(LockRank::Shard, i as u32);
            let g = sh.read().unwrap();
            for space in g.spaces.values() {
                namespaces += 1;
                docs += space.docs.len();
                indexes += space.indexes.len();
            }
        }
        let (snapshot_gen, wal_records, wal_bytes, compactions) =
            match &self.dur {
                None => (0, 0, 0, 0),
                Some(d) => {
                    let _held =
                        tracker::acquired(LockRank::WalWriter, 0);
                    let w = d.writer.lock().unwrap();
                    (
                        w.gen,
                        w.records_since_snapshot,
                        w.wal_bytes,
                        d.compactions.load(Ordering::Relaxed),
                    )
                }
            };
        StorageStats {
            durable: self.dur.is_some(),
            namespaces,
            docs,
            indexes,
            snapshot_gen,
            wal_records,
            wal_bytes,
            skipped_records: self.skipped_at_open,
            compactions,
        }
    }

    /// Full dump as `{ns: {key: doc}}`, namespaces and keys sorted —
    /// used by the crash-recovery equivalence tests.
    pub fn dump(&self) -> Json {
        let mut spaces: BTreeMap<String, Json> = BTreeMap::new();
        for (i, sh) in self.shards.iter().enumerate() {
            let _held = tracker::acquired(LockRank::Shard, i as u32);
            let g = sh.read().unwrap();
            for (ns, space) in &g.spaces {
                if space.docs.is_empty() {
                    continue;
                }
                spaces.insert(
                    ns.clone(),
                    Json::Obj(
                        space
                            .docs
                            .iter()
                            .map(|(k, v)| (k.clone(), v.json().clone()))
                            .collect(),
                    ),
                );
            }
        }
        Json::Obj(spaces.into_iter().collect())
    }

    // ----------------------------------------------------------- helpers

    fn space_mut<'a>(
        &self,
        shard: &'a mut Shard,
        ns: &str,
    ) -> &'a mut Namespace {
        if !shard.spaces.contains_key(ns) {
            let mut space = Namespace::default();
            let _held = tracker::acquired(LockRank::Index, 0);
            let defs = self.defs.read().unwrap();
            if let Some(list) = defs.get(ns) {
                for def in list {
                    space.indexes.push(FieldIndex::new(def.clone()));
                }
            }
            shard.spaces.insert(ns.to_string(), space);
        }
        shard.spaces.get_mut(ns).unwrap()
    }
}

fn migration_backup_path(path: &Path) -> PathBuf {
    let mut bak = path.as_os_str().to_os_string();
    bak.push(".migrating");
    PathBuf::from(bak)
}

/// Heal a migration the process died in the middle of. The backup file
/// `<path>.migrating` exists only between `migrate_legacy_file`'s
/// rename and its final cleanup: if the snapshot made it, finish the
/// cleanup; otherwise roll the rename back so the legacy data is never
/// stranded in a file no code path reads.
fn recover_interrupted_migration(path: &Path) -> crate::Result<()> {
    let bak = migration_backup_path(path);
    if !bak.is_file() {
        return Ok(());
    }
    let migrated = path.is_dir()
        && snapshot::snapshot_path(path, 1).is_file();
    if migrated {
        fs::remove_file(&bak)?;
    } else {
        // crash before the snapshot: restore the legacy file and let
        // the normal migration path run again
        if path.is_dir() {
            fs::remove_dir_all(path)?;
        }
        fs::rename(&bak, path)?;
        crate::warnlog!(
            "storage",
            "resuming interrupted legacy migration of {}",
            path.display()
        );
    }
    Ok(())
}

/// Migrate a pre-v2 single-file JSON-lines WAL into the directory
/// layout: tolerant replay, then snapshot generation 1 in a directory
/// at the same path. Crash-safe: the source is renamed to
/// `<path>.migrating` first, and [`recover_interrupted_migration`]
/// completes or rolls back a half-done pass on the next open.
fn migrate_legacy_file(
    path: &Path,
    skipped: &mut u64,
) -> crate::Result<()> {
    let mut data: BTreeMap<String, BTreeMap<String, Json>> = BTreeMap::new();
    let _ = replay_wal(path, &mut data, skipped)?;
    let bak = migration_backup_path(path);
    fs::rename(path, &bak)?;
    fs::create_dir_all(path)?;
    let dump: Vec<(String, Vec<(String, Arc<Doc>)>)> = data
        .into_iter()
        .map(|(ns, docs)| {
            (
                ns,
                docs.into_iter()
                    .map(|(k, v)| (k, Arc::new(Doc::new(v))))
                    .collect(),
            )
        })
        .collect();
    snapshot::write_snapshot(path, 1, &dump)?;
    fs::remove_file(&bak)?;
    crate::info!(
        "storage",
        "migrated legacy WAL file into data dir {}",
        path.display()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "submarine-kv-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        let _ = fs::remove_file(&d);
        d
    }

    /// Owned-`Json` view of a stored doc for equality asserts.
    fn got(s: &MetaStore, ns: &str, key: &str) -> Option<Json> {
        s.get(ns, key).map(|d| d.json().clone())
    }

    #[test]
    fn put_get_delete_roundtrip() {
        let s = MetaStore::in_memory();
        s.put("exp", "e1", Json::parse(r#"{"name":"mnist"}"#).unwrap())
            .unwrap();
        assert_eq!(
            s.get("exp", "e1").unwrap().str_field("name"),
            Some("mnist")
        );
        assert!(s.delete("exp", "e1").unwrap());
        assert!(!s.delete("exp", "e1").unwrap());
        assert!(s.get("exp", "e1").is_none());
    }

    #[test]
    fn namespaces_are_isolated() {
        let s = MetaStore::in_memory();
        s.put("a", "k", Json::Num(1.0)).unwrap();
        s.put("b", "k", Json::Num(2.0)).unwrap();
        assert_eq!(got(&s, "a", "k"), Some(Json::Num(1.0)));
        assert_eq!(got(&s, "b", "k"), Some(Json::Num(2.0)));
        assert_eq!(s.count("a"), 1);
    }

    #[test]
    fn list_is_key_ordered() {
        let s = MetaStore::in_memory();
        for k in ["c", "a", "b"] {
            s.put("ns", k, Json::Null).unwrap();
        }
        let keys: Vec<_> =
            s.list("ns").into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, ["a", "b", "c"]);
    }

    #[test]
    fn page_slices_without_full_clone() {
        let s = MetaStore::in_memory();
        for i in 0..10 {
            s.put("ns", &format!("k{i:02}"), Json::Num(i as f64))
                .unwrap();
        }
        let (page, total) = s.page("ns", 3, Some(2));
        assert_eq!(total, 10);
        assert_eq!(
            page.iter().map(|(k, _)| k.as_str()).collect::<Vec<_>>(),
            ["k03", "k04"]
        );
        let (keys, total) = s.keys_page("ns", 8, Some(5));
        assert_eq!((keys.len(), total), (2, 10));
    }

    #[test]
    fn page_after_seeks_and_survives_interleaved_writes() {
        let s = MetaStore::in_memory();
        for i in 0..10 {
            s.put("ns", &format!("k{i:02}"), Json::Num(i as f64))
                .unwrap();
        }
        let (page, total) = s.page_after("ns", None, 3);
        assert_eq!(total, 10);
        let keys: Vec<_> =
            page.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["k00", "k01", "k02"]);
        // a write landing before the cursor and a delete of the
        // cursor key itself don't shift the continuation
        s.put("ns", "k000", Json::Null).unwrap();
        s.delete("ns", "k02").unwrap();
        let (page, _) = s.page_after("ns", Some("k02"), 3);
        let keys: Vec<_> =
            page.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["k03", "k04", "k05"]);
        // keys-only continuation agrees
        let (keys, _) = s.keys_page_after("ns", Some("k08"), 10);
        assert_eq!(keys, ["k09"]);
        assert!(s.page_after("ns", Some("k09"), 3).0.is_empty());
        assert_eq!(s.page_after("nowhere", None, 3).1, 0);
    }

    #[test]
    fn scan_chunk_drains_in_bounded_chunks() {
        let s = MetaStore::in_memory();
        for i in 0..10 {
            s.put("ns", &format!("k{i:02}"), Json::Num(i as f64))
                .unwrap();
        }
        let mut seen = Vec::new();
        let mut after: Option<String> = None;
        let mut chunks = 0;
        loop {
            let resume = s.scan_chunk(
                "ns",
                after.as_deref(),
                4,
                &mut |k, _| {
                    seen.push(k.to_string());
                    true
                },
            );
            chunks += 1;
            match resume {
                Some(k) => after = Some(k),
                None => break,
            }
        }
        assert_eq!(seen.len(), 10);
        assert_eq!(chunks, 3); // 4 + 4 + 2
        assert!(seen.windows(2).all(|w| w[0] < w[1]));
        // emit returning false stops the chunk early with a resume key
        let resume = s.scan_chunk("ns", None, 100, &mut |_, _| false);
        assert_eq!(resume.as_deref(), Some("k00"));
        // a chunk that exactly exhausts the namespace reports done
        let resume =
            s.scan_chunk("ns", Some("k05"), 4, &mut |_, _| true);
        assert!(resume.is_none());
        assert!(s.scan_chunk("nowhere", None, 4, &mut |_, _| true).is_none());
    }

    #[test]
    fn update_is_atomic_and_respects_absence() {
        let s = MetaStore::in_memory();
        assert!(!s.update("ns", "k", |_| None).unwrap());
        s.put("ns", "k", Json::Num(1.0)).unwrap();
        assert!(s
            .update("ns", "k", |d| Some(Json::Num(
                d.as_f64().unwrap() + 1.0
            )))
            .unwrap());
        assert_eq!(got(&s, "ns", "k"), Some(Json::Num(2.0)));
        // None leaves the doc untouched
        assert!(s.update("ns", "k", |_| None).unwrap());
        assert_eq!(got(&s, "ns", "k"), Some(Json::Num(2.0)));
    }

    #[test]
    fn revisions_are_monotonic_and_feed_orders_them() {
        let s = MetaStore::in_memory();
        assert_eq!(s.current_rev(), 0);
        let r1 = s.put_rev("ns", "a", |_| Json::Num(1.0)).unwrap();
        let r2 = s.put_rev("ns", "b", |rev| Json::Num(rev as f64)).unwrap();
        assert!(r2 > r1);
        assert_eq!(s.current_rev(), r2);
        // the doc built by `make` saw its own revision
        assert_eq!(got(&s, "ns", "b"), Some(Json::Num(r2 as f64)));
        let changes = s.changes_since("ns", 0, 100).unwrap();
        assert_eq!(changes.len(), 2);
        assert_eq!(changes[0].rev, r1);
        assert_eq!(changes[1].rev, r2);
        // deletes publish tombstones
        s.delete("ns", "a").unwrap();
        let changes = s.changes_since("ns", r2, 100).unwrap();
        assert_eq!(changes.len(), 1);
        assert!(changes[0].doc.is_none());
        // namespace filtering
        assert!(s.changes_since("other", 0, 100).unwrap().is_empty());
    }

    #[test]
    fn feed_overflow_signals_gone() {
        let s = MetaStore::in_memory_with(StoreOptions {
            feed_capacity: 4,
            ..StoreOptions::default()
        });
        for i in 0..10 {
            s.put("ns", &format!("k{i}"), Json::Num(i as f64)).unwrap();
        }
        // rev 0 predates the ring: Gone
        let err = s.changes_since("ns", 0, 100).unwrap_err();
        assert_eq!(err.http_status(), 410);
        // resuming from the current bookmark is clean
        let rev = s.current_rev();
        assert!(s.changes_since("ns", rev, 100).unwrap().is_empty());
    }

    #[test]
    fn churn_elsewhere_does_not_gone_a_quiet_namespace() {
        let s = MetaStore::in_memory_with(StoreOptions {
            feed_capacity: 4,
            ..StoreOptions::default()
        });
        s.put("quiet", "q", Json::Num(0.0)).unwrap(); // rev 1
        let bookmark = s.current_rev();
        // heavy churn in another namespace evicts the quiet
        // namespace's *event*, then rolls far past the bookmark
        for i in 0..20 {
            s.put("busy", &format!("k{i}"), Json::Num(i as f64))
                .unwrap();
        }
        // the quiet watcher missed nothing after its bookmark: no 410
        assert!(s
            .changes_since("quiet", bookmark, 100)
            .unwrap()
            .is_empty());
        // but a quiet-namespace bookmark from before its own evicted
        // event is still Gone
        let err = s.changes_since("quiet", 0, 100).unwrap_err();
        assert_eq!(err.http_status(), 410);
        // and the busy namespace reports Gone for stale positions
        assert_eq!(
            s.changes_since("busy", 2, 100).unwrap_err().http_status(),
            410
        );
    }

    #[test]
    fn revision_counter_survives_deletes_and_compaction() {
        let dir = tmp_dir("rev-hwm");
        let bookmark;
        {
            let s = MetaStore::open(&dir).unwrap();
            s.put("ns", "a", Json::Num(1.0)).unwrap(); // rev 1
            s.delete("ns", "a").unwrap(); // tombstone holds rev 2
            bookmark = s.current_rev();
            assert_eq!(bookmark, 2);
        }
        {
            // plain restart: WAL records carry their revisions, so
            // the counter does NOT regress even though no surviving
            // doc references rev 2 — a pre-restart bookmark can never
            // silently skip post-restart events
            let s = MetaStore::open(&dir).unwrap();
            assert_eq!(s.current_rev(), bookmark);
            s.put("ns", "b", Json::Num(2.0)).unwrap(); // rev 3
            let changes = s.changes_since("ns", bookmark, 10).unwrap();
            assert_eq!(changes.len(), 1);
            assert!(changes[0].rev > bookmark);
            // compaction rotates the WAL away; the rotation marker
            // preserves the high-water mark
            s.compact().unwrap();
        }
        let s = MetaStore::open(&dir).unwrap();
        assert!(s.current_rev() >= 3, "{}", s.current_rev());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn bookmark_from_the_future_is_gone_not_a_hang() {
        // defense in depth: a bookmark beyond anything ever assigned
        // (another server's timeline) forces a relist instead of a
        // wait that can never be satisfied
        let s = MetaStore::in_memory();
        s.put("ns", "k", Json::Null).unwrap();
        assert_eq!(
            s.changes_since("ns", 999, 10)
                .unwrap_err()
                .http_status(),
            410
        );
    }

    #[test]
    fn create_rev_conflicts_on_existing_key() {
        let s = MetaStore::in_memory();
        s.create_rev("ns", "k", |_| Json::Num(1.0)).unwrap();
        let err = s.create_rev("ns", "k", |_| Json::Num(2.0)).unwrap_err();
        assert_eq!(err.http_status(), 409);
        assert_eq!(got(&s, "ns", "k"), Some(Json::Num(1.0)));
    }

    #[test]
    fn update_rev_supports_conditional_writes() {
        let s = MetaStore::in_memory();
        assert_eq!(
            s.update_rev("ns", "k", |_, _| Ok(None)).unwrap(),
            UpdateRev::Missing
        );
        s.put("ns", "k", Json::Num(1.0)).unwrap();
        // closure veto aborts without writing
        let err = s
            .update_rev("ns", "k", |_, _| {
                Err(crate::SubmarineError::PreconditionFailed(
                    "stale".into(),
                ))
            })
            .unwrap_err();
        assert_eq!(err.http_status(), 412);
        assert_eq!(got(&s, "ns", "k"), Some(Json::Num(1.0)));
        match s
            .update_rev("ns", "k", |_, rev| {
                Ok(Some(Json::Num(rev as f64)))
            })
            .unwrap()
        {
            UpdateRev::Written(rev) => {
                assert_eq!(got(&s, "ns", "k"), Some(Json::Num(rev as f64)))
            }
            other => panic!("expected write, got {other:?}"),
        }
    }

    #[test]
    fn wait_changes_wakes_on_write() {
        use std::sync::Arc;
        let s = Arc::new(MetaStore::in_memory());
        let rev = s.current_rev();
        let watcher = {
            let s = Arc::clone(&s);
            std::thread::spawn(move || {
                s.wait_changes(
                    "ns",
                    rev,
                    Duration::from_secs(5),
                    16,
                )
                .unwrap()
            })
        };
        std::thread::sleep(Duration::from_millis(20));
        s.put("ns", "k", Json::Num(7.0)).unwrap();
        let changes = watcher.join().unwrap();
        assert_eq!(changes.len(), 1);
        assert_eq!(changes[0].key, "k");
        // timeout path returns empty, not an error
        let none = s
            .wait_changes(
                "ns",
                s.current_rev(),
                Duration::from_millis(10),
                16,
            )
            .unwrap();
        assert!(none.is_empty());
    }

    #[test]
    fn revision_counter_survives_reopen_via_doc_meta() {
        let dir = tmp_dir("revs");
        let rev = {
            let s = MetaStore::open(&dir).unwrap();
            s.put_rev("ns", "k", |rev| {
                Json::obj().set(
                    "meta",
                    Json::obj()
                        .set("resource_version", Json::Num(rev as f64)),
                )
            })
            .unwrap()
        };
        let s = MetaStore::open(&dir).unwrap();
        // counter resumes past the persisted max; old watch positions
        // are Gone (the feed is volatile)
        assert_eq!(s.current_rev(), rev);
        let next = s.put_rev("ns", "k2", |r| Json::Num(r as f64)).unwrap();
        assert!(next > rev);
        assert_eq!(s.changes_since("ns", 0, 10).unwrap_err().http_status(), 410);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn wal_replay_restores_state() {
        let dir = tmp_dir("replay");
        {
            let s = MetaStore::open(&dir).unwrap();
            s.put("exp", "e1", Json::Num(1.0)).unwrap();
            s.put("exp", "e2", Json::Num(2.0)).unwrap();
            s.put("exp", "e1", Json::Num(3.0)).unwrap(); // overwrite
            s.delete("exp", "e2").unwrap();
        }
        let s = MetaStore::open(&dir).unwrap();
        assert_eq!(got(&s, "exp", "e1"), Some(Json::Num(3.0)));
        assert!(s.get("exp", "e2").is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn interior_corruption_is_an_error() {
        let dir = tmp_dir("corrupt");
        fs::create_dir_all(&dir).unwrap();
        fs::write(
            snapshot::wal_path(&dir, 1),
            "not json\n{\"op\":\"put\",\"ns\":\"a\",\"key\":\"k\",\
             \"doc\":1}\n",
        )
        .unwrap();
        assert!(MetaStore::open(&dir).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn secondary_index_tracks_puts_and_deletes() {
        let s = MetaStore::in_memory();
        s.define_index("exp", "status", true);
        let doc = |st: &str| {
            Json::obj().set("status", Json::Str(st.to_string()))
        };
        s.put("exp", "e1", doc("Running")).unwrap();
        s.put("exp", "e2", doc("Running")).unwrap();
        s.put("exp", "e3", doc("Failed")).unwrap();
        assert_eq!(
            s.index_lookup("exp", "status", "running").unwrap(),
            vec!["e1", "e2"]
        );
        // transition e1 and delete e2: postings follow transactionally
        s.put("exp", "e1", doc("Succeeded")).unwrap();
        s.delete("exp", "e2").unwrap();
        assert!(s
            .index_lookup("exp", "status", "Running")
            .unwrap()
            .is_empty());
        let (page, total) = s
            .index_page("exp", "status", "succeeded", 0, Some(10))
            .unwrap();
        assert_eq!(total, 1);
        assert_eq!(page[0].0, "e1");
        // undeclared index is loud, not silently empty
        assert!(s.index_lookup("exp", "nope", "x").is_err());
    }

    #[test]
    fn index_page_after_resumes_deterministically() {
        let s = MetaStore::in_memory();
        s.define_index("exp", "status", true);
        let doc = |st: &str| {
            Json::obj().set("status", Json::Str(st.to_string()))
        };
        for i in 0..6 {
            s.put("exp", &format!("e{i}"), doc("Running")).unwrap();
        }
        s.put("exp", "zz", doc("Failed")).unwrap();
        let (page, total) = s
            .index_page_after("exp", "status", "running", None, 2)
            .unwrap();
        assert_eq!(total, 6);
        let keys: Vec<_> =
            page.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["e0", "e1"]);
        // the continuation seeks past delivered postings even after
        // the anchor key changed status (left the posting set)
        s.put("exp", "e1", doc("Failed")).unwrap();
        let (page, _) = s
            .index_page_after("exp", "status", "running", Some("e1"), 2)
            .unwrap();
        let keys: Vec<_> =
            page.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["e2", "e3"]);
        assert!(s
            .index_page_after("exp", "nope", "x", None, 2)
            .is_err());
    }

    #[test]
    fn define_index_backfills_existing_docs() {
        let s = MetaStore::in_memory();
        s.put("m", "k1", Json::obj().set("stage", Json::Str("Prod".into())))
            .unwrap();
        s.define_index("m", "stage", true);
        assert_eq!(
            s.index_lookup("m", "stage", "prod").unwrap(),
            vec!["k1"]
        );
        // idempotent re-declaration keeps one index
        s.define_index("m", "stage", true);
        assert_eq!(s.stats().indexes, 1);
    }

    #[test]
    fn compaction_bounds_the_wal_and_survives_reopen() {
        let dir = tmp_dir("compact");
        {
            let s = MetaStore::open_with(
                &dir,
                StoreOptions {
                    compact_threshold: 8,
                    ..StoreOptions::default()
                },
            )
            .unwrap();
            for i in 0..50 {
                s.put("ns", &format!("k{i:03}"), Json::Num(i as f64))
                    .unwrap();
            }
            let st = s.stats();
            assert!(
                st.wal_records < 50,
                "auto-compaction never fired: {st:?}"
            );
            assert!(st.compactions >= 1);
            assert!(st.snapshot_gen > 1);
        }
        let s = MetaStore::open(&dir).unwrap();
        assert_eq!(s.count("ns"), 50);
        assert_eq!(got(&s, "ns", "k049"), Some(Json::Num(49.0)));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_reflect_contents() {
        let s = MetaStore::in_memory();
        s.put("a", "k", Json::Null).unwrap();
        s.put("b", "k", Json::Null).unwrap();
        let st = s.stats();
        assert!(!st.durable);
        assert_eq!(st.namespaces, 2);
        assert_eq!(st.docs, 2);
        assert_eq!(st.skipped_records, 0);
    }
}
