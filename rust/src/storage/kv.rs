//! Embedded metadata store: in-memory maps + append-only JSON-lines WAL.
//!
//! Write path: mutate memory, append one WAL record
//! (`{"op":"put","ns":..,"key":..,"doc":..}`); recovery replays the log.
//! This deliberately mirrors what Submarine gets from MySQL at the
//! fidelity the paper's experiments need (durable experiment metadata,
//! comparability across runs) without an external service.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{BufRead, Write};
use std::path::PathBuf;
use std::sync::Mutex;

struct Inner {
    data: BTreeMap<String, BTreeMap<String, Json>>,
    wal: Option<std::fs::File>,
}

/// Thread-safe namespaced document store.
pub struct MetaStore {
    inner: Mutex<Inner>,
    path: Option<PathBuf>,
}

impl MetaStore {
    /// Volatile store (tests, benches).
    pub fn in_memory() -> MetaStore {
        MetaStore {
            inner: Mutex::new(Inner {
                data: BTreeMap::new(),
                wal: None,
            }),
            path: None,
        }
    }

    /// Durable store backed by a WAL file; replays existing log.
    pub fn open(path: &std::path::Path) -> crate::Result<MetaStore> {
        let mut data: BTreeMap<String, BTreeMap<String, Json>> =
            BTreeMap::new();
        if path.exists() {
            let f = std::fs::File::open(path)?;
            for line in std::io::BufReader::new(f).lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                let rec = Json::parse(&line).map_err(|e| {
                    crate::SubmarineError::Storage(format!(
                        "corrupt WAL line: {e}"
                    ))
                })?;
                let ns = rec.str_field("ns").unwrap_or_default().to_string();
                let key =
                    rec.str_field("key").unwrap_or_default().to_string();
                match rec.str_field("op") {
                    Some("put") => {
                        let doc =
                            rec.get("doc").cloned().unwrap_or(Json::Null);
                        data.entry(ns).or_default().insert(key, doc);
                    }
                    Some("del") => {
                        data.entry(ns).or_default().remove(&key);
                    }
                    other => {
                        return Err(crate::SubmarineError::Storage(
                            format!("unknown WAL op {other:?}"),
                        ))
                    }
                }
            }
        }
        let wal = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        Ok(MetaStore {
            inner: Mutex::new(Inner {
                data,
                wal: Some(wal),
            }),
            path: Some(path.to_path_buf()),
        })
    }

    pub fn path(&self) -> Option<&std::path::Path> {
        self.path.as_deref()
    }

    pub fn put(&self, ns: &str, key: &str, doc: Json) -> crate::Result<()> {
        let mut g = self.inner.lock().unwrap();
        if let Some(w) = g.wal.as_mut() {
            let rec = Json::obj()
                .set("op", Json::Str("put".into()))
                .set("ns", Json::Str(ns.into()))
                .set("key", Json::Str(key.into()))
                .set("doc", doc.clone());
            writeln!(w, "{}", rec.dump())?;
        }
        g.data
            .entry(ns.to_string())
            .or_default()
            .insert(key.to_string(), doc);
        Ok(())
    }

    pub fn get(&self, ns: &str, key: &str) -> Option<Json> {
        self.inner
            .lock()
            .unwrap()
            .data
            .get(ns)
            .and_then(|m| m.get(key))
            .cloned()
    }

    pub fn delete(&self, ns: &str, key: &str) -> crate::Result<bool> {
        let mut g = self.inner.lock().unwrap();
        let existed = g
            .data
            .get_mut(ns)
            .map(|m| m.remove(key).is_some())
            .unwrap_or(false);
        if existed {
            if let Some(w) = g.wal.as_mut() {
                let rec = Json::obj()
                    .set("op", Json::Str("del".into()))
                    .set("ns", Json::Str(ns.into()))
                    .set("key", Json::Str(key.into()));
                writeln!(w, "{}", rec.dump())?;
            }
        }
        Ok(existed)
    }

    /// All `(key, doc)` pairs in a namespace, key-ordered.
    pub fn list(&self, ns: &str) -> Vec<(String, Json)> {
        self.inner
            .lock()
            .unwrap()
            .data
            .get(ns)
            .map(|m| {
                m.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
            })
            .unwrap_or_default()
    }

    pub fn count(&self, ns: &str) -> usize {
        self.inner
            .lock()
            .unwrap()
            .data
            .get(ns)
            .map(|m| m.len())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_delete_roundtrip() {
        let s = MetaStore::in_memory();
        s.put("exp", "e1", Json::parse(r#"{"name":"mnist"}"#).unwrap())
            .unwrap();
        assert_eq!(
            s.get("exp", "e1").unwrap().str_field("name"),
            Some("mnist")
        );
        assert!(s.delete("exp", "e1").unwrap());
        assert!(!s.delete("exp", "e1").unwrap());
        assert!(s.get("exp", "e1").is_none());
    }

    #[test]
    fn namespaces_are_isolated() {
        let s = MetaStore::in_memory();
        s.put("a", "k", Json::Num(1.0)).unwrap();
        s.put("b", "k", Json::Num(2.0)).unwrap();
        assert_eq!(s.get("a", "k"), Some(Json::Num(1.0)));
        assert_eq!(s.get("b", "k"), Some(Json::Num(2.0)));
        assert_eq!(s.count("a"), 1);
    }

    #[test]
    fn list_is_key_ordered() {
        let s = MetaStore::in_memory();
        for k in ["c", "a", "b"] {
            s.put("ns", k, Json::Null).unwrap();
        }
        let keys: Vec<_> =
            s.list("ns").into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, ["a", "b", "c"]);
    }

    #[test]
    fn wal_replay_restores_state() {
        let dir = std::env::temp_dir()
            .join(format!("submarine-kv-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wal-replay.jsonl");
        let _ = std::fs::remove_file(&path);
        {
            let s = MetaStore::open(&path).unwrap();
            s.put("exp", "e1", Json::Num(1.0)).unwrap();
            s.put("exp", "e2", Json::Num(2.0)).unwrap();
            s.put("exp", "e1", Json::Num(3.0)).unwrap(); // overwrite
            s.delete("exp", "e2").unwrap();
        }
        let s = MetaStore::open(&path).unwrap();
        assert_eq!(s.get("exp", "e1"), Some(Json::Num(3.0)));
        assert!(s.get("exp", "e2").is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_wal_is_an_error() {
        let dir = std::env::temp_dir()
            .join(format!("submarine-kv-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wal-corrupt.jsonl");
        std::fs::write(&path, "not json\n").unwrap();
        assert!(MetaStore::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
