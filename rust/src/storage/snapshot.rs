//! Snapshot files and data-directory layout for the storage engine.
//!
//! A [`crate::storage::MetaStore`] data directory holds numbered
//! generations:
//!
//! ```text
//! data/
//!   snapshot-000003.json   # full dump at generation 3
//!   wal-000003.jsonl       # records appended since that snapshot
//! ```
//!
//! Snapshots are written to `*.tmp`, fsynced, then atomically renamed,
//! so a crash mid-snapshot leaves only a `*.tmp` leftover (deleted on
//! the next open) and never a half-readable snapshot. See
//! `docs/STORAGE.md` for the full recovery contract.

use crate::storage::kv::Doc;
use crate::util::json::{write_json_string, write_json_u64, Json};
use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Arc;

const SNAPSHOT_FORMAT: &str = "submarine-snapshot-v1";

pub(crate) fn snapshot_path(dir: &Path, gen: u64) -> PathBuf {
    dir.join(format!("snapshot-{gen:06}.json"))
}

pub(crate) fn wal_path(dir: &Path, gen: u64) -> PathBuf {
    dir.join(format!("wal-{gen:06}.jsonl"))
}

/// Generations present in a data directory, ascending.
#[derive(Debug, Default)]
pub(crate) struct DirScan {
    pub snapshots: Vec<u64>,
    pub wals: Vec<u64>,
}

fn parse_gen(name: &str, prefix: &str, suffix: &str) -> Option<u64> {
    name.strip_prefix(prefix)?
        .strip_suffix(suffix)?
        .parse::<u64>()
        .ok()
}

/// Scan a data directory. With `clean_tmp`, `*.tmp` leftovers from a
/// crashed snapshot write (never renamed, so never authoritative) are
/// deleted along the way; read-only inspection passes `false`.
pub(crate) fn scan_dir(
    dir: &Path,
    clean_tmp: bool,
) -> crate::Result<DirScan> {
    let mut scan = DirScan::default();
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if name.ends_with(".tmp") {
            if clean_tmp {
                let _ = fs::remove_file(entry.path());
            }
            continue;
        }
        if let Some(g) = parse_gen(name, "snapshot-", ".json") {
            scan.snapshots.push(g);
        } else if let Some(g) = parse_gen(name, "wal-", ".jsonl") {
            scan.wals.push(g);
        }
    }
    scan.snapshots.sort_unstable();
    scan.wals.sort_unstable();
    Ok(scan)
}

/// Write the full dump as generation `gen`: tmp file, fsync, atomic
/// rename, best-effort directory fsync. The body is serialized
/// incrementally from the shared documents — no intermediate `Json`
/// tree and no per-document deep clone (the compaction pass holds
/// every shard lock while this runs, so the less work here the
/// shorter the write pause).
pub(crate) fn write_snapshot(
    dir: &Path,
    gen: u64,
    dump: &[(String, Vec<(String, Arc<Doc>)>)],
) -> crate::Result<()> {
    let mut body = Vec::with_capacity(4096);
    body.extend_from_slice(b"{\"format\":");
    write_json_string(&mut body, SNAPSHOT_FORMAT);
    body.extend_from_slice(b",\"gen\":");
    write_json_u64(&mut body, gen);
    body.extend_from_slice(b",\"data\":{");
    for (i, (ns, docs)) in dump.iter().enumerate() {
        if i > 0 {
            body.push(b',');
        }
        write_json_string(&mut body, ns);
        body.extend_from_slice(b":{");
        for (j, (k, doc)) in docs.iter().enumerate() {
            if j > 0 {
                body.push(b',');
            }
            write_json_string(&mut body, k);
            body.push(b':');
            // splice the cached encoding when a WAL append or GET
            // already paid for it; only cold docs serialize here (and
            // without forcing a cache fill they would keep forever)
            match doc.encoded_if_cached() {
                Some(enc) => body.extend_from_slice(&enc),
                None => doc.json().dump_into(&mut body),
            }
        }
        body.push(b'}');
    }
    body.extend_from_slice(b"}}");
    let tmp = dir.join(format!("snapshot-{gen:06}.json.tmp"));
    {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(&body)?;
        f.write_all(b"\n")?;
        f.sync_all()?;
    }
    fs::rename(&tmp, snapshot_path(dir, gen))?;
    // directory entry durability is best-effort (platform-dependent)
    let _ = fs::File::open(dir).and_then(|d| d.sync_all());
    Ok(())
}

/// Load a snapshot file back into the namespace -> key -> doc map.
pub(crate) fn load_snapshot(
    path: &Path,
) -> crate::Result<BTreeMap<String, BTreeMap<String, Json>>> {
    let text = fs::read_to_string(path)?;
    let bad = |msg: &str| {
        crate::SubmarineError::Storage(format!(
            "snapshot {}: {msg}",
            path.display()
        ))
    };
    let j = Json::parse(&text)
        .map_err(|e| bad(&format!("unparseable: {e}")))?;
    if j.str_field("format") != Some(SNAPSHOT_FORMAT) {
        return Err(bad("unknown format"));
    }
    let data = j
        .get("data")
        .and_then(Json::as_obj)
        .ok_or_else(|| bad("missing data object"))?;
    let mut out: BTreeMap<String, BTreeMap<String, Json>> = BTreeMap::new();
    for (ns, docs) in data {
        let docs =
            docs.as_obj().ok_or_else(|| bad("namespace not an object"))?;
        let space = out.entry(ns.clone()).or_default();
        for (k, v) in docs {
            space.insert(k.clone(), v.clone());
        }
    }
    Ok(out)
}

/// Delete snapshot (and optionally WAL) files older than `keep_gen`.
/// Returns how many files were removed. WAL files are only safe to
/// drop once a newer snapshot covers them, so open-time cleanup passes
/// `include_wals = false` and compaction passes `true`.
pub(crate) fn remove_stale(
    dir: &Path,
    keep_gen: u64,
    include_wals: bool,
) -> usize {
    let mut removed = 0;
    let Ok(scan) = scan_dir(dir, true) else { return 0 };
    for g in scan.snapshots {
        if g < keep_gen && fs::remove_file(snapshot_path(dir, g)).is_ok() {
            removed += 1;
        }
    }
    if include_wals {
        for g in scan.wals {
            if g < keep_gen && fs::remove_file(wal_path(dir, g)).is_ok() {
                removed += 1;
            }
        }
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "submarine-snap-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample() -> Vec<(String, Vec<(String, Arc<Doc>)>)> {
        vec![(
            "exp".to_string(),
            vec![
                ("e1".to_string(), Arc::new(Doc::new(Json::Num(1.0)))),
                (
                    "e2".to_string(),
                    Arc::new(Doc::new(
                        Json::obj()
                            .set("status", Json::Str("Running".into())),
                    )),
                ),
            ],
        )]
    }

    #[test]
    fn snapshot_roundtrip() {
        let dir = tmp_dir("roundtrip");
        write_snapshot(&dir, 3, &sample()).unwrap();
        let loaded = load_snapshot(&snapshot_path(&dir, 3)).unwrap();
        assert_eq!(loaded["exp"].len(), 2);
        assert_eq!(
            loaded["exp"]["e2"].str_field("status"),
            Some("Running")
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn scan_orders_generations_and_drops_tmp() {
        let dir = tmp_dir("scan");
        write_snapshot(&dir, 2, &sample()).unwrap();
        write_snapshot(&dir, 1, &sample()).unwrap();
        fs::write(wal_path(&dir, 2), b"").unwrap();
        fs::write(dir.join("snapshot-000009.json.tmp"), b"junk").unwrap();
        let scan = scan_dir(&dir, true).unwrap();
        assert_eq!(scan.snapshots, vec![1, 2]);
        assert_eq!(scan.wals, vec![2]);
        assert!(!dir.join("snapshot-000009.json.tmp").exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_removal_respects_wal_flag() {
        let dir = tmp_dir("stale");
        write_snapshot(&dir, 1, &sample()).unwrap();
        write_snapshot(&dir, 2, &sample()).unwrap();
        fs::write(wal_path(&dir, 1), b"").unwrap();
        fs::write(wal_path(&dir, 2), b"").unwrap();
        assert_eq!(remove_stale(&dir, 2, false), 1);
        assert!(wal_path(&dir, 1).exists());
        assert_eq!(remove_stale(&dir, 2, true), 1);
        assert!(!wal_path(&dir, 1).exists());
        assert!(snapshot_path(&dir, 2).exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_snapshot_is_loud() {
        let dir = tmp_dir("corrupt");
        let p = snapshot_path(&dir, 1);
        fs::write(&p, "not json").unwrap();
        assert!(load_snapshot(&p).is_err());
        fs::write(&p, r#"{"format":"other","data":{}}"#).unwrap();
        assert!(load_snapshot(&p).is_err());
        let _ = fs::remove_dir_all(&dir);
    }
}
