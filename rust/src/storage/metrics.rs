//! Metric time-series store (paper §3.2.2 Output: "logs and metrics are
//! used to troubleshoot bugs and evaluate the quality of models", with
//! "metric visualization ... in Submarine Workbench").
//!
//! Series are keyed by `(experiment, metric)`. The workbench UI is out of
//! scope for a headless reproduction; [`MetricStore::sparkline`] renders
//! the same at-a-glance curve in the terminal and CSV export feeds the
//! benches' figures.

use crate::analysis::lock_order::LockRank;
use crate::analysis::tracker;
use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard};

/// One logged observation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricPoint {
    pub step: u64,
    pub value: f64,
}

/// Thread-safe metric sink.
#[derive(Default)]
pub struct MetricStore {
    series: Mutex<BTreeMap<(String, String), Vec<MetricPoint>>>,
}

impl MetricStore {
    pub fn new() -> MetricStore {
        MetricStore::default()
    }

    /// Series guard + its lock-order token ([`Metrics`] is a leaf
    /// rank: nothing may be acquired under it).
    fn series_lock(
        &self,
    ) -> (
        MutexGuard<'_, BTreeMap<(String, String), Vec<MetricPoint>>>,
        tracker::Held,
    ) {
        let held = tracker::acquired(LockRank::Metrics, 0);
        (self.series.lock().unwrap(), held)
    }

    pub fn log(&self, experiment: &str, metric: &str, step: u64, value: f64) {
        let (mut series, _held) = self.series_lock();
        series
            .entry((experiment.to_string(), metric.to_string()))
            .or_default()
            .push(MetricPoint { step, value });
    }

    /// Log with a bound on retained samples: once the series exceeds
    /// `2 * cap`, the oldest half is dropped (amortized O(1) per log),
    /// keeping between `cap` and `2 * cap` of the most recent points.
    /// Used for open-ended operational series (e.g. per-route HTTP
    /// latency) that would otherwise grow without limit.
    pub fn log_bounded(
        &self,
        experiment: &str,
        metric: &str,
        step: u64,
        value: f64,
        cap: usize,
    ) {
        let cap = cap.max(1);
        let (mut series, _held) = self.series_lock();
        let v = series
            .entry((experiment.to_string(), metric.to_string()))
            .or_default();
        v.push(MetricPoint { step, value });
        if v.len() > 2 * cap {
            v.drain(..v.len() - cap);
        }
    }

    pub fn series(&self, experiment: &str, metric: &str) -> Vec<MetricPoint> {
        let (series, _held) = self.series_lock();
        series
            .get(&(experiment.to_string(), metric.to_string()))
            .cloned()
            .unwrap_or_default()
    }

    pub fn metrics_of(&self, experiment: &str) -> Vec<String> {
        let (series, _held) = self.series_lock();
        series
            .keys()
            .filter(|(e, _)| e == experiment)
            .map(|(_, m)| m.clone())
            .collect()
    }

    pub fn last(&self, experiment: &str, metric: &str) -> Option<MetricPoint> {
        self.series(experiment, metric).last().copied()
    }

    /// min/mean/max summary.
    pub fn summary(
        &self,
        experiment: &str,
        metric: &str,
    ) -> Option<(f64, f64, f64)> {
        let s = self.series(experiment, metric);
        if s.is_empty() {
            return None;
        }
        let (mut lo, mut hi, mut sum) = (f64::MAX, f64::MIN, 0.0);
        for p in &s {
            lo = lo.min(p.value);
            hi = hi.max(p.value);
            sum += p.value;
        }
        Some((lo, sum / s.len() as f64, hi))
    }

    /// Terminal sparkline of the series (workbench §3.1.3 stand-in).
    pub fn sparkline(&self, experiment: &str, metric: &str, width: usize)
        -> String
    {
        const BARS: [char; 8] =
            ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let s = self.series(experiment, metric);
        if s.is_empty() {
            return String::new();
        }
        let width = width.max(1).min(s.len());
        // Downsample by bucketing.
        let bucket = (s.len() as f64 / width as f64).ceil() as usize;
        let vals: Vec<f64> = s
            .chunks(bucket)
            .map(|c| c.iter().map(|p| p.value).sum::<f64>() / c.len() as f64)
            .collect();
        let lo = vals.iter().cloned().fold(f64::MAX, f64::min);
        let hi = vals.iter().cloned().fold(f64::MIN, f64::max);
        let span = (hi - lo).max(1e-12);
        vals.iter()
            .map(|v| BARS[(((v - lo) / span) * 7.0).round() as usize])
            .collect()
    }

    /// CSV export (`step,value` rows) for the bench harness figures.
    pub fn to_csv(&self, experiment: &str, metric: &str) -> String {
        let mut out = String::from("step,value\n");
        for p in self.series(experiment, metric) {
            out.push_str(&format!("{},{}\n", p.step, p.value));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_and_read_back() {
        let m = MetricStore::new();
        m.log("e1", "loss", 0, 1.0);
        m.log("e1", "loss", 1, 0.5);
        m.log("e1", "auc", 1, 0.7);
        assert_eq!(m.series("e1", "loss").len(), 2);
        assert_eq!(m.last("e1", "loss").unwrap().value, 0.5);
        assert_eq!(m.metrics_of("e1"), vec!["auc", "loss"]);
    }

    #[test]
    fn bounded_log_caps_series() {
        let m = MetricStore::new();
        for i in 0..1000 {
            m.log_bounded("http", "lat", i, i as f64, 100);
        }
        let s = m.series("http", "lat");
        assert!(s.len() >= 100 && s.len() <= 200, "len={}", s.len());
        // the retained window is the most recent one
        assert_eq!(s.last().unwrap().step, 999);
        assert!(s[0].step >= 800);
    }

    #[test]
    fn summary_stats() {
        let m = MetricStore::new();
        for (i, v) in [2.0, 4.0, 6.0].iter().enumerate() {
            m.log("e", "x", i as u64, *v);
        }
        let (lo, mean, hi) = m.summary("e", "x").unwrap();
        assert_eq!((lo, mean, hi), (2.0, 4.0, 6.0));
        assert!(m.summary("e", "nope").is_none());
    }

    #[test]
    fn sparkline_shape() {
        let m = MetricStore::new();
        for i in 0..100 {
            m.log("e", "loss", i, 1.0 / (1.0 + i as f64));
        }
        let sl = m.sparkline("e", "loss", 10);
        assert_eq!(sl.chars().count(), 10);
        // decreasing curve: first bucket highest bar, last lowest
        let first = sl.chars().next().unwrap();
        let last = sl.chars().last().unwrap();
        assert_eq!(first, '█');
        assert_eq!(last, '▁');
    }

    #[test]
    fn csv_export() {
        let m = MetricStore::new();
        m.log("e", "loss", 5, 0.25);
        assert_eq!(m.to_csv("e", "loss"), "step,value\n5,0.25\n");
    }
}
