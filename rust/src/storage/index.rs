//! Secondary indexes over [`crate::storage::MetaStore`] documents.
//!
//! An index maps one document field to the set of keys whose documents
//! carry each value (`status -> {"accepted": {e1, e2}, ...}`). The
//! field may be a dotted path into nested objects (`meta.labels`), and
//! a field that resolves to an **object** posts one `key=value` token
//! per pair — which is how label selectors (`?label=team=vision`) are
//! served without scanning. A field resolving to an array of strings
//! posts each element. Indexes live next to the primary map inside the
//! owning shard and are mutated under the same shard write lock as the
//! document itself, so a reader never observes a doc/index mismatch.
//! They are memory-only: recovery rebuilds them from the replayed
//! documents, which keeps the WAL format index-agnostic.

use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};

/// Declaration of one secondary index: which field (dotted path) to
/// index, and whether lookups fold ASCII case (status/stage-style enums
/// do; name-style identifiers and labels don't).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IndexDef {
    pub field: String,
    pub case_insensitive: bool,
}

impl IndexDef {
    pub fn new(field: &str, case_insensitive: bool) -> IndexDef {
        IndexDef {
            field: field.to_string(),
            case_insensitive,
        }
    }
}

/// One maintained posting map: normalized field value -> sorted key set.
#[derive(Debug)]
pub struct FieldIndex {
    def: IndexDef,
    postings: BTreeMap<String, BTreeSet<String>>,
}

impl FieldIndex {
    pub fn new(def: IndexDef) -> FieldIndex {
        FieldIndex {
            def,
            postings: BTreeMap::new(),
        }
    }

    pub fn field(&self) -> &str {
        &self.def.field
    }

    fn normalize(&self, value: &str) -> String {
        if self.def.case_insensitive {
            value.to_ascii_lowercase()
        } else {
            value.to_string()
        }
    }

    /// Resolve the (possibly dotted) index path inside `doc`.
    fn resolve<'a>(&self, doc: &'a Json) -> Option<&'a Json> {
        let mut cur = doc;
        for part in self.def.field.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    /// The posting tokens of `doc`: strings index as-is, numbers/bools
    /// by their JSON text, objects as one `key=value` token per scalar
    /// pair (labels), string arrays one token per element; null and
    /// nested composites don't index.
    fn values_of(&self, doc: &Json) -> Vec<String> {
        let Some(node) = self.resolve(doc) else {
            return Vec::new();
        };
        match node {
            Json::Str(s) => vec![self.normalize(s)],
            v @ (Json::Num(_) | Json::Bool(_)) => vec![v.dump()],
            Json::Obj(pairs) => pairs
                .iter()
                .filter_map(|(k, v)| match v {
                    Json::Str(s) => {
                        Some(self.normalize(&format!("{k}={s}")))
                    }
                    v @ (Json::Num(_) | Json::Bool(_)) => Some(
                        self.normalize(&format!("{k}={}", v.dump())),
                    ),
                    _ => None,
                })
                .collect(),
            Json::Arr(items) => items
                .iter()
                .filter_map(|v| v.as_str().map(|s| self.normalize(s)))
                .collect(),
            _ => Vec::new(),
        }
    }

    /// Add `key`'s postings for `doc` (called under the shard write lock).
    pub fn add(&mut self, key: &str, doc: &Json) {
        for v in self.values_of(doc) {
            self.postings.entry(v).or_default().insert(key.to_string());
        }
    }

    /// Remove `key`'s postings for `doc` (the document being replaced or
    /// deleted — the index must see the *old* doc to find the postings).
    pub fn remove(&mut self, key: &str, doc: &Json) {
        for v in self.values_of(doc) {
            if let Some(set) = self.postings.get_mut(&v) {
                set.remove(key);
                if set.is_empty() {
                    self.postings.remove(&v);
                }
            }
        }
    }

    /// Keys whose documents carry `value`, in key order.
    pub fn lookup(&self, value: &str) -> Vec<String> {
        self.postings
            .get(&self.normalize(value))
            .map(|set| set.iter().cloned().collect())
            .unwrap_or_default()
    }

    /// Up to `limit` keys posted under `value` that sort strictly
    /// after `after` (`None` starts at the beginning). Postings are a
    /// sorted set, so a cursor that remembers the last key it saw
    /// resumes in O(log n) and never re-walks delivered keys — the
    /// index-path counterpart of `MetaStore::page_after`.
    pub fn lookup_after(
        &self,
        value: &str,
        after: Option<&str>,
        limit: usize,
    ) -> Vec<String> {
        use std::ops::Bound;
        let Some(set) = self.postings.get(&self.normalize(value))
        else {
            return Vec::new();
        };
        let lo = match after {
            Some(a) => Bound::Excluded(a),
            None => Bound::Unbounded,
        };
        set.range::<str, _>((lo, Bound::Unbounded))
            .take(limit)
            .cloned()
            .collect()
    }

    /// Number of keys posted under `value` (for stats / pagination
    /// totals without materializing the key list).
    pub fn cardinality(&self, value: &str) -> usize {
        self.postings
            .get(&self.normalize(value))
            .map(BTreeSet::len)
            .unwrap_or(0)
    }

    /// Distinct indexed values and their posting sizes.
    pub fn histogram(&self) -> BTreeMap<String, usize> {
        self.postings
            .iter()
            .map(|(v, set)| (v.clone(), set.len()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(status: &str) -> Json {
        Json::obj().set("status", Json::Str(status.to_string()))
    }

    #[test]
    fn add_lookup_remove_roundtrip() {
        let mut idx = FieldIndex::new(IndexDef::new("status", true));
        idx.add("e1", &doc("Running"));
        idx.add("e2", &doc("Running"));
        idx.add("e3", &doc("Failed"));
        assert_eq!(idx.lookup("running"), vec!["e1", "e2"]);
        assert_eq!(idx.lookup("RUNNING"), vec!["e1", "e2"]);
        assert_eq!(idx.cardinality("failed"), 1);
        idx.remove("e1", &doc("Running"));
        assert_eq!(idx.lookup("Running"), vec!["e2"]);
        idx.remove("e2", &doc("Running"));
        assert!(idx.lookup("Running").is_empty());
        // empty posting sets are pruned
        assert_eq!(idx.histogram().len(), 1);
    }

    #[test]
    fn lookup_after_resumes_in_key_order() {
        let mut idx = FieldIndex::new(IndexDef::new("status", true));
        for k in ["e1", "e2", "e3", "e4"] {
            idx.add(k, &doc("Running"));
        }
        assert_eq!(
            idx.lookup_after("running", None, 2),
            vec!["e1", "e2"]
        );
        assert_eq!(
            idx.lookup_after("running", Some("e2"), 2),
            vec!["e3", "e4"]
        );
        assert!(idx.lookup_after("running", Some("e4"), 2).is_empty());
        // an `after` that was deleted meanwhile still seeks correctly
        idx.remove("e3", &doc("Running"));
        assert_eq!(
            idx.lookup_after("running", Some("e2"), 2),
            vec!["e4"]
        );
        assert!(idx.lookup_after("failed", None, 2).is_empty());
    }

    #[test]
    fn case_sensitive_index_distinguishes() {
        let mut idx = FieldIndex::new(IndexDef::new("name", false));
        idx.add("k1", &Json::obj().set("name", Json::Str("A".into())));
        assert_eq!(idx.lookup("A"), vec!["k1"]);
        assert!(idx.lookup("a").is_empty());
    }

    #[test]
    fn non_scalar_fields_do_not_index() {
        let mut idx = FieldIndex::new(IndexDef::new("tags", true));
        idx.add("k1", &Json::obj().set("tags", Json::Arr(vec![])));
        idx.add("k2", &Json::obj());
        assert!(idx.histogram().is_empty());
        // removing unindexed docs is a no-op
        idx.remove("k1", &Json::obj().set("tags", Json::Arr(vec![])));
    }

    #[test]
    fn label_map_posts_one_token_per_pair() {
        let mut idx =
            FieldIndex::new(IndexDef::new("meta.labels", false));
        let doc = Json::obj().set(
            "meta",
            Json::obj().set(
                "labels",
                Json::obj()
                    .set("team", Json::Str("vision".into()))
                    .set("tier", Json::Str("prod".into())),
            ),
        );
        idx.add("e1", &doc);
        assert_eq!(idx.lookup("team=vision"), vec!["e1"]);
        assert_eq!(idx.lookup("tier=prod"), vec!["e1"]);
        assert!(idx.lookup("team=nlp").is_empty());
        idx.remove("e1", &doc);
        assert!(idx.histogram().is_empty());
    }

    #[test]
    fn string_arrays_post_each_element() {
        let mut idx = FieldIndex::new(IndexDef::new("tags", false));
        let doc = Json::obj().set(
            "tags",
            Json::Arr(vec![
                Json::Str("a".into()),
                Json::Str("b".into()),
            ]),
        );
        idx.add("k", &doc);
        assert_eq!(idx.lookup("a"), vec!["k"]);
        assert_eq!(idx.lookup("b"), vec!["k"]);
    }

    #[test]
    fn numbers_index_by_json_text() {
        let mut idx = FieldIndex::new(IndexDef::new("version", false));
        idx.add("k1", &Json::obj().set("version", Json::Num(3.0)));
        assert_eq!(idx.lookup("3"), vec!["k1"]);
    }
}
