//! Metadata and metric persistence (DESIGN.md S5).
//!
//! Paper §3.2.2: "the experiment manager ... persists the experiment
//! metadata in a database so that experiments become easy to compare and
//! reproducible."  [`MetaStore`] is that database: a namespaced KV store
//! over [`crate::util::json::Json`] documents — engine v2 with sharded
//! locking, a group-committed WAL bounded by snapshot compaction, and
//! secondary indexes (see [`kv`] and `docs/STORAGE.md`).  [`MetricStore`]
//! holds time-series metrics (loss curves etc.) and renders the
//! workbench-style summaries.

pub mod index;
pub mod kv;
pub mod metrics;
pub(crate) mod snapshot;

pub use kv::{
    Change, CompactReport, Doc, MetaStore, StorageStats, StoreOptions,
    UpdateRev,
};
pub use metrics::{MetricPoint, MetricStore};
