//! Metadata and metric persistence (DESIGN.md S5).
//!
//! Paper §3.2.2: "the experiment manager ... persists the experiment
//! metadata in a database so that experiments become easy to compare and
//! reproducible."  [`MetaStore`] is that database: a namespaced KV store
//! over [`crate::util::json::Json`] documents with an append-only WAL so
//! state survives restarts.  [`MetricStore`] holds time-series metrics
//! (loss curves etc.) and renders the workbench-style summaries.

pub mod kv;
pub mod metrics;

pub use kv::MetaStore;
pub use metrics::{MetricPoint, MetricStore};
