//! Bench harness (DESIGN.md S4): criterion-style warmup + timed iterations
//! with mean/p50/p95 reporting, plus an aligned table printer used by every
//! `rust/benches/*.rs` target to regenerate the paper's tables and claims.
//! (The offline registry lacks `criterion`; methodology is the same.)

use crate::util::clock::Stopwatch;

/// Timing statistics over n iterations (seconds).
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    fn from_samples(mut samples: Vec<f64>) -> Stats {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let pct = |p: f64| samples[((n as f64 * p) as usize).min(n - 1)];
        Stats {
            iters: n,
            mean,
            p50: pct(0.50),
            p95: pct(0.95),
            min: samples[0],
            max: samples[n - 1],
        }
    }

    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean
    }
}

/// True when `BENCH_SMOKE` is set (and not `0`): benches shrink their
/// workloads so CI can run them on every commit as a provenance smoke
/// test (results are uploaded as build artifacts).
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
}

/// Scale an iteration/size knob down for smoke mode.
pub fn scaled(n: usize) -> usize {
    if smoke() {
        (n / 10).max(1)
    } else {
        n
    }
}

/// `(min_iters, min_secs)` for [`bench`], shrunk in smoke mode.
pub fn bench_params(min_iters: usize, min_secs: f64) -> (usize, f64) {
    if smoke() {
        ((min_iters / 10).max(3), min_secs / 10.0)
    } else {
        (min_iters, min_secs)
    }
}

/// Time `f` with warmup. `min_iters`/`min_secs` bound total effort.
pub fn bench(min_iters: usize, min_secs: f64, mut f: impl FnMut()) -> Stats {
    // Warmup: a few runs to populate caches / JIT the PJRT executable.
    for _ in 0..2.min(min_iters) {
        f();
    }
    let mut samples = Vec::with_capacity(min_iters);
    let total = Stopwatch::start();
    loop {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.elapsed_secs());
        if samples.len() >= min_iters && total.elapsed_secs() >= min_secs {
            break;
        }
        if total.elapsed_secs() > min_secs * 20.0 + 30.0 {
            break; // hard cap for very slow subjects
        }
    }
    Stats::from_samples(samples)
}

/// Machine-readable result sink (ISSUE 5): in smoke mode every bench
/// that races a baseline against its optimized path also records
/// `(op, baseline ns, optimized ns, ratio)` into `BENCH_5.json` at the
/// repo root (override the directory with `BENCH_RESULTS_DIR`), so CI
/// uploads make the perf trajectory trackable PR-over-PR. Entries
/// merge by `op`: bench binaries run sequentially and each read-
/// modify-writes the shared file.
pub fn record_result(op: &str, baseline_secs: f64, optimized_secs: f64) {
    record_result_to("BENCH_5.json", op, baseline_secs, optimized_secs)
}

/// Like [`record_result`] but into an explicit results file — each PR's
/// headline bench writes its own `BENCH_N.json`, and the CI bench gate
/// globs `BENCH_*.json` so new files are picked up automatically.
pub fn record_result_to(
    file: &str,
    op: &str,
    baseline_secs: f64,
    optimized_secs: f64,
) {
    if !smoke() {
        return;
    }
    let path = std::env::var("BENCH_RESULTS_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
        })
        .join(file);
    let mut results: Vec<crate::util::json::Json> =
        match std::fs::read_to_string(&path)
            .ok()
            .and_then(|t| crate::util::json::Json::parse(&t).ok())
        {
            Some(j) => j
                .get("results")
                .and_then(crate::util::json::Json::as_arr)
                .map(|a| a.to_vec())
                .unwrap_or_default(),
            None => Vec::new(),
        };
    results.retain(|r| r.str_field("op") != Some(op));
    use crate::util::json::Json;
    let baseline_ns = baseline_secs * 1e9;
    let optimized_ns = optimized_secs * 1e9;
    results.push(
        Json::obj()
            .set("op", Json::Str(op.to_string()))
            .set("baseline_ns", Json::Num(baseline_ns.round()))
            .set("optimized_ns", Json::Num(optimized_ns.round()))
            .set(
                "ratio",
                Json::Num(baseline_ns / optimized_ns.max(1.0)),
            ),
    );
    let doc = Json::obj().set("results", Json::Arr(results));
    if let Err(e) = std::fs::write(&path, doc.pretty()) {
        eprintln!("bench: failed to write {}: {e}", path.display());
    } else {
        println!("bench: recorded {op} -> {}", path.display());
    }
}

/// Human duration formatting.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Aligned ASCII table printer for bench reports.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = width[i]));
            }
            s
        };
        let sep = {
            let mut s = String::from("|");
            for w in &width {
                s.push_str(&format!("{}|", "-".repeat(w + 2)));
            }
            s
        };
        let mut out = format!("\n== {} ==\n", self.title);
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let s = bench(5, 0.0, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 5);
        assert!(s.min <= s.p50 && s.p50 <= s.max);
        assert!(s.mean > 0.0);
    }

    #[test]
    fn throughput_inverts_mean() {
        let s = Stats {
            iters: 1,
            mean: 0.5,
            p50: 0.5,
            p95: 0.5,
            min: 0.5,
            max: 0.5,
        };
        assert!((s.throughput(100.0) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer-name".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("longer-name"));
        // all data lines same width
        let lines: Vec<_> =
            r.lines().filter(|l| l.starts_with('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    fn fmt_secs_scales() {
        assert_eq!(fmt_secs(2.0), "2.00s");
        assert_eq!(fmt_secs(0.002), "2.00ms");
        assert_eq!(fmt_secs(2e-6), "2.0us");
    }
}
