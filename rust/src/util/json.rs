//! Minimal-dependency JSON parser and serializer.
//!
//! The offline crate registry for this build lacks `serde`/`serde_json`, so
//! Submarine-RS carries its own JSON substrate (DESIGN.md S1). It supports
//! the full JSON grammar (RFC 8259): objects, arrays, strings with escapes
//! and `\uXXXX` (including surrogate pairs), numbers, booleans, null.
//! Object key order is preserved (insertion order) so serialized specs stay
//! diff-stable.
//!
//! Serialization is allocation-free beyond the output buffer (ISSUE 5):
//! [`Json::dump_into`] appends to a caller-owned `Vec<u8>`, escape-free
//! string spans are bulk-copied with one `extend_from_slice`, and numbers
//! format through `fmt::Write` straight into the buffer instead of
//! `format!` temporaries. The parser takes the same tack on the way in:
//! escape-free strings become one bulk slice copy and collections are
//! preallocated from input-size heuristics.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with insertion-ordered keys.
    Obj(Vec<(String, Json)>),
}

/// Error raised by [`Json::parse`], with byte offset into the input.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------ access
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as u64) } else { None })
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Nested lookup: `j.at(&["spec", "Worker", "replicas"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    /// Convenience: string field.
    pub fn str_field(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }
    /// Convenience: numeric field.
    pub fn num_field(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    // ------------------------------------------------------- construction
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }
    /// Builder-style insert for objects (replaces an existing key).
    pub fn set(mut self, key: &str, value: Json) -> Json {
        if let Json::Obj(ref mut o) = self {
            if let Some(slot) = o.iter_mut().find(|(k, _)| k == key) {
                slot.1 = value;
            } else {
                o.push((key.to_string(), value));
            }
        }
        self
    }
    pub fn from_map(map: &BTreeMap<String, String>) -> Json {
        Json::Obj(
            map.iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect(),
        )
    }

    // ------------------------------------------------------------ parsing
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -------------------------------------------------------- serializing
    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = Vec::with_capacity(128);
        self.write(&mut out, None, 0);
        // The serializer only emits `str` slices and ASCII syntax.
        String::from_utf8(out).expect("json serializer emits utf-8")
    }

    /// Compact serialization appended to a caller-owned buffer — the
    /// zero-allocation form every hot serialization call site uses
    /// (response bodies, WAL records, cached document bodies).
    pub fn dump_into(&self, out: &mut Vec<u8>) {
        self.write(out, None, 0);
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = Vec::with_capacity(256);
        self.write(&mut out, Some(2), 0);
        String::from_utf8(out).expect("json serializer emits utf-8")
    }

    fn write(&self, out: &mut Vec<u8>, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.extend_from_slice(b"null"),
            Json::Bool(true) => out.extend_from_slice(b"true"),
            Json::Bool(false) => out.extend_from_slice(b"false"),
            Json::Num(n) => write_json_num(out, *n),
            Json::Str(s) => write_json_string(out, s),
            Json::Arr(a) => {
                out.push(b'[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(b',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(b']');
            }
            Json::Obj(o) => {
                out.push(b'{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(b',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_json_string(out, k);
                    out.push(b':');
                    if indent.is_some() {
                        out.push(b' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(b'}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.dump())
    }
}

fn newline_indent(out: &mut Vec<u8>, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push(b'\n');
        out.resize(out.len() + n * depth, b' ');
    }
}

/// Adapter letting `fmt::Write` formatting land directly in a byte
/// buffer (numbers, `\uXXXX` escapes) with no `String` temporary.
struct FmtBytes<'a>(&'a mut Vec<u8>);

impl fmt::Write for FmtBytes<'_> {
    fn write_str(&mut self, s: &str) -> fmt::Result {
        self.0.extend_from_slice(s.as_bytes());
        Ok(())
    }
}

/// Append the JSON text of `n` to `out` (integral values print without
/// a fraction; non-finite values have no JSON form and print `null`).
pub fn write_json_num(out: &mut Vec<u8>, n: f64) {
    use fmt::Write as _;
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        write_json_i64(out, n as i64);
    } else if n.is_finite() {
        let _ = write!(FmtBytes(out), "{}", n);
    } else {
        out.extend_from_slice(b"null"); // JSON has no Inf/NaN
    }
}

/// Append a decimal integer without intermediate allocation.
pub fn write_json_u64(out: &mut Vec<u8>, mut v: u64) {
    let mut tmp = [0u8; 20];
    let mut i = tmp.len();
    loop {
        i -= 1;
        tmp[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    out.extend_from_slice(&tmp[i..]);
}

fn write_json_i64(out: &mut Vec<u8>, v: i64) {
    if v < 0 {
        out.push(b'-');
        write_json_u64(out, v.unsigned_abs());
    } else {
        write_json_u64(out, v as u64);
    }
}

/// Append a JSON string literal (quoted and escaped) to `out`.
/// Escape-free spans — the overwhelmingly common case — are copied with
/// one `extend_from_slice` instead of per-character pushes; multi-byte
/// UTF-8 passes through raw (RFC 8259 permits unescaped non-ASCII).
pub fn write_json_string(out: &mut Vec<u8>, s: &str) {
    use fmt::Write as _;
    out.push(b'"');
    let bytes = s.as_bytes();
    let mut start = 0;
    for (i, &b) in bytes.iter().enumerate() {
        let esc: &[u8] = match b {
            b'"' => b"\\\"",
            b'\\' => b"\\\\",
            b'\n' => b"\\n",
            b'\r' => b"\\r",
            b'\t' => b"\\t",
            0x08 => b"\\b",
            0x0c => b"\\f",
            b if b < 0x20 => {
                out.extend_from_slice(&bytes[start..i]);
                let _ = write!(FmtBytes(out), "\\u{:04x}", b);
                start = i + 1;
                continue;
            }
            _ => continue,
        };
        out.extend_from_slice(&bytes[start..i]);
        out.extend_from_slice(esc);
        start = i + 1;
    }
    out.extend_from_slice(&bytes[start..]);
    out.push(b'"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    /// Preallocation hint for a collection opening at the current
    /// position: a conservative guess from the remaining input size
    /// (~16 bytes per element, capped so hostile input cannot reserve
    /// unbounded memory up front).
    fn collection_hint(&self) -> usize {
        ((self.bytes.len() - self.pos) / 16).clamp(4, 64)
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::with_capacity(self.collection_hint());
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(fields)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::with_capacity(self.collection_hint());
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        // Fast path: scan to the closing quote; a string with no
        // escapes and no control chars becomes one validated bulk copy
        // instead of a byte-at-a-time rebuild.
        let raw = self.bytes; // copy of the &'a [u8], not a self-borrow
        let start = self.pos;
        let mut scan = self.pos;
        while let Some(&b) = raw.get(scan) {
            match b {
                b'"' => {
                    let text = std::str::from_utf8(&raw[start..scan])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    self.pos = scan + 1;
                    return Ok(text.to_string());
                }
                b'\\' => break,
                b if b < 0x20 => break, // slow path reports the error
                _ => scan += 1,
            }
        }
        // Slow path (escapes present or malformed): re-scan from the
        // start with a capacity hint from the clean prefix.
        let mut s = String::with_capacity(scan - start + 16);
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\x08'),
                    Some(b'f') => s.push('\x0c'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // surrogate pair
                            if self.bump() != Some(b'\\')
                                || self.bump() != Some(b'u')
                            {
                                return Err(
                                    self.err("expected low surrogate")
                                );
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            s.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        } else {
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let len = utf8_len(b)
                            .ok_or_else(|| self.err("invalid utf-8"))?;
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk =
                            std::str::from_utf8(&self.bytes[start..end])
                                .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(b: u8) -> Option<usize> {
    match b {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"hi\"").unwrap(),
            Json::Str("hi".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":{"d":true}}"#)
            .unwrap();
        assert_eq!(j.at(&["c", "d"]), Some(&Json::Bool(true)));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "line\n\"quote\"\t\\slash\\ unicode: \u{1F600} é";
        let j = Json::Str(s.to_string());
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn surrogate_pairs() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "\u{1F600}");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\"}", "tru", "1.2.3", "\"\\q\"",
                    "{} extra", "\"\\ud83d\""] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn preserves_key_order() {
        let j = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = j
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, _)| k.as_str())
            .collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn pretty_parses_back() {
        let j = Json::parse(r#"{"a":[1,2],"b":{"c":"d"}}"#).unwrap();
        assert_eq!(Json::parse(&j.pretty()).unwrap(), j);
    }

    #[test]
    fn builder_set_replaces() {
        let j = Json::obj()
            .set("a", Json::Num(1.0))
            .set("a", Json::Num(2.0));
        assert_eq!(j.num_field("a"), Some(2.0));
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }

    #[test]
    fn integral_numbers_stay_integral() {
        assert_eq!(Json::Num(256.0).dump(), "256");
        assert_eq!(Json::Num(0.001).dump(), "0.001");
        assert_eq!(Json::Num(-42.0).dump(), "-42");
    }

    #[test]
    fn dump_into_appends_to_existing_buffer() {
        let j = Json::parse(r#"{"a":[1,"x"],"b":null}"#).unwrap();
        let mut buf = b"result:".to_vec();
        j.dump_into(&mut buf);
        assert_eq!(
            std::str::from_utf8(&buf).unwrap(),
            r#"result:{"a":[1,"x"],"b":null}"#
        );
        // identical to dump()
        assert_eq!(&buf[7..], j.dump().as_bytes());
    }

    #[test]
    fn byte_helpers_match_dump() {
        let mut buf = Vec::new();
        write_json_u64(&mut buf, 0);
        buf.push(b' ');
        write_json_u64(&mut buf, 18_446_744_073_709_551_615);
        assert_eq!(buf, b"0 18446744073709551615");
        for s in ["plain", "esc\"\\\n\t", "unicode \u{1F600} é", "\u{1}"] {
            let mut via_helper = Vec::new();
            write_json_string(&mut via_helper, s);
            assert_eq!(
                via_helper,
                Json::Str(s.to_string()).dump().into_bytes(),
                "mismatch for {s:?}"
            );
        }
        for n in [1.5, -0.25, 3e20, f64::NAN, f64::INFINITY] {
            let mut via_helper = Vec::new();
            write_json_num(&mut via_helper, n);
            assert_eq!(via_helper, Json::Num(n).dump().into_bytes());
        }
    }

    #[test]
    fn fast_and_slow_string_paths_agree() {
        // escape-free (fast path) and escaped (slow path) round-trip
        for raw in [r#""hello world""#, r#""aA\n b""#] {
            let j = Json::parse(raw).unwrap();
            assert_eq!(Json::parse(&j.dump()).unwrap(), j);
        }
    }
}
