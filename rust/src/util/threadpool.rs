//! Fixed-size thread pool (DESIGN.md S2). Offline registry lacks `tokio` /
//! `rayon`; this std-only pool offers a bounded task queue, graceful
//! shutdown on drop, and a `scope`-style join helper for fork/join
//! workloads. Currently has no in-tree callers: the HTTP server (its
//! original user) moved to a capped thread-per-connection model with
//! keep-alive in API v2. Kept as shared infrastructure for future
//! fork/join work.

use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Task),
    Shutdown,
}

/// A fixed-size worker pool.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("submarine-pool-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(task)) => task(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx, workers, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a task; never blocks.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .send(Msg::Run(Box::new(f)))
            .expect("pool has shut down");
    }

    /// Run `jobs` to completion on the pool and collect results in order.
    pub fn map<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        let results: Arc<(Mutex<Vec<Option<T>>>, Condvar)> = Arc::new((
            Mutex::new((0..n).map(|_| None).collect()),
            Condvar::new(),
        ));
        for (i, job) in jobs.into_iter().enumerate() {
            let results = Arc::clone(&results);
            self.execute(move || {
                let out = job();
                let (lock, cv) = &*results;
                lock.lock().unwrap()[i] = Some(out);
                cv.notify_all();
            });
        }
        let (lock, cv) = &*results;
        let mut guard = lock.lock().unwrap();
        while guard.iter().any(|r| r.is_none()) {
            guard = cv.wait(guard).unwrap();
        }
        guard.iter_mut().map(|r| r.take().unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_tasks() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        for _ in 0..100 {
            let counter = Arc::clone(&counter);
            let done = Arc::clone(&done);
            pool.execute(move || {
                counter.fetch_add(1, Ordering::SeqCst);
                let (lock, cv) = &*done;
                *lock.lock().unwrap() += 1;
                cv.notify_all();
            });
        }
        let (lock, cv) = &*done;
        let mut g = lock.lock().unwrap();
        while *g < 100 {
            g = cv.wait(g).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let jobs: Vec<_> = (0..20)
            .map(|i| move || i * i)
            .collect();
        let out = pool.map(jobs);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(
            std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn zero_size_clamped_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        assert_eq!(pool.map(vec![|| 42]), vec![42]);
    }
}
